"""Paper Table 4: VGG-16 comparison to existing works at (16, 32)."""
from repro.core.synthesis import CNN2Gate
from repro.models import cnn
from .common import emit

CITED = [
    ("Qiu'16 [39]", "Zynq 7045", None, 136.91),
    ("Ma'17 [10]", "Arria10", 47.97, 645.25),
    ("fpgaConvNet [8]", "Zynq 7045", 249.5, 161.98),
    ("Suda'16 [20]", "Stratix-V", 262.9, 117.8),
]


def run() -> None:
    gate = CNN2Gate.from_graph(cnn.vgg16())
    rep = gate.latency_report("ARRIA10", 16, 32)
    for name, fpga, lat, gops in CITED:
        emit(f"table4/{name.split()[0]}",
             (lat or 0) * 1e3, f"{fpga} {gops}GOp/s")
    emit("table4/this-work", rep.total_s * 1e6,
         f"Arria10 {rep.total_s * 1e3:.0f}ms {rep.gops:.1f}GOp/s "
         "(paper: 205ms, 151.7GOp/s)")
