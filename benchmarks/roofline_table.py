"""Roofline table from the dry-run results (EXPERIMENTS.md §Roofline).

Reads results/dryrun.json (produced by repro.launch.dryrun) and prints
the per-cell three-term roofline.  Falls back to recomputing a single
representative cell if the sweep output is missing.
"""
import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


def run() -> None:
    if not os.path.exists(RESULTS):
        emit("roofline/missing", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun")
        return
    with open(RESULTS) as f:
        cells = json.load(f)
    singles = {k: v for k, v in cells.items() if k.endswith("single_pod")}
    for key in sorted(singles):
        v = singles[key]
        arch, shape, _ = key.split("|")
        emit(f"roofline/{arch}/{shape}", v["t_step"] * 1e6,
             f"dom={v['dominant']} tc={v['t_compute']:.3g}s "
             f"tm={v['t_memory_fused']:.3g}s tcol={v['t_collective']:.3g}s "
             f"rf={v['roofline_fraction']:.3f} "
             f"useful={v['useful_flops_ratio']:.2f}")
    multi = [k for k in cells if k.endswith("multi_pod")]
    emit("roofline/multi_pod_cells", float(len(multi)),
         f"{len(multi)} cells compiled on the 2x16x16 mesh")
