"""§Perf hillclimb results (reads results/perf.json)."""
import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "perf.json")


def run() -> None:
    if not os.path.exists(RESULTS):
        emit("perf/missing", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.perf")
        return
    with open(RESULTS) as f:
        cells = json.load(f)
    for key in sorted(cells):
        v = cells[key]
        arch, shape, it = key.split("|")
        emit(f"perf/{arch}/{shape}/{it}", v["t_step"] * 1e6,
             f"rf={v['roofline_fraction']:.3f} dom={v['dominant']} "
             f"tc={v['t_compute']:.3g} tm={v['t_memory_fused']:.3g} "
             f"tcol={v['t_collective']:.3g} "
             f"peakGB={v['peak_bytes_per_dev'] / 1e9:.1f}")
