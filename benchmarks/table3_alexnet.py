"""Paper Table 3: AlexNet comparison to existing works at (16, 32).

Cited rows are the paper's published numbers; 'this work' is our
calibrated model + DSE resource estimate, including the performance
density (GOp/s/DSP) comparison the paper highlights (0.266 vs 0.234
for [20])."""
from repro.core.synthesis import CNN2Gate
from repro.models import cnn
from .common import emit

CITED = [
    ("Zhang'15 [21]", "Virtex-7", 21.61, 61.62, 2240),
    ("Ma'16 [22]", "Stratix-V", 12.75, 114.5, 256),
    ("fpgaConvNet [8]", "Zynq 7045", 8.22, 161.98, 897),
    ("Suda'16 [20]", "Stratix-V GX-D8", 20.1, 72.4, 665),
]


def run() -> None:
    gate = CNN2Gate.from_graph(cnn.alexnet())
    rep = gate.latency_report("ARRIA10", 16, 32)
    dse = gate.explore("ARRIA10", algo="bf")
    dsp = dse.best_report.raw["dsp"]
    for name, fpga, lat, gops, dsps in CITED:
        emit(f"table3/{name.split()[0]}", lat * 1e3,
             f"{fpga} {gops}GOp/s density={gops / dsps:.3f}")
    emit("table3/this-work", rep.total_s * 1e9 / 1e3,
         f"Arria10 {rep.gops:.1f}GOp/s dsp={dsp:.0f} "
         f"density={rep.gops / dsp:.3f} (paper: 80.04GOp/s, 0.266)")
