"""Inception-class concat-fusion benchmark (interpret mode on CPU).

Three executors over the same quantized program on the two
inception-class builders (googlenet_tiny: two 4-way merges;
squeezenet_tiny: three fire-module 2-way merges):

  * ``fused_concat`` — the default: every eligible concat written
    in-place by the producing conv epilogues (DESIGN.md §10), plus
    skip fusion, one jitted closure;
  * ``unfused``      — same one-jit DAG interpreter with every merge a
    standalone stage (``fuse_concat=False, fuse_skip=False``);
  * ``stagewise``    — per-stage Python dispatch of the unfused
    program (the seed-style loop).

All three are bit-identical (asserted before timing).  Interpret-mode
wall clocks are functional-path timings, NOT TPU performance — what
concat fusion actually buys is **memory traffic**: every fused merge
deletes one full merged-feature-map write + read from the stage
schedule (the concat stops being a copy), so the JSON also records the
modeled per-inference DDR bytes and the paper's Table-1 latency model
for both programs — the axis the fused program must (and does) win on
every backend with a memory hierarchy.
"""
import json
import os
import time

import numpy as np
import jax.numpy as jnp

from repro.core import parser as P
from repro.core import pipeline as pipe
from repro.core.synthesis import CNN2Gate
from repro.kernels import ops
from repro.models import cnn
from .common import emit, write_bench_json

RNG = np.random.default_rng(0)
RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "inception_bench.json")


def _stagewise(qm: pipe.QuantizedModel, x_float: jnp.ndarray):
    """Baseline executor: the same DAG interpretation, but dispatched
    stage-by-stage from Python on every call (no whole-program jit)."""
    h = jnp.clip(jnp.round(x_float * 2.0 ** qm.input_m),
                 -128, 127).astype(jnp.int8)
    h = jnp.transpose(h, (0, 2, 3, 1))
    env = {qm.parsed.input_name: h}
    for ql in qm.layers:
        li = ql.info
        if li.kind == P.CONV:
            pool = None
            if li.pool is not None:
                pool = (li.pool.kernel_shape[0], li.pool.strides[0])
            h = ops.qconv2d_nhwc(env[li.inputs[0]], ql.w_q, ql.b_q,
                                 strides=li.strides, pads=li.pads,
                                 shift=ql.spec.requant_shift, relu=li.relu,
                                 pool=pool, groups=li.group, interpret=True)
        elif li.kind == P.POOL:
            fn = (ops.avgpool2d_nhwc if li.pool_type == "avg"
                  else ops.maxpool2d_nhwc)
            h = fn(env[li.inputs[0]], li.kernel_shape[0], li.strides[0],
                   li.pads)
        elif li.kind == P.FC:
            h = env[li.inputs[0]]
            if h.ndim > 2:
                h = h.reshape(h.shape[0], -1)
            h = ops.qgemm(h, ql.w_q, ql.b_q, shift=ql.spec.requant_shift,
                          relu=li.relu, interpret=True)
        elif li.kind == P.ADD:
            h = ops.qadd_nhwc([env[t] for t in li.inputs],
                              ql.operand_shifts,
                              shift=ql.spec.requant_shift, relu=li.relu)
        else:
            h = ops.qconcat_nhwc([env[t] for t in li.inputs],
                                 ql.operand_shifts, relu=li.relu)
        env[li.output] = h
    out = env[qm.parsed.output_name]
    return out.astype(jnp.float32) * (2.0 ** -qm.output_m)


def run() -> None:
    results = {}
    for tag, build, in_hw, batch in (
            ("googlenet_tiny", cnn.googlenet_tiny, 24, 2),
            ("squeezenet_tiny", cnn.squeezenet_tiny, 24, 2)):
        gate = CNN2Gate.from_graph(build(batch=batch, in_hw=in_hw))
        x = (RNG.standard_normal((batch, 3, in_hw, in_hw)) * 0.5
             ).astype(np.float32)
        specs = gate.calibrate_quantization(x)
        xj = jnp.asarray(x)

        gate_u = CNN2Gate.from_graph(build(batch=batch, in_hw=in_hw),
                                     fuse_skip=False, fuse_concat=False)
        gate_u.apply_quantization(specs)
        qm_u = gate_u.quantized

        n_fused = sum(li.kind == P.CONCAT and li.concat_fused
                      for li in gate.parsed.layers)
        n_cc = sum(li.kind == P.CONCAT for li in gate.parsed.layers)
        assert n_fused == n_cc and n_cc > 0, (tag, n_fused, n_cc)

        fused = gate.build("emulation")
        unfused = gate_u.build("emulation")
        np.testing.assert_array_equal(  # never time divergent programs
            np.asarray(fused(xj)), np.asarray(unfused(xj)))

        # interleave the contenders round-robin: CPU wall-clock drifts
        # far more *between* measurement blocks than within one, so
        # back-to-back blocks systematically bias whichever runs first
        cases = {"fused_concat": lambda: fused(xj),
                 "unfused": lambda: unfused(xj),
                 "stagewise": lambda: _stagewise(qm_u, xj)}
        times = {k: [] for k in cases}
        for _ in range(3):          # warmup, all contenders
            for fn in cases.values():
                fn().block_until_ready()
        for _ in range(15):
            for k, fn in cases.items():
                t0 = time.perf_counter()
                fn().block_until_ready()
                times[k].append(time.perf_counter() - t0)
        med = {k: float(np.median(v) * 1e6) for k, v in times.items()}

        us_fused, us_unfused, us_stage = (med["fused_concat"],
                                          med["unfused"],
                                          med["stagewise"])
        emit(f"inception/{tag}_fused_concat", us_fused,
             "concats written in-place by producer epilogues")
        emit(f"inception/{tag}_unfused", us_unfused,
             "standalone merge stages")
        emit(f"inception/{tag}_stagewise", us_stage,
             "per-stage Python dispatch")

        # the claim concat fusion makes: fewer stage-schedule bytes and
        # a lower modeled pipeline latency — every fused concat removes
        # one merged-feature-map write + read
        def _model(g):
            by = sum(sum(pipe.layer_bytes(li.info))
                     for li in g.quantized.layers)
            lat = g.latency_report("ARRIA10", 16, 32).total_s
            return by, lat
        bytes_f, lat_f = _model(gate)
        bytes_u, lat_u = _model(gate_u)
        assert bytes_f < bytes_u, (tag, bytes_f, bytes_u)
        emit(f"inception/{tag}_model_bytes_saved", float(bytes_u - bytes_f),
             "DDR bytes/inference removed by concat fusion")

        results[tag] = {
            "batch": batch, "in_hw": in_hw,
            "fused_concat_us": us_fused, "unfused_us": us_unfused,
            "stagewise_us": us_stage,
            "wallclock_speedup": us_unfused / max(us_fused, 1e-9),
            "speedup": us_stage / max(us_fused, 1e-9),
            "fused_concats": int(n_fused),
            "model_bytes_fused_concat": bytes_f,
            "model_bytes_unfused": bytes_u,
            "model_latency_fused_concat_s": lat_f,
            "model_latency_unfused_s": lat_u,
            "fused_concat_beats_unfused": bool(bytes_f < bytes_u
                                               and lat_f <= lat_u),
        }

    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(results, f, indent=1)
    write_bench_json("inception", results)
