"""Shared helpers for the benchmark harness."""
import json
import os
import time
from typing import Callable, Dict, List, Tuple

import numpy as np

ROWS: List[Tuple[str, float, str]] = []

#: repo root — the machine-readable BENCH_*.json trajectory files live
#: here (top level, next to CHANGES.md) so the perf history is greppable
#: across PRs without digging through results/.
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bench_env() -> Dict[str, object]:
    """Environment metadata stamped into every BENCH_*.json: perf
    numbers are meaningless across PRs without the jax version and the
    device they ran on."""
    import jax
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "devices": [str(d) for d in jax.devices()],
        "device_count": len(jax.devices()),
    }


def write_bench_json(name: str, results: Dict[str, object]) -> str:
    """Write the top-level ``BENCH_<name>.json`` trajectory file
    (results + environment metadata).  Returns the path."""
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump({"bench": name, "env": bench_env(),
                   "results": results}, f, indent=1)
    return path


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)
