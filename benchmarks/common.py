"""Shared helpers for the benchmark harness."""
import time
from typing import Callable, List, Tuple

import numpy as np

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)
