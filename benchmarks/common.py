"""Shared helpers for the benchmark harness."""
import json
import os
import platform
import subprocess
import time
from typing import Callable, Dict, List, Tuple

import numpy as np

ROWS: List[Tuple[str, float, str]] = []

#: repo root — the machine-readable BENCH_*.json trajectory files live
#: here (top level, next to CHANGES.md) so the perf history is greppable
#: across PRs without digging through results/.
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: keys every BENCH_*.json env block must carry — write_bench_json
#: refuses to ship a file missing any of them, so the trajectory stays
#: joinable across PRs.
ENV_REQUIRED_KEYS = ("jax_version", "backend", "devices", "device_count",
                     "git_rev", "host")


def _git_rev() -> str:
    """Current commit hash (short), or "unknown" outside a git checkout
    — bench files must still write from exported tarballs."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=5)
        if out.returncode == 0:
            rev = out.stdout.strip()
            dirty = subprocess.run(
                ["git", "status", "--porcelain"], cwd=REPO_ROOT,
                capture_output=True, text=True, timeout=5)
            if dirty.returncode == 0 and dirty.stdout.strip():
                rev += "-dirty"
            return rev
    except (OSError, subprocess.TimeoutExpired):
        pass
    return "unknown"


def bench_env() -> Dict[str, object]:
    """Environment metadata stamped into every BENCH_*.json: perf
    numbers are meaningless across PRs without the jax version, the
    device they ran on, and the revision that produced them."""
    import jax
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "devices": [str(d) for d in jax.devices()],
        "device_count": len(jax.devices()),
        "git_rev": _git_rev(),
        "host": {
            "platform": platform.platform(),
            "machine": platform.machine(),
            "python": platform.python_version(),
            "node": platform.node(),
        },
    }


def write_bench_json(name: str, results: Dict[str, object]) -> str:
    """Write the top-level ``BENCH_<name>.json`` trajectory file
    (results + environment metadata).  Returns the path.

    Every bench writer routes through here, so this is the one place
    the schema is enforced: the env block must carry
    :data:`ENV_REQUIRED_KEYS` and ``results`` must be a
    JSON-serializable dict (checked by serializing before the file is
    opened — a half-written BENCH file is worse than none).
    """
    if not isinstance(results, dict):
        raise TypeError(f"results must be a dict, got {type(results).__name__}")
    env = bench_env()
    missing = [k for k in ENV_REQUIRED_KEYS if k not in env]
    if missing:
        raise ValueError(f"bench_env() missing required keys: {missing}")
    doc = {"bench": name, "env": env, "results": results}
    blob = json.dumps(doc, indent=1)     # serialize first, then write
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        f.write(blob)
    return path


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)
