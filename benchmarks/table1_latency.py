"""Paper Table 1: execution times for AlexNet and VGG-16 (batch 1).

Modeled FPGA latencies from the calibrated board model (DESIGN.md §8)
for both boards x both networks, plus a measured CPU-emulation time —
printed against the paper's published values with relative error.
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core.synthesis import CNN2Gate
from repro.models import cnn
from .common import emit

PAPER_MS = {
    ("alexnet", "ARRIA10"): 18.24,
    ("vgg16", "ARRIA10"): 205.0,
    ("alexnet", "5CSEMA5"): 153.0,
    ("vgg16", "5CSEMA5"): 4260.0,
}
OPTIONS = {"ARRIA10": (16, 32), "5CSEMA5": (8, 8)}


def run() -> None:
    gates = {"alexnet": CNN2Gate.from_graph(cnn.alexnet()),
             "vgg16": CNN2Gate.from_graph(cnn.vgg16())}
    for (net, board), paper in PAPER_MS.items():
        rep = gates[net].latency_report(board, *OPTIONS[board])
        ours = rep.total_s * 1e3
        err = (ours - paper) / paper * 100
        emit(f"table1/{net}/{board}", ours * 1e3,
             f"model={ours:.1f}ms paper={paper}ms err={err:+.0f}% "
             f"gops={rep.gops:.1f}")

    # measured emulation-mode latency (the paper's Core-i7 column role:
    # functional verification, not a throughput reference)
    g = cnn.tiny_cnn()
    gate = CNN2Gate.from_graph(g)
    x = np.random.default_rng(0).standard_normal((1, 3, 32, 32)).astype(
        np.float32)
    gate.calibrate_quantization(x)
    run_fn = gate.build("emulation")
    xj = jnp.asarray(x)
    run_fn(xj)  # warm
    t0 = time.perf_counter()
    np.asarray(run_fn(xj))
    emu = time.perf_counter() - t0
    emit("table1/emulation/tiny_cnn", emu * 1e6,
         f"emulation verify pass {emu:.2f}s (paper: 13s AlexNet on i7)")
