"""Benchmark harness: one module per paper table/figure + kernels +
roofline.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only table1,...]
"""
import argparse
import sys
import traceback

from . import (faults_bench, fig6_breakdown, inception_bench,
               kernels_bench, perf_iterations, pipeline_bench,
               resnet_bench, roofline_table, table1_latency, table2_dse,
               table3_alexnet, table4_vgg)

SUITES = {
    "faults": faults_bench,
    "inception": inception_bench,
    "table1": table1_latency,
    "table2": table2_dse,
    "table3": table3_alexnet,
    "table4": table4_vgg,
    "fig6": fig6_breakdown,
    "kernels": kernels_bench,
    "pipeline": pipeline_bench,
    "resnet": resnet_bench,
    "roofline": roofline_table,
    "perf": perf_iterations,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        try:
            SUITES[name].run()
        except Exception:  # noqa: BLE001 - report, continue, fail at end
            traceback.print_exc()
            failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
