"""Paper Fig. 6: per-stage execution-time breakdown of AlexNet."""
from repro.core.synthesis import CNN2Gate
from repro.models import cnn
from .common import emit


def run() -> None:
    gate = CNN2Gate.from_graph(cnn.alexnet())
    rep = gate.latency_report("ARRIA10", 16, 32)
    for i, lt in enumerate(rep.layers):
        bound = "mem" if lt.t_memory > lt.t_compute else "compute"
        emit(f"fig6/layer{i + 1}_{lt.kind}", lt.time_s * 1e6,
             f"{lt.name} {lt.time_s * 1e3:.3f}ms {bound}-bound "
             f"macs={lt.macs / 1e6:.0f}M")
