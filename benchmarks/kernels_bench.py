"""Pallas kernel micro-benchmarks (interpret mode on CPU: numbers are
functional-path timings, NOT TPU performance — TPU perf is projected by
the roofline; this bench guards against pathological regressions and
reports the kernels' arithmetic characteristics)."""
import numpy as np
import jax.numpy as jnp

from repro.kernels.qgemm import qgemm
from repro.kernels.qconv import qconv2d
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from .common import emit, timeit

RNG = np.random.default_rng(0)


def run() -> None:
    # int8 GEMM: the conv/FC fused unit
    m, k, n = 256, 512, 256
    x = jnp.asarray(RNG.integers(-128, 128, (m, k), np.int8))
    w = jnp.asarray(RNG.integers(-128, 128, (k, n), np.int8))
    b = jnp.zeros((n,), jnp.int32)
    us = timeit(lambda: qgemm(x, w, b, shift=8, interpret=True))
    ops = 2 * m * k * n
    emit("kernels/qgemm_256x512x256", us, f"{ops / 1e6:.0f}MOp int8")

    # fused conv+relu+pool
    xc = jnp.asarray(RNG.integers(-128, 128, (1, 32, 32, 16), np.int8))
    wc = jnp.asarray(RNG.integers(-128, 128, (3, 3, 16, 32), np.int8))
    us = timeit(lambda: qconv2d(xc, wc, None, strides=(1, 1), shift=8,
                                relu=True, pool=(2, 2), interpret=True))
    emit("kernels/qconv_32x32x16->32", us, "fused conv+relu+maxpool")

    # row-band tiled variant: same op, line-buffer-sized working set
    us = timeit(lambda: qconv2d(xc, wc, None, strides=(1, 1), shift=8,
                                relu=True, pool=(2, 2), block_h=4,
                                interpret=True))
    emit("kernels/qconv_32x32x16->32_bh4", us, "row-band block_h=4")

    # flash attention
    q = jnp.asarray(RNG.standard_normal((1, 4, 256, 64)), jnp.float32)
    kv = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    us = timeit(lambda: flash_attention(q, kv, kv, causal=True,
                                        block_q=64, block_k=64,
                                        interpret=True))
    emit("kernels/flash_attn_s256_gqa", us, "blocked online softmax")

    # ssd scan
    xs = jnp.asarray(RNG.standard_normal((1, 256, 4, 32)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (1, 256, 4)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.5, 2, (4,)), jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((1, 256, 1, 32)) * 0.3, jnp.float32)
    us = timeit(lambda: ssd_scan(xs, dt, a, bb, bb, chunk=64,
                                 interpret=True))
    emit("kernels/ssd_scan_s256", us, "chunked state-space duality")
