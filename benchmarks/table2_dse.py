"""Paper Table 2: DSE details — BF vs RL time, options found, fit."""
from repro.core.synthesis import CNN2Gate
from repro.models import cnn
from .common import emit

EVAL_COST_S = 7.0  # one vendor-compiler estimate (30 evals ~ 3.5 min)
PAPER = {"5CSEMA4": ("no fit", 2.5, 3.5), "5CSEMA5": ("(8, 8)", 2.5, 3.5),
         "ARRIA10": ("(16, 32)", 3.0, 4.0)}


def run() -> None:
    gate = CNN2Gate.from_graph(cnn.alexnet())
    for board, (paper_best, paper_rl, paper_bf) in PAPER.items():
        bf = gate.explore(board, algo="bf", eval_cost_s=EVAL_COST_S)
        rl = gate.explore(board, algo="rl", eval_cost_s=EVAL_COST_S)
        best = str(rl.best) if rl.found else "no fit"
        speedup = (1 - rl.wall_time_s / bf.wall_time_s) * 100
        emit(f"table2/{board}/bf", bf.wall_time_s * 1e6,
             f"best={bf.best} evals={bf.evaluations} "
             f"t={bf.wall_time_s / 60:.2f}min (paper {paper_bf}min)")
        emit(f"table2/{board}/rl", rl.wall_time_s * 1e6,
             f"best={best} evals={rl.evaluations} "
             f"t={rl.wall_time_s / 60:.2f}min (paper {paper_rl}min) "
             f"rl_saves={speedup:.0f}% paper_best={paper_best}")
