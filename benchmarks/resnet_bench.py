"""Residual-network executor benchmark (interpret mode on CPU).

Times the whole-network fused DAG executor (one jitted closure over the
tensor-environment interpreter) against a stagewise baseline that
re-dispatches the Python stage loop per call — the same comparison
``pipeline_bench`` makes for linear nets, here over a skip-connection
topology where the environment must keep residual operands live across
stages.  Writes before/after JSON to ``results/resnet_bench.json`` next
to ``pipeline_bench.json``.  Interpret-mode numbers are functional-path
timings, NOT TPU performance — the point is the relative cost of the
executor dataflow, which exists on every backend.
"""
import json
import os

import numpy as np
import jax.numpy as jnp

from repro.core import parser as P
from repro.core import pipeline as pipe
from repro.core.synthesis import CNN2Gate
from repro.kernels import ops
from repro.models import cnn
from .common import emit, timeit

RNG = np.random.default_rng(0)
RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "resnet_bench.json")


def _stagewise(qm: pipe.QuantizedModel, x_float: jnp.ndarray):
    """Baseline executor: the same DAG interpretation, but dispatched
    stage-by-stage from Python on every call (no whole-program jit)."""
    h = jnp.clip(jnp.round(x_float * 2.0 ** qm.input_m),
                 -128, 127).astype(jnp.int8)
    h = jnp.transpose(h, (0, 2, 3, 1))
    env = {qm.parsed.input_name: h}
    for ql in qm.layers:
        li = ql.info
        if li.kind == P.CONV:
            pool = None
            if li.pool is not None:
                pool = (li.pool.kernel_shape[0], li.pool.strides[0])
            h = ops.qconv2d_nhwc(env[li.inputs[0]], ql.w_q, ql.b_q,
                                 strides=li.strides, pads=li.pads,
                                 shift=ql.spec.requant_shift, relu=li.relu,
                                 pool=pool, groups=li.group, interpret=True)
        elif li.kind == P.POOL:
            fn = (ops.avgpool2d_nhwc if li.pool_type == "avg"
                  else ops.maxpool2d_nhwc)
            h = fn(env[li.inputs[0]], li.kernel_shape[0], li.strides[0],
                   li.pads)
        elif li.kind == P.FC:
            h = env[li.inputs[0]]
            if h.ndim > 2:
                h = h.reshape(h.shape[0], -1)
            h = ops.qgemm(h, ql.w_q, ql.b_q, shift=ql.spec.requant_shift,
                          relu=li.relu, interpret=True)
        elif li.kind == P.ADD:
            h = ops.qadd_nhwc([env[t] for t in li.inputs],
                              ql.operand_shifts,
                              shift=ql.spec.requant_shift, relu=li.relu)
        else:
            h = ops.qconcat_nhwc([env[t] for t in li.inputs],
                                 ql.operand_shifts, relu=li.relu)
        env[li.output] = h
    out = env[qm.parsed.output_name]
    return out.astype(jnp.float32) * (2.0 ** -qm.output_m)


def run() -> None:
    results = {}
    for tag, build, in_hw, batch in (
            ("resnet_tiny", cnn.resnet_tiny, 32, 2),
            ("mobilenet_tiny", cnn.mobilenet_tiny, 32, 2)):
        gate = CNN2Gate.from_graph(build(batch=batch, in_hw=in_hw))
        x = (RNG.standard_normal((batch, 3, in_hw, in_hw)) * 0.5
             ).astype(np.float32)
        gate.calibrate_quantization(x)
        xj = jnp.asarray(x)
        qm = gate.quantized

        fused = gate.build("emulation")
        us_fused = timeit(lambda: fused(xj), warmup=2, iters=9)
        emit(f"resnet/{tag}_fused", us_fused,
             "DAG interpreter under one jit")

        us_stage = timeit(lambda: _stagewise(qm, xj), warmup=2, iters=9)
        emit(f"resnet/{tag}_stagewise", us_stage,
             "per-stage Python dispatch")
        results[tag] = {
            "batch": batch, "in_hw": in_hw,
            "fused_us": us_fused, "stagewise_us": us_stage,
            "speedup": us_stage / max(us_fused, 1e-9),
        }

    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(results, f, indent=1)
