"""End-to-end int8 executor benchmark (interpret mode on CPU).

Times the whole-network fused NHWC executor against a seed-equivalent
per-layer NCHW path (transposes around every stage, Python layer loop
re-dispatched per call) on tiny_cnn, plus the fused executor alone at
AlexNet scale.  Writes before/after JSON to ``results/pipeline_bench.json``
so this and future perf PRs have a trajectory.  Interpret-mode numbers
are functional-path timings, NOT TPU performance — the point is the
relative cost of the executor dataflow (layout round-trips + per-layer
dispatch vs one fused jit), which exists on every backend.
"""
import json
import os

import numpy as np
import jax.numpy as jnp

from repro.core import parser as P
from repro.core import pipeline as pipe
from repro.core.synthesis import CNN2Gate
from repro.kernels import ops
from repro.models import cnn
from .common import emit, timeit, write_bench_json

RNG = np.random.default_rng(0)
RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "pipeline_bench.json")


def _layerwise_nchw(qm: pipe.QuantizedModel, x_float: jnp.ndarray):
    """Seed-equivalent executor: NCHW activations, per-layer transposes,
    Python dispatch on every call (the pre-row-band baseline)."""
    h = jnp.clip(jnp.round(x_float * 2.0 ** qm.input_m),
                 -128, 127).astype(jnp.int8)
    for ql in qm.layers:
        li = ql.info
        if li.kind == P.CONV:
            pool = None
            if li.pool is not None:
                pool = (li.pool.kernel_shape[0], li.pool.strides[0])
            w_oihw = jnp.transpose(ql.w_q, (3, 2, 0, 1))  # undo staging
            h = ops.qconv2d_nchw(h, w_oihw, ql.b_q, strides=li.strides,
                                 pads=li.pads, shift=ql.spec.requant_shift,
                                 relu=li.relu, pool=pool, interpret=True)
        elif li.kind == P.POOL:
            fn = (ops.avgpool2d_nchw if li.pool_type == "avg"
                  else ops.maxpool2d_nchw)
            h = fn(h, li.kernel_shape[0], li.strides[0], li.pads)
        elif li.kind == P.FC:
            if h.ndim > 2:
                h = jnp.transpose(h, (0, 2, 3, 1)).reshape(h.shape[0], -1)
            h = ops.qgemm(h, ql.w_q, ql.b_q, shift=ql.spec.requant_shift,
                          relu=li.relu, interpret=True)
    return h.astype(jnp.float32) * (2.0 ** -qm.output_m)


def run() -> None:
    results = {}

    # tiny_cnn at two operating points: 16x16/batch-2 is the
    # dispatch/layout-bound regime where the executor dataflow dominates
    # the timing; 32x32/batch-4 is emulation-compute-bound (the fused
    # win there is HBM traffic, which interpret mode cannot see).
    for tag, in_hw, batch in (("tiny_cnn_16", 16, 2), ("tiny_cnn", 32, 4)):
        gate = CNN2Gate.from_graph(cnn.tiny_cnn(batch=batch, in_hw=in_hw))
        x = (RNG.standard_normal((batch, 3, in_hw, in_hw)) * 0.5
             ).astype(np.float32)
        gate.calibrate_quantization(x)
        xj = jnp.asarray(x)
        qm = gate.quantized

        fused = gate.build("emulation")
        us_fused = timeit(lambda: fused(xj), warmup=2, iters=9)
        emit(f"pipeline/{tag}_fused", us_fused, "NHWC end-to-end, one jit")

        us_layer = timeit(lambda: _layerwise_nchw(qm, xj),
                          warmup=2, iters=9)
        emit(f"pipeline/{tag}_layerwise", us_layer,
             "seed executor: per-layer NCHW round-trips")
        results[tag] = {
            "batch": batch, "in_hw": in_hw,
            "fused_us": us_fused, "layerwise_us": us_layer,
            "speedup": us_layer / max(us_fused, 1e-9),
        }

    # -------------------------------- AlexNet-scale fused (batch 1)
    gate_a = CNN2Gate.from_graph(cnn.alexnet(channels_base=16,
                                             num_classes=100))
    xa = (RNG.standard_normal((1, 3, 224, 224)) * 0.5).astype(np.float32)
    gate_a.calibrate_quantization(xa)
    fused_a = gate_a.build("emulation", block_h=8)
    xaj = jnp.asarray(xa)
    us_a = timeit(fused_a, xaj, warmup=1, iters=3)
    emit("pipeline/alexnet16_fused_bh8", us_a,
         "row-band block_h=8, 224x224 ingress")
    results["alexnet_cb16"] = {"batch": 1, "fused_us": us_a, "block_h": 8}

    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(results, f, indent=1)
    write_bench_json("pipeline", results)
