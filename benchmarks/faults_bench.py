"""Fault-injection bench: SEU detection/recovery rates and guard
overhead on resnet_tiny (DESIGN.md §9).

Sweeps weight-bit flip counts through the guarded executor (one
calibration kit, re-deployed per trial via ``with_program``) and
reports, per flip count: detection rate, bit-exact recovery rate,
silent-corruption rate and masked-fault rate, plus the audit's runtime
overhead over the plain executor.  Emits ``BENCH_faults.json``.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import faults as F
from repro.core import pipeline as pipe
from repro.core.guard import GuardPolicy
from repro.core.synthesis import CNN2Gate
from repro.models import cnn

from .common import emit, timeit, write_bench_json

FLIP_COUNTS = (1, 2, 4, 8)
TRIALS = 3


def run() -> None:
    rng = np.random.default_rng(0)
    gate = CNN2Gate.from_graph(cnn.resnet_tiny(batch=1))
    x = (rng.standard_normal((1, 3, 32, 32)) * 0.5).astype(np.float32)
    gate.calibrate_quantization(x)
    xj = jnp.asarray(x)

    plain = pipe.make_executor(gate.quantized, interpret=True)
    audited = pipe.make_executor(gate.quantized, interpret=True, audit=True)
    clean = np.asarray(plain(xj))
    t_plain = timeit(plain, xj)
    t_audit = timeit(lambda v: audited(v)[0], xj)
    emit("faults/audit_overhead", t_audit,
         f"x{t_audit / t_plain:.2f} vs plain executor")

    kit = gate.build_guarded(x_cal=x,
                             policy=GuardPolicy(margin=0.0, sat_tol=0.0))
    t_guard_clean = timeit(lambda v: kit(v)[0], xj)
    emit("faults/guarded_clean", t_guard_clean, "no-fault guarded call")

    sweep = []
    for n_flips in FLIP_COUNTS:
        detected = recovered = silent = masked = 0
        times = []
        for trial in range(TRIALS):
            plan = F.FaultPlan.sample(gate.quantized, n_flips,
                                      kinds=(F.WEIGHT_BIT,),
                                      seed=1000 * n_flips + trial)
            gx = kit.with_program(F.inject(gate.quantized, plan))
            t0 = time.perf_counter()
            y, report = gx(xj)
            times.append(time.perf_counter() - t0)
            exact = np.array_equal(np.asarray(y), clean)
            if report.detected:
                detected += 1
                recovered += int(exact)
            elif exact:
                masked += 1      # flip never reached the output
            else:
                silent += 1      # corruption escaped the audit
        row = {
            "flips": n_flips, "trials": TRIALS,
            "detected": detected, "recovered_bit_exact": recovered,
            "masked": masked, "silent": silent,
            "mean_guarded_s": float(np.mean(times)),
        }
        sweep.append(row)
        emit(f"faults/flips{n_flips}", float(np.mean(times)) * 1e6,
             f"det {detected}/{TRIALS} rec {recovered}/{TRIALS} "
             f"silent {silent}")

    assert all(r["silent"] == 0 for r in sweep), \
        "corruption escaped the zero-slack audit"
    write_bench_json("faults", {
        "model": "resnet_tiny",
        "policy": {"margin": 0.0, "sat_tol": 0.0},
        "plain_us": t_plain,
        "audited_us": t_audit,
        "audit_overhead_x": t_audit / t_plain,
        "guarded_clean_us": t_guard_clean,
        "sweep": sweep,
    })


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
