"""Fault-injection bench: vectorized SER campaign + guard overhead on
resnet_tiny (DESIGN.md §9, §11).

Statistical soft-error study via ``core/ser.py``: ≥100 sampled
weight-bit trials per flip count batched through ONE compiled executor
(weights as vmapped call-time arguments), classified
detected/masked/silent against the golden run with Wilson 95%
confidence intervals, and recovered through the vectorized
checkpoint-replay path.  From the campaign evidence the bench derives
the selective-hardening audit set (greedy set cover over
output-reaching trials) and measures its runtime overhead next to the
full audit's — the number the ISSUE requires to land measurably below
the full-audit factor.  Emits ``BENCH_faults.json``.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline as pipe
from repro.core import ser
from repro.core.guard import GuardPolicy
from repro.core.synthesis import CNN2Gate
from repro.models import cnn

from .common import emit, timeit, write_bench_json

FLIP_COUNTS = (1, 2, 4, 8)
TRIALS = 100
CHECKPOINT_K = 2


def run() -> None:
    rng = np.random.default_rng(0)
    gate = CNN2Gate.from_graph(cnn.resnet_tiny(batch=1))
    x = (rng.standard_normal((1, 3, 32, 32)) * 0.5).astype(np.float32)
    gate.calibrate_quantization(x)
    xj = jnp.asarray(x)

    plain = pipe.make_executor(gate.quantized, interpret=True)
    audited = pipe.make_executor(gate.quantized, interpret=True, audit=True)
    t_plain = timeit(plain, xj)
    t_audit = timeit(lambda v: audited(v)[0], xj)
    emit("faults/audit_overhead", t_audit,
         f"x{t_audit / t_plain:.2f} vs plain executor (full audit)")

    kit = gate.build_guarded(x_cal=x,
                             policy=GuardPolicy(margin=0.0, sat_tol=0.0))
    t_guard_clean = timeit(lambda v: kit(v)[0], xj)
    emit("faults/guarded_clean", t_guard_clean, "no-fault guarded call")

    # ---- vectorized SER campaign, >=100 trials per flip count -------
    campaigns = []
    sweep = []
    for n_flips in FLIP_COUNTS:
        c = ser.run_campaign(gate, x, trials=TRIALS, flips=n_flips,
                             kinds=(ser.F.WEIGHT_BIT,),
                             seed=1000 * n_flips,
                             checkpoints=CHECKPOINT_K)
        campaigns.append(c)
        s = c.summary()
        sweep.append(s)
        cnt = s["counts"]
        det = s["rates"]["detected"]
        emit(f"faults/flips{n_flips}",
             float(s["mean_replayed_stages"]),
             f"det {cnt['detected']}/{c.trials} "
             f"[{det['lo']:.2f},{det['hi']:.2f}] "
             f"silent {cnt['silent']} "
             f"replay {s['mean_replayed_stages']:.1f}/{s['n_stages']}")

    assert all(s["counts"]["silent"] == 0 for s in sweep), \
        "corruption escaped the zero-slack audit"

    # ---- selective hardening: derive, then measure the overhead -----
    policy = ser.derive_guard_policy(campaigns, gate.parsed)
    sel_tensors = tuple(
        ql.info.output for ql in gate.quantized.layers
        if ql.info.name in set(policy.audit_stages))
    sel_audited = pipe.make_executor(gate.quantized, interpret=True,
                                    audit=sel_tensors)
    t_sel = timeit(lambda v: sel_audited(v)[0], xj)
    emit("faults/selective_audit", t_sel,
         f"x{t_sel / t_plain:.2f} auditing "
         f"{len(policy.audit_stages)}/{len(gate.parsed.layers)} stages "
         f"(full audit x{t_audit / t_plain:.2f})")
    assert t_sel < t_audit, \
        "selective audit must cost less than the full audit"

    write_bench_json("faults", {
        "version": ser.SCHEMA_VERSION,
        "model": "resnet_tiny",
        "policy": {"margin": 0.0, "sat_tol": 0.0},
        "trials_per_flip": TRIALS,
        "checkpoints": CHECKPOINT_K,
        "plain_us": t_plain,
        "audited_us": t_audit,
        "audit_overhead_x": t_audit / t_plain,
        "guarded_clean_us": t_guard_clean,
        "selective": {
            "audit_stages": list(policy.audit_stages),
            "audited_us": t_sel,
            "overhead_x": t_sel / t_plain,
        },
        "sweep": sweep,
    })


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
