"""Deterministic, shardable data pipeline.

Production posture: each host owns a disjoint shard of the global batch
(``host_id``/``num_hosts``), batches are derivable from ``step`` alone
(stateless resume — the checkpoint stores just the step counter), and a
double-buffered prefetch thread hides host->device transfer.

The token source here is synthetic (seeded permutation LM over a
Zipf-ish unigram mix — enough structure that training measurably
reduces loss) plus a memory-mapped binary-token file source for real
corpora.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    source: str = "synthetic"       # synthetic | mmap
    path: Optional[str] = None      # for mmap: int32 token file

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class SyntheticLM:
    """Seeded synthetic corpus: next-token = affine-permuted current
    token with occasional resets — learnable structure, zero storage."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        v = cfg.vocab_size
        rng = np.random.default_rng(cfg.seed)
        self.perm = rng.permutation(v)
        self.unigram = rng.zipf(1.5, size=v * 4) % v

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for a given global step — pure function of (seed, step,
        host_id): resume == replay."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_id))
        b, s = cfg.host_batch, cfg.seq_len
        start = self.unigram[rng.integers(0, len(self.unigram), b)]
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = start
        noise = rng.random((b, s))
        resets = self.unigram[rng.integers(0, len(self.unigram), (b, s))]
        for t in range(s):
            nxt = self.perm[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.05, resets[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class MmapTokens:
    """Memory-mapped int32 token stream, deterministic strided reads."""

    def __init__(self, cfg: DataConfig):
        assert cfg.path, "mmap source needs a path"
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")
        self.n_windows = (len(self.tokens) - 1) // cfg.seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b, s = cfg.host_batch, cfg.seq_len
        rng = np.random.default_rng((cfg.seed, step, cfg.host_id))
        idx = rng.integers(0, self.n_windows, b)
        toks = np.stack([self.tokens[i * s:i * s + s + 1] for i in idx])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def make_source(cfg: DataConfig):
    return MmapTokens(cfg) if cfg.source == "mmap" else SyntheticLM(cfg)


#: Queue marker the producer enqueues after recording a failure, so the
#: consumer wakes up and re-raises instead of blocking forever.
_SENTINEL = object()


class Prefetcher:
    """Double-buffered background prefetch keyed by step (resumable).

    A failing source must not hang training: if ``batch_at`` raises, the
    producer records the exception and enqueues a sentinel; the consumer
    drains any already-buffered good batches, then re-raises the
    producer's error as a ``RuntimeError`` (with the original chained as
    ``__cause__``) instead of blocking on an empty queue forever."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.step = start_step
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        step = self.step
        try:
            while not self._stop.is_set():
                batch = self.source.batch_at(step)
                try:
                    self.q.put((step, batch), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue
        except BaseException as e:
            self._error = e
            while not self._stop.is_set():
                try:
                    self.q.put(_SENTINEL, timeout=0.5)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            try:
                item = self.q.get(timeout=0.5)
            except queue.Empty:
                if self._error is not None:
                    raise RuntimeError(
                        "data producer failed") from self._error
                if not self.thread.is_alive():
                    raise RuntimeError("data producer thread died")
                continue
            if item is _SENTINEL:
                raise RuntimeError("data producer failed") from self._error
            return item

    def close(self):
        self._stop.set()
        # drain so the producer can observe the stop flag
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2.0)
