"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed
[arXiv:2212.04356; unverified].

Backbone only: input_specs() provides precomputed 1500-frame encoder
embeddings; the decoder backbone is exercised at the assigned sequence
lengths even though production Whisper caps decoding at 448 tokens
(see DESIGN.md arch notes)."""
import dataclasses
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        n_layers=32, encoder_layers=32, d_model=1280,
        n_heads=20, n_kv_heads=20, head_dim=64,
        d_ff=5120, vocab_size=51866, encoder_seq=1500,
        norm_type="layer", mlp_type="gelu", pos_embedding="learned",
        qkv_bias=True, attention_impl="chunked",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        encoder_seq=16, dtype="float32", attention_impl="naive")
