"""zamba2-2.7b [hybrid] — Mamba2 blocks + shared attention block
[arXiv:2411.15242; hf].

54 mamba2 layers with ONE weight-shared attention+MLP block applied
every 6 layers (zamba2's concat-with-embedding input to the shared
block is simplified to the running hidden state — DESIGN.md)."""
import dataclasses
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
        d_ff=10240, vocab_size=32000,
        ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_ngroups=1,
        ssm_chunk=256, hybrid_attn_every=6, rope_theta=1e4,
        attention_impl="chunked",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256, ssm_state=16,
        ssm_headdim=16, ssm_chunk=16, hybrid_attn_every=2,
        dtype="float32", attention_impl="naive")
