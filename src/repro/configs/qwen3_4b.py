"""qwen3-4b [dense] — qk_norm, GQA kv=8 [hf:Qwen/Qwen3-8B; hf]."""
import dataclasses
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b", family="dense",
        n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=9728, vocab_size=151936,
        qk_norm=True, rope_theta=1e6,
        attention_impl="chunked",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=160, vocab_size=256, dtype="float32",
        attention_impl="naive")
