"""llama4-scout-17b-a16e [moe] — 16 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Treated as full attention per the assigned config (the production
model's chunked-attention variant is not part of the assignment —
see DESIGN.md); therefore long_500k is skipped for this arch."""
import dataclasses
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=202048,
        n_experts=16, top_k=1, rope_theta=5e5,
        attention_impl="chunked",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=256, n_experts=4, top_k=1, capacity_factor=8.0,
        dtype="float32", attention_impl="naive")
