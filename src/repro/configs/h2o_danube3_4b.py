"""h2o-danube-3-4b [dense] — llama+mistral mix, SWA [arXiv:2401.16818]."""
import dataclasses
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense",
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
        d_ff=10240, vocab_size=32000,
        sliding_window=4096, rope_theta=1e4,
        attention_impl="chunked",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=160, vocab_size=256, sliding_window=32,
        dtype="float32", attention_impl="naive")
