"""~100M-parameter dense LM for the end-to-end training example
(deliverable b): 12L x d768, llama-style, tied embeddings (~138M with
the 32k embedding table, ~113M non-embedding)."""
import dataclasses
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="lm100m", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=3072, vocab_size=32000, tie_embeddings=True,
        rope_theta=1e4, dtype="float32", attention_impl="naive",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64,
                               n_heads=4, n_kv_heads=2, head_dim=16,
                               d_ff=128, vocab_size=512)
