"""Architecture registry: the 10 assigned archs + the paper's CNNs.

``get(name)`` returns the full assigned config; ``get_smoke(name)``
returns the reduced same-family config used by the CPU smoke tests.
"""
from __future__ import annotations

from .base import ModelConfig, ShapeConfig, ALL_SHAPES  # noqa: F401
from . import (qwen2_1_5b, qwen3_4b, qwen2_5_32b, h2o_danube3_4b,
               granite_moe_1b, llama4_scout, qwen2_vl_2b, mamba2_2_7b,
               whisper_large_v3, zamba2_2_7b, lm100m)

_MODULES = {
    "qwen2-1.5b": qwen2_1_5b,
    "qwen3-4b": qwen3_4b,
    "qwen2.5-32b": qwen2_5_32b,
    "h2o-danube-3-4b": h2o_danube3_4b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "llama4-scout-17b-a16e": llama4_scout,
    "qwen2-vl-2b": qwen2_vl_2b,
    "mamba2-2.7b": mamba2_2_7b,
    "whisper-large-v3": whisper_large_v3,
    "zamba2-2.7b": zamba2_2_7b,
}

# extra (non-assigned) configs usable via get()/get_smoke()
_EXTRAS = {"lm100m": lm100m}

ARCH_NAMES = tuple(_MODULES)


def get(name: str) -> ModelConfig:
    return {**_MODULES, **_EXTRAS}[name].config()


def get_smoke(name: str) -> ModelConfig:
    return {**_MODULES, **_EXTRAS}[name].smoke()


def supports_shape(name: str, shape: str) -> bool:
    """Shape-cell applicability (skip table in DESIGN.md)."""
    if shape != "long_500k":
        return True
    # long_500k needs sub-quadratic live state: SWA / SSM / hybrid only.
    return name in ("h2o-danube-3-4b", "mamba2-2.7b", "zamba2-2.7b")
