"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only: the vision frontend is a stub — input_specs() provides
precomputed patch embeddings (B, S, D) plus (3, B, S) M-RoPE ids."""
import dataclasses
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
        d_ff=8960, vocab_size=151936,
        qkv_bias=True, mrope=True, rope_theta=1e6, input_embeds=True,
        attention_impl="chunked",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, dtype="float32",
        attention_impl="naive")
