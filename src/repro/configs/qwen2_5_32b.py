"""qwen2.5-32b [dense] — GQA kv=8, QKV bias [hf:Qwen/Qwen2.5; hf]."""
import dataclasses
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=27648, vocab_size=152064,
        qkv_bias=True, rope_theta=1e6,
        attention_impl="chunked",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=192, vocab_size=256, dtype="float32",
        attention_impl="naive")
