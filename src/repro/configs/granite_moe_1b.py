"""granite-moe-1b-a400m [moe] — 32 experts top-8, GQA kv=8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
import dataclasses
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
        d_ff=512, vocab_size=49155,
        n_experts=32, top_k=8, rope_theta=1e4, tie_embeddings=True,
        attention_impl="chunked",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=32, vocab_size=256, n_experts=4, top_k=2, capacity_factor=8.0,
        dtype="float32", attention_impl="naive")
