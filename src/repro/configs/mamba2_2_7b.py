"""mamba2-2.7b [ssm] — SSD, attention-free [arXiv:2405.21060; unverified]."""
import dataclasses
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_ngroups=1,
        ssm_chunk=256, pos_embedding="none", tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, ssm_state=16, ssm_headdim=16,
        ssm_chunk=16, vocab_size=256, dtype="float32")
