"""Model/run configuration system.

One ``ModelConfig`` describes every architecture family in the fleet
(dense / MoE / SSM / hybrid / enc-dec / VLM); per-arch modules in this
package instantiate it with the exact assigned hyper-parameters and a
reduced ``smoke()`` variant for CPU tests.  ``ShapeConfig`` describes
the assigned input-shape cells.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0               # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab_size: int = 32000
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    mrope: bool = False             # 3-component M-RoPE (qwen2-vl)
    sliding_window: Optional[int] = None
    attention_impl: str = "naive"   # naive | chunked | flash
    attention_chunk: int = 1024
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # routing-group token bound: tokens route within groups of at most
    # this many tokens, so dispatch/combine stay linear in sequence
    # length (0 = one group per batch row, the einsum-dispatch baseline)
    moe_group_size: int = 0
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 128
    # hybrid (zamba2): one shared attention block applied every k layers
    hybrid_attn_every: int = 0
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500
    # frontend stubs: inputs are precomputed embeddings, not token ids
    input_embeds: bool = False
    # norm / mlp style
    norm_type: str = "rms"          # rms | layer
    mlp_type: str = "gated_silu"    # gated_silu | gelu
    pos_embedding: str = "rope"     # rope | learned | none
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    max_position: int = 1 << 20
    dtype: str = "bfloat16"
    # roofline dry-run: unroll inner chunk scans (attention/SSD) so XLA
    # cost_analysis counts every iteration (while bodies count once)
    scan_unroll: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, h, kv = self.hd, self.n_heads, self.n_kv_heads
        n = 0
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        mlp_dense = 3 * d * f if self.mlp_type == "gated_silu" else 2 * d * f
        if self.family in ("dense", "vlm"):
            n += self.n_layers * (attn + mlp_dense + 2 * d)
        elif self.family == "moe":
            moe = self.n_experts * 3 * d * f + d * self.n_experts
            n += self.n_layers * (attn + moe + 2 * d)
        elif self.family == "ssm":
            n += self.n_layers * self._mamba_block_params()
        elif self.family == "hybrid":
            n += self.n_layers * self._mamba_block_params()
            n += attn + mlp_dense + 2 * d  # one shared block
        elif self.family == "encdec":
            enc = self.encoder_layers * (attn + mlp_dense + 2 * d)
            dec = self.n_layers * (2 * attn + mlp_dense + 3 * d)
            n += enc + dec
        n += v * d                      # embed
        if not self.tie_embeddings:
            n += v * d                  # lm head
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_moe = self.n_experts * 3 * d * f
        active_moe = self.top_k * 3 * d * f
        return self.param_count() - self.n_layers * (dense_moe - active_moe)

    def _mamba_block_params(self) -> int:
        d, di = self.d_model, self.d_inner
        g, ns = self.ssm_ngroups, self.ssm_state
        nh = self.ssm_nheads
        in_proj = d * (2 * di + 2 * g * ns + nh)
        conv = self.ssm_conv_kernel * (di + 2 * g * ns)
        out_proj = di * d
        return in_proj + conv + out_proj + 2 * nh + di + 2 * d


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

ALL_SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}
