"""Fused int8 conv + ReLU + max-pool Pallas kernel — the flagship
"pipelined kernel" of the paper (§3.2.3, Fig. 5), adapted to TPU.

FPGA -> TPU adaptation (see DESIGN.md §2): the paper streams a
line-buffer convolution through OpenCL pipes; the TPU-native equivalent
keeps the conv -> ReLU -> requantize -> max-pool chain resident in VMEM
inside ONE kernel (fusion = pipes: the intermediate feature map never
round-trips through HBM) and expresses the convolution as kh*kw
shifted int8 matmuls on the MXU (im2col-free sliced dot products).

Parallelism parameters map onto the paper's degrees of freedom
(DESIGN.md §2 table):
  * ``N_l`` (compute lanes)      -> ``block_cout`` (output-channel tile)
  * ``N_i`` (input vector width) -> the Cin contraction width (whole Cin
    per dot here; the DSE scores VMEM pressure of both).
  * line-buffer depth            -> ``block_h`` (row-band tile)

Grid: ``(batch, H/block_h, Cout/block_cout)``, iterated with the
output-channel tile innermost.  Each step sees one **row band** of the
input — ``block_h`` output rows plus the halo the band needs (kh-1 conv
rows, and when a max-pool is fused, the pool-window carry rows, so the
fused pool stays bit-exact across band boundaries).  The band window
*overlaps* its neighbours by the halo, which a blocked BlockSpec cannot
express; the input spec therefore uses unblocked (element-offset)
indexing.  Because the input index map ignores the Cout grid axis, the
band stays resident in VMEM while the weight tiles cycle — the old
whole-plane kernel re-fetched the entire input per Cout tile.  The
int32 accumulator lives in explicit VMEM scratch, and
``dimension_semantics`` tells Mosaic the batch/band axes are parallel
so it double-buffers the next band's DMA behind the current band's
matmuls.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INT8_MIN, INT8_MAX = -128, 127


def _qconv_band_kernel(
    x_ref,    # (1, band_in_rows, Wp, Cin) int8 — overlapping halo band
    w_ref,    # (KH, KW, Cin, bco) int8
    b_ref,    # (1, bco) int32
    o_ref,    # (1, block_h, Wo', bco) int8 (post-pool if fused)
    acc_ref,  # VMEM scratch: (conv_rows * wo, bco) int32
    *,
    strides: Tuple[int, int],
    conv_hw: Tuple[int, int],   # conv rows/cols produced by this band
    shift: int,
    relu: bool,
    pool: Optional[Tuple[int, int]],
):
    x = x_ref[0]                      # (band_in_rows, Wp, Cin)
    kh, kw = w_ref.shape[0], w_ref.shape[1]
    cin = x.shape[-1]
    bco = o_ref.shape[-1]
    ho, wo = conv_hw
    sh, sw = strides

    acc_ref[...] = jnp.zeros_like(acc_ref)
    for i in range(kh):              # static unroll: kh*kw MXU matmuls
        for j in range(kw):
            patch = jax.lax.slice(
                x,
                (i, j, 0),
                (i + (ho - 1) * sh + 1, j + (wo - 1) * sw + 1, cin),
                (sh, sw, 1),
            )                         # (ho, wo, cin) int8
            acc_ref[...] += jnp.dot(
                patch.reshape(ho * wo, cin),
                w_ref[i, j],
                preferred_element_type=jnp.int32,
            )

    acc = acc_ref[...] + b_ref[...].astype(jnp.int32)  # (1,bco) broadcasts
    if shift > 0:
        acc = jax.lax.shift_right_arithmetic(acc + (1 << (shift - 1)), shift)
    if relu:
        acc = jnp.maximum(acc, 0)
    y = jnp.clip(acc, INT8_MIN, INT8_MAX).astype(jnp.int8).reshape(ho, wo, bco)

    if pool is not None:
        pw, ps = pool
        pho, pwo = (ho - pw) // ps + 1, (wo - pw) // ps + 1
        pooled = jnp.full((pho, pwo, bco), INT8_MIN, jnp.int8)
        for pi in range(pw):          # static unroll over the pool window
            for pj in range(pw):
                win = jax.lax.slice(
                    y,
                    (pi, pj, 0),
                    (pi + (pho - 1) * ps + 1, pj + (pwo - 1) * ps + 1, bco),
                    (ps, ps, 1),
                )
                pooled = jnp.maximum(pooled, win)
        y = pooled

    o_ref[0] = y


def band_geometry(block_h: int, kh: int, sh: int,
                  pool: Optional[Tuple[int, int]]) -> Tuple[int, int, int]:
    """Row-band halo arithmetic shared by the kernel and the DSE
    resource model.

    For a band of ``block_h`` *final* output rows (post-pool when a pool
    is fused) returns ``(conv_rows, in_rows, in_step)``:

      conv_rows — conv output rows the band must compute
                  (= ``(block_h-1)*ps + pw`` with a fused pool: the last
                  pool window carries ``pw-ps`` rows past the stride);
      in_rows   — input rows the band must read (conv halo ``kh-1``);
      in_step   — input-row distance between consecutive band starts
                  (< in_rows: the difference is the halo overlap).
    """
    if pool is not None:
        pw, ps = pool
        conv_rows = (block_h - 1) * ps + pw
        conv_step = block_h * ps
    else:
        conv_rows = block_h
        conv_step = block_h
    in_rows = (conv_rows - 1) * sh + kh
    in_step = conv_step * sh
    return conv_rows, in_rows, in_step


def default_block_h(oh: int, wo: int) -> int:
    """Default row-band height: enough rows that each band's matmul has
    a healthy M dimension (targets >= ~1024 conv pixels per band, the
    MXU sweet spot) without approaching the whole-plane working set."""
    target_rows = max(1, -(-1024 // max(wo, 1)))
    return min(oh, target_rows, 32)


@functools.partial(
    jax.jit,
    static_argnames=("strides", "shift", "relu", "pool", "block_cout",
                     "block_h", "interpret"),
)
def qconv2d(
    x: jnp.ndarray,  # (N, Hp, Wp, Cin) int8, pre-padded (VALID conv)
    w: jnp.ndarray,  # (KH, KW, Cin, Cout) int8
    b: Optional[jnp.ndarray],  # (Cout,) int32
    *,
    strides: Tuple[int, int] = (1, 1),
    shift: int = 0,
    relu: bool = True,
    pool: Optional[Tuple[int, int]] = None,
    block_cout: int = 128,
    block_h: Optional[int] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    n, hp, wp, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, (x.shape, w.shape)
    sh, sw = strides
    ho = (hp - kh) // sh + 1
    wo = (wp - kw) // sw + 1
    if b is None:
        b = jnp.zeros((cout,), jnp.int32)

    bco = min(block_cout, _rup(cout, 128))
    coutp = _rup(cout, bco)
    wpad = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, coutp - cout)))
    bpad = jnp.pad(b, (0, coutp - cout)).reshape(1, coutp)

    if pool is not None:
        pwin, pstr = pool
        oh, ow = (ho - pwin) // pstr + 1, (wo - pwin) // pstr + 1
    else:
        oh, ow = ho, wo

    bh = min(block_h or default_block_h(oh, wo), oh)
    conv_rows, band_in_rows, in_step = band_geometry(bh, kh, sh, pool)
    n_bands = -(-oh // bh)
    ohp = n_bands * bh
    # Rows past the last valid output row read zero-padding (zero ==
    # symmetric quantization zero-point); their outputs are sliced off.
    rows_needed = (n_bands - 1) * in_step + band_in_rows
    if rows_needed > hp:
        x = jnp.pad(x, ((0, 0), (0, rows_needed - hp), (0, 0), (0, 0)))

    out = pl.pallas_call(
        functools.partial(
            _qconv_band_kernel,
            strides=strides,
            conv_hw=(conv_rows, wo),
            shift=shift,
            relu=relu,
            pool=pool,
        ),
        grid=(n, n_bands, coutp // bco),
        in_specs=[
            # Overlapping halo bands: element-offset (unblocked)
            # indexing; the map ignores `co`, so the band stays resident
            # across the Cout tiles (no per-tile input re-read).
            pl.BlockSpec((1, band_in_rows, wp, cin),
                         lambda ni, hi, co: (ni, hi * in_step, 0, 0),
                         indexing_mode=pl.unblocked),
            pl.BlockSpec((kh, kw, cin, bco), lambda ni, hi, co: (0, 0, 0, co)),
            pl.BlockSpec((1, bco), lambda ni, hi, co: (0, co)),
        ],
        out_specs=pl.BlockSpec((1, bh, ow, bco),
                               lambda ni, hi, co: (ni, hi, 0, co)),
        out_shape=jax.ShapeDtypeStruct((n, ohp, ow, coutp), jnp.int8),
        scratch_shapes=[pltpu.VMEM((conv_rows * wo, bco), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wpad, bpad)
    return out[:, :oh, :, :cout]


def vmem_bytes(hp: int, wp: int, cin: int, kh: int, kw: int, bco: int,
               ho: int, wo: int, *,
               sh: int = 1,
               sw: Optional[int] = None,
               block_h: Optional[int] = None,
               pool: Optional[Tuple[int, int]] = None) -> int:
    """Per-grid-step working-set estimate used by the DSE resource
    model: one halo row band + weight tile + int32 accumulator scratch +
    output band.  ``ho``/``wo`` are *final* output rows/cols (post-pool
    when ``pool`` is fused); ``block_h=None`` means untiled (the whole
    plane in one band — the old kernel's working set)."""
    bh = min(block_h or ho, ho)
    conv_rows, band_in_rows, _step = band_geometry(bh, kh, sh, pool)
    band_in_rows = min(band_in_rows, hp)
    conv_wo = (wp - kw) // (sw or sh) + 1 if pool is not None else wo
    return (band_in_rows * wp * cin          # x band int8
            + kh * kw * cin * bco            # w tile int8
            + 4 * conv_rows * conv_wo * bco  # acc scratch int32
            + bh * wo * bco)                 # y band int8


def _rup(x: int, mult: int) -> int:
    return -(-x // mult) * mult
