"""Fused int8 conv + ReLU + max-pool Pallas kernel — the flagship
"pipelined kernel" of the paper (§3.2.3, Fig. 5), adapted to TPU.

FPGA -> TPU adaptation (see DESIGN.md §2): the paper streams a
line-buffer convolution through OpenCL pipes; the TPU-native equivalent
keeps the conv -> ReLU -> requantize -> max-pool chain resident in VMEM
inside ONE kernel (fusion = pipes: the intermediate feature map never
round-trips through HBM) and expresses the convolution as kh*kw
shifted int8 matmuls on the MXU (im2col-free sliced dot products).

Parallelism parameters map onto the paper's degrees of freedom
(DESIGN.md §2 table):
  * ``N_l`` (compute lanes)      -> ``block_cout`` (output-channel tile)
  * ``N_i`` (input vector width) -> the Cin contraction width (whole Cin
    per dot here; the DSE scores VMEM pressure of both).
  * line-buffer depth            -> ``block_h`` (row-band tile)

Grid: ``(batch, H/block_h, Cout/block_cout)``, iterated with the
output-channel tile innermost.  Each step sees one **row band** of the
input — ``block_h`` output rows plus the halo the band needs (kh-1 conv
rows, and when a max-pool is fused, the pool-window carry rows, so the
fused pool stays bit-exact across band boundaries).  The band window
*overlaps* its neighbours by the halo, which a blocked BlockSpec cannot
express; the input spec therefore uses unblocked (element-offset)
indexing.  Because the input index map ignores the Cout grid axis, the
band stays resident in VMEM while the weight tiles cycle — the old
whole-plane kernel re-fetched the entire input per Cout tile.  The
int32 accumulator lives in explicit VMEM scratch, and
``dimension_semantics`` tells Mosaic the batch/band axes are parallel
so it double-buffers the next band's DMA behind the current band's
matmuls.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INT8_MIN, INT8_MAX = -128, 127


def _band_epilogue(
    acc,      # (conv_rows * wo, bco) int32 accumulator
    b_row,    # (1, bco) int32 bias
    conv_hw: Tuple[int, int],
    shift: int,
    relu: bool,
    pool: Optional[Tuple[int, int]],
):
    """Shared bias/requant/ReLU/max-pool tail of both band kernels —
    identical fixed-point semantics for dense and depthwise convs."""
    ho, wo = conv_hw
    bco = acc.shape[-1]
    acc = acc + b_row.astype(jnp.int32)          # (1,bco) broadcasts
    if shift > 0:
        acc = jax.lax.shift_right_arithmetic(acc + (1 << (shift - 1)), shift)
    if relu:
        acc = jnp.maximum(acc, 0)
    y = jnp.clip(acc, INT8_MIN, INT8_MAX).astype(jnp.int8).reshape(ho, wo, bco)

    if pool is not None:
        pw, ps = pool
        pho, pwo = (ho - pw) // ps + 1, (wo - pw) // ps + 1
        pooled = jnp.full((pho, pwo, bco), INT8_MIN, jnp.int8)
        for pi in range(pw):          # static unroll over the pool window
            for pj in range(pw):
                win = jax.lax.slice(
                    y,
                    (pi, pj, 0),
                    (pi + (pho - 1) * ps + 1, pj + (pwo - 1) * ps + 1, bco),
                    (ps, ps, 1),
                )
                pooled = jnp.maximum(pooled, win)
        y = pooled
    return y


def _qconv_band_kernel(
    x_ref,    # (1, band_in_rows, Wp, Cin) int8 — overlapping halo band
    w_ref,    # (KH, KW, Cin, bco) int8
    b_ref,    # (1, bco) int32
    o_ref,    # (1, block_h, Wo', bco) int8 (post-pool if fused)
    acc_ref,  # VMEM scratch: (conv_rows * wo, bco) int32
    *,
    strides: Tuple[int, int],
    conv_hw: Tuple[int, int],   # conv rows/cols produced by this band
    shift: int,
    relu: bool,
    pool: Optional[Tuple[int, int]],
):
    x = x_ref[0]                      # (band_in_rows, Wp, Cin)
    kh, kw = w_ref.shape[0], w_ref.shape[1]
    cin = x.shape[-1]
    ho, wo = conv_hw
    sh, sw = strides

    acc_ref[...] = jnp.zeros_like(acc_ref)
    for i in range(kh):              # static unroll: kh*kw MXU matmuls
        for j in range(kw):
            patch = jax.lax.slice(
                x,
                (i, j, 0),
                (i + (ho - 1) * sh + 1, j + (wo - 1) * sw + 1, cin),
                (sh, sw, 1),
            )                         # (ho, wo, cin) int8
            acc_ref[...] += jnp.dot(
                patch.reshape(ho * wo, cin),
                w_ref[i, j],
                preferred_element_type=jnp.int32,
            )

    o_ref[0] = _band_epilogue(acc_ref[...], b_ref[...], conv_hw,
                              shift, relu, pool)


def _qdwconv_band_kernel(
    x_ref,    # (1, band_in_rows, Wp, bc) int8 — halo band, channel tile
    w_ref,    # (KH, KW, bc) int8 — one filter tap per channel
    b_ref,    # (1, bc) int32
    o_ref,    # (1, block_h, Wo', bc) int8 (post-pool if fused)
    acc_ref,  # VMEM scratch: (conv_rows * wo, bc) int32
    *,
    strides: Tuple[int, int],
    conv_hw: Tuple[int, int],
    shift: int,
    relu: bool,
    pool: Optional[Tuple[int, int]],
):
    """Depthwise variant of the row-band kernel: each output channel is
    its own group, so the "per-group Cout tile" degenerates to a channel
    tile and the kh*kw contraction becomes VPU multiply-accumulates
    (channels ride the 128-wide lane axis; there is no cross-channel
    reduction to feed the MXU)."""
    x = x_ref[0]                      # (band_in_rows, Wp, bc)
    kh, kw = w_ref.shape[0], w_ref.shape[1]
    bc = o_ref.shape[-1]
    ho, wo = conv_hw
    sh, sw = strides

    acc_ref[...] = jnp.zeros_like(acc_ref)
    for i in range(kh):              # static unroll: kh*kw VPU FMAs
        for j in range(kw):
            patch = jax.lax.slice(
                x,
                (i, j, 0),
                (i + (ho - 1) * sh + 1, j + (wo - 1) * sw + 1, bc),
                (sh, sw, 1),
            )                         # (ho, wo, bc) int8
            acc_ref[...] += (patch.reshape(ho * wo, bc).astype(jnp.int32)
                             * w_ref[i, j].astype(jnp.int32))

    o_ref[0] = _band_epilogue(acc_ref[...], b_ref[...], conv_hw,
                              shift, relu, pool)


def band_geometry(block_h: int, kh: int, sh: int,
                  pool: Optional[Tuple[int, int]]) -> Tuple[int, int, int]:
    """Row-band halo arithmetic shared by the kernel and the DSE
    resource model.

    For a band of ``block_h`` *final* output rows (post-pool when a pool
    is fused) returns ``(conv_rows, in_rows, in_step)``:

      conv_rows — conv output rows the band must compute
                  (= ``(block_h-1)*ps + pw`` with a fused pool: the last
                  pool window carries ``pw-ps`` rows past the stride);
      in_rows   — input rows the band must read (conv halo ``kh-1``);
      in_step   — input-row distance between consecutive band starts
                  (< in_rows: the difference is the halo overlap).
    """
    if pool is not None:
        pw, ps = pool
        conv_rows = (block_h - 1) * ps + pw
        conv_step = block_h * ps
    else:
        conv_rows = block_h
        conv_step = block_h
    in_rows = (conv_rows - 1) * sh + kh
    in_step = conv_step * sh
    return conv_rows, in_rows, in_step


def default_block_h(oh: int, wo: int) -> int:
    """Default row-band height: enough rows that each band's matmul has
    a healthy M dimension (targets >= ~1024 conv pixels per band, the
    MXU sweet spot) without approaching the whole-plane working set."""
    target_rows = max(1, -(-1024 // max(wo, 1)))
    return min(oh, target_rows, 32)


@functools.partial(
    jax.jit,
    static_argnames=("strides", "shift", "relu", "pool", "block_cout",
                     "block_h", "interpret"),
)
def qconv2d(
    x: jnp.ndarray,  # (N, Hp, Wp, Cin) int8, pre-padded (VALID conv)
    w: jnp.ndarray,  # (KH, KW, Cin, Cout) int8
    b: Optional[jnp.ndarray],  # (Cout,) int32
    *,
    strides: Tuple[int, int] = (1, 1),
    shift: int = 0,
    relu: bool = True,
    pool: Optional[Tuple[int, int]] = None,
    block_cout: int = 128,
    block_h: Optional[int] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    n, hp, wp, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, (x.shape, w.shape)
    sh, sw = strides
    ho = (hp - kh) // sh + 1
    wo = (wp - kw) // sw + 1
    if b is None:
        b = jnp.zeros((cout,), jnp.int32)

    bco = min(block_cout, _rup(cout, 128))
    coutp = _rup(cout, bco)
    wpad = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, coutp - cout)))
    bpad = jnp.pad(b, (0, coutp - cout)).reshape(1, coutp)

    if pool is not None:
        pwin, pstr = pool
        oh, ow = (ho - pwin) // pstr + 1, (wo - pwin) // pstr + 1
    else:
        oh, ow = ho, wo

    bh = min(block_h or default_block_h(oh, wo), oh)
    conv_rows, band_in_rows, in_step = band_geometry(bh, kh, sh, pool)
    n_bands = -(-oh // bh)
    ohp = n_bands * bh
    # Rows past the last valid output row read zero-padding (zero ==
    # symmetric quantization zero-point); their outputs are sliced off.
    rows_needed = (n_bands - 1) * in_step + band_in_rows
    if rows_needed > hp:
        x = jnp.pad(x, ((0, 0), (0, rows_needed - hp), (0, 0), (0, 0)))

    out = pl.pallas_call(
        functools.partial(
            _qconv_band_kernel,
            strides=strides,
            conv_hw=(conv_rows, wo),
            shift=shift,
            relu=relu,
            pool=pool,
        ),
        grid=(n, n_bands, coutp // bco),
        in_specs=[
            # Overlapping halo bands: element-offset (unblocked)
            # indexing; the map ignores `co`, so the band stays resident
            # across the Cout tiles (no per-tile input re-read).
            pl.BlockSpec((1, band_in_rows, wp, cin),
                         lambda ni, hi, co: (ni, hi * in_step, 0, 0),
                         indexing_mode=pl.unblocked),
            pl.BlockSpec((kh, kw, cin, bco), lambda ni, hi, co: (0, 0, 0, co)),
            pl.BlockSpec((1, bco), lambda ni, hi, co: (0, co)),
        ],
        out_specs=pl.BlockSpec((1, bh, ow, bco),
                               lambda ni, hi, co: (ni, hi, 0, co)),
        out_shape=jax.ShapeDtypeStruct((n, ohp, ow, coutp), jnp.int8),
        scratch_shapes=[pltpu.VMEM((conv_rows * wo, bco), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wpad, bpad)
    return out[:, :oh, :, :cout]


@functools.partial(
    jax.jit,
    static_argnames=("strides", "shift", "relu", "pool", "block_c",
                     "block_h", "interpret"),
)
def qdwconv2d(
    x: jnp.ndarray,  # (N, Hp, Wp, C) int8, pre-padded (VALID conv)
    w: jnp.ndarray,  # (KH, KW, C) int8 — one 2-D filter per channel
    b: Optional[jnp.ndarray],  # (C,) int32
    *,
    strides: Tuple[int, int] = (1, 1),
    shift: int = 0,
    relu: bool = True,
    pool: Optional[Tuple[int, int]] = None,
    block_c: int = 128,
    block_h: Optional[int] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Depthwise (group == C, multiplier 1) row-banded int8 conv with the
    same fused ReLU/requant/max-pool tail as :func:`qconv2d`.  Grid is
    ``(batch, H/block_h, C/block_c)`` — the channel tile is the
    per-group Cout tile with one channel per group."""
    n, hp, wp, c = x.shape
    kh, kw, c2 = w.shape
    assert c == c2, (x.shape, w.shape)
    sh, sw = strides
    ho = (hp - kh) // sh + 1
    wo = (wp - kw) // sw + 1
    if b is None:
        b = jnp.zeros((c,), jnp.int32)

    bc = min(block_c, _rup(c, 128))
    cp = _rup(c, bc)
    if cp > c:  # zero channels: zero weights/bias keep them inert
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cp - c)))
    wpad = jnp.pad(w, ((0, 0), (0, 0), (0, cp - c)))
    bpad = jnp.pad(b, (0, cp - c)).reshape(1, cp)

    if pool is not None:
        pwin, pstr = pool
        oh, ow = (ho - pwin) // pstr + 1, (wo - pwin) // pstr + 1
    else:
        oh, ow = ho, wo

    bh = min(block_h or default_block_h(oh, wo), oh)
    conv_rows, band_in_rows, in_step = band_geometry(bh, kh, sh, pool)
    n_bands = -(-oh // bh)
    ohp = n_bands * bh
    rows_needed = (n_bands - 1) * in_step + band_in_rows
    if rows_needed > hp:
        x = jnp.pad(x, ((0, 0), (0, rows_needed - hp), (0, 0), (0, 0)))

    out = pl.pallas_call(
        functools.partial(
            _qdwconv_band_kernel,
            strides=strides,
            conv_hw=(conv_rows, wo),
            shift=shift,
            relu=relu,
            pool=pool,
        ),
        grid=(n, n_bands, cp // bc),
        in_specs=[
            # Halo band, channel-tiled: unblocked element offsets (rows
            # overlap between bands; channels advance by whole tiles).
            pl.BlockSpec((1, band_in_rows, wp, bc),
                         lambda ni, hi, ci: (ni, hi * in_step, 0, ci * bc),
                         indexing_mode=pl.unblocked),
            pl.BlockSpec((kh, kw, bc), lambda ni, hi, ci: (0, 0, ci)),
            pl.BlockSpec((1, bc), lambda ni, hi, ci: (0, ci)),
        ],
        out_specs=pl.BlockSpec((1, bh, ow, bc),
                               lambda ni, hi, ci: (ni, hi, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((n, ohp, ow, cp), jnp.int8),
        scratch_shapes=[pltpu.VMEM((conv_rows * wo, bc), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wpad, bpad)
    return out[:, :oh, :, :c]


def vmem_bytes(hp: int, wp: int, cin: int, kh: int, kw: int, bco: int,
               ho: int, wo: int, *,
               sh: int = 1,
               sw: Optional[int] = None,
               block_h: Optional[int] = None,
               pool: Optional[Tuple[int, int]] = None) -> int:
    """Per-grid-step working-set estimate used by the DSE resource
    model: one halo row band + weight tile + int32 accumulator scratch +
    output band.  ``ho``/``wo`` are *final* output rows/cols (post-pool
    when ``pool`` is fused); ``block_h=None`` means untiled (the whole
    plane in one band — the old kernel's working set)."""
    bh = min(block_h or ho, ho)
    conv_rows, band_in_rows, _step = band_geometry(bh, kh, sh, pool)
    band_in_rows = min(band_in_rows, hp)
    conv_wo = (wp - kw) // (sw or sh) + 1 if pool is not None else wo
    return (band_in_rows * wp * cin          # x band int8
            + kh * kw * cin * bco            # w tile int8
            + 4 * conv_rows * conv_wo * bco  # acc scratch int32
            + bh * wo * bco)                 # y band int8


def dw_vmem_bytes(wp: int, c: int, kh: int, kw: int, bc: int,
                  ho: int, wo: int, *,
                  sh: int = 1,
                  sw: Optional[int] = None,
                  block_h: Optional[int] = None,
                  pool: Optional[Tuple[int, int]] = None) -> int:
    """Per-grid-step working set of the depthwise row-band kernel.  The
    input band is channel-tiled (unlike the dense kernel, which must see
    every Cin for the contraction), so ``bc`` bounds every term."""
    bh = min(block_h or ho, ho)
    conv_rows, band_in_rows, _step = band_geometry(bh, kh, sh, pool)
    conv_wo = (wp - kw) // (sw or sh) + 1 if pool is not None else wo
    bc = min(bc, c)
    return (band_in_rows * wp * bc           # x band int8 (channel tile)
            + kh * kw * bc                   # per-channel taps int8
            + 4 * conv_rows * conv_wo * bc   # acc scratch int32
            + bh * wo * bc)                  # y band int8


def _rup(x: int, mult: int) -> int:
    return -(-x // mult) * mult
