"""Fused int8 conv + ReLU + max-pool Pallas kernel — the flagship
"pipelined kernel" of the paper (§3.2.3, Fig. 5), adapted to TPU.

FPGA -> TPU adaptation (see DESIGN.md §2): the paper streams a
line-buffer convolution through OpenCL pipes; the TPU-native equivalent
keeps the conv -> ReLU -> requantize -> max-pool chain resident in VMEM
inside ONE kernel (fusion = pipes: the intermediate feature map never
round-trips through HBM) and expresses the convolution as kh*kw
shifted int8 matmuls on the MXU (im2col-free sliced dot products).

Parallelism parameters map onto the paper's degrees of freedom
(DESIGN.md §2 table):
  * ``N_l`` (compute lanes)      -> ``block_cout`` (output-channel tile)
  * ``N_i`` (input vector width) -> ``block_cin`` (input-channel
    contraction tile, ``8·N_i``: eight int8 elements per lane-vector
    word feed one MXU column — a real grid axis, not just a model knob)
  * line-buffer depth            -> ``block_h`` (row-band tile)

Grid: ``(batch, H/block_h, Cout/block_cout, Cin/block_cin)``, iterated
with the Cin contraction tile innermost.  Each step sees one **row
band** of the input — ``block_h`` output rows plus the halo the band
needs (kh-1 conv rows, and when a max-pool is fused, the pool-window
carry rows, so the fused pool stays bit-exact across band boundaries)
— restricted to one ``block_cin`` channel slice, so per-step VMEM no
longer scales with the whole Cin (wide VGG/ResNet layers fit deeper
bands).  The band window *overlaps* its neighbours by the halo, which
a blocked BlockSpec cannot express; the input spec therefore uses
unblocked (element-offset) indexing.  Because the input index map
ignores the Cout grid axis, the band slice stays resident in VMEM
while the weight tiles cycle — the old whole-plane kernel re-fetched
the entire input per Cout tile.  The int32 accumulator lives in
explicit VMEM scratch and is carried across the Cin steps
(qgemm-style ``pl.when`` init/accumulate/finish), and
``dimension_semantics`` tells Mosaic the batch/band axes are parallel
so it double-buffers the next band's DMA behind the current band's
matmuls.

Epilogue skip operand (residual-add fusion): the final Cin step may
add an int8 **skip** feature map into the band before the merge
requantization — the residual ``Add`` of a ResNet block executed
inside the conv kernel's epilogue instead of as a standalone stage
(one whole feature-map HBM write+read saved per skip connection; the
paper's §3.2.3 "never leave the pipe" argument applied to the skip
path).  The math replicates the unfused two-stage program bit-for-bit:
the conv result is requantized and *clipped to int8* first (exactly
the tensor the standalone conv stage would have produced), then both
operands are alignment-shifted in int32, added, and requantized to
the merge output scale — see ``_band_epilogue``.

Concat-epilogue output (inception-class merges, DESIGN.md §10): with
``out_buf`` the kernel writes its Cout tiles directly into a
channel-offset slice ``[out_off, out_off + Cout)`` of a shared merge
buffer instead of materializing its own tensor — the channel ``Concat``
of a GoogLeNet/SqueezeNet branch merge becomes an *output BlockSpec*,
not a copy.  The buffer rides ``input_output_aliases`` (unwritten
channels pass through untouched) and every output-side BlockSpec uses
unblocked element offsets with **clamped** index maps
(``min(i*tile, size-tile)``): a ragged final row band or Cout tile
re-computes its overlap with the previous tile — identical values, so
the revisit is benign — instead of writing padding into neighbouring
branches' channels.  The per-operand concat alignment shift and the
merge's fused ReLU run inside the epilogue (monotone per-element maps,
so they commute exactly with the fused max-pool that still runs last).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

INT8_MIN, INT8_MAX = -128, 127

#: Round-half-up arithmetic right shift (the paper's requant and the
#: merge alignment step share this primitive).  ``shift`` is a static
#: Python int (per-tensor requant) or an int32 row vector — ``(1,
#: bco)``, one count per output-channel lane — for per-channel weight
#: scales.  ONE implementation for oracle and kernels (ref.py imports
#: only jax/jnp, so no cycle): a rounding-rule change cannot drift
#: between them.
_round_shift = ref.round_shift


def _band_epilogue(
    acc,      # (conv_rows * wo, bco) int32 accumulator
    b_row,    # (1, bco) int32 bias
    conv_hw: Tuple[int, int],
    shift,                           # int | (1, bco) int32 per-lane row
    relu: bool,
    pool: Optional[Tuple[int, int]],
    skip=None,                       # (conv_rows * wo, bco) int8 or None
    skip_shifts: Tuple[int, int] = (0, 0),
    merge_shift: int = 0,
    merge_relu: bool = False,
    concat_shift: int = 0,
    concat_relu: bool = False,
):
    """Shared bias/requant/ReLU/max-pool tail of both band kernels —
    identical fixed-point semantics for dense and depthwise convs.
    With a per-channel quantized layer ``shift`` is a ``(1, bco)``
    int32 row (one count per Cout lane, staged as a kernel operand
    alongside the bias) instead of a static scalar; the merge
    alignment/requant shifts below stay scalar either way (activations
    are always per-tensor).

    With ``skip`` the tail replicates the unfused Conv→Add two-stage
    program exactly: the conv accumulator is requantized and clipped to
    int8 (the tensor the standalone conv would have written to HBM),
    then conv result and skip are alignment-shifted to the merge's
    common fixed-point position in int32, added, and requantized with
    ``merge_shift``/``merge_relu``.  A fused max-pool always runs last
    (post-merge), matching the graph order Conv→Add→(ReLU)→MaxPool.

    With ``concat_shift``/``concat_relu`` the tail additionally applies
    this operand's channel-``Concat`` alignment — exactly
    ``ops.qconcat_nhwc``'s per-operand ``clip(round_shift(x, s))`` (a
    zero shift is the identity on values already clipped to int8 range,
    so it is skipped) and the merge's fused ReLU — before the pool.
    Both maps are monotone and per-element, so running them pre-pool is
    bit-identical to pooling the concatenated tensor."""
    ho, wo = conv_hw
    bco = acc.shape[-1]
    acc = acc + b_row.astype(jnp.int32)          # (1,bco) broadcasts
    acc = _round_shift(acc, shift)
    if relu:
        acc = jnp.maximum(acc, 0)
    acc = jnp.clip(acc, INT8_MIN, INT8_MAX)      # int8 range, int32 carrier
    if skip is not None:
        a_conv, a_skip = skip_shifts
        acc = (_round_shift(acc, a_conv)
               + _round_shift(skip.astype(jnp.int32), a_skip))
        acc = _round_shift(acc, merge_shift)
        if merge_relu:
            acc = jnp.maximum(acc, 0)
        acc = jnp.clip(acc, INT8_MIN, INT8_MAX)
    if concat_shift:
        acc = jnp.clip(_round_shift(acc, concat_shift), INT8_MIN, INT8_MAX)
    if concat_relu:
        acc = jnp.maximum(acc, 0)
    y = acc.astype(jnp.int8).reshape(ho, wo, bco)

    if pool is not None:
        pw, ps = pool
        pho, pwo = (ho - pw) // ps + 1, (wo - pw) // ps + 1
        pooled = jnp.full((pho, pwo, bco), INT8_MIN, jnp.int8)
        for pi in range(pw):          # static unroll over the pool window
            for pj in range(pw):
                win = jax.lax.slice(
                    y,
                    (pi, pj, 0),
                    (pi + (pho - 1) * ps + 1, pj + (pwo - 1) * ps + 1, bco),
                    (ps, ps, 1),
                )
                pooled = jnp.maximum(pooled, win)
        y = pooled
    return y


def _qconv_band_kernel(
    x_ref,    # (1, band_in_rows, Wp, bci) int8 — halo band, Cin slice
    w_ref,    # (KH, KW, bci, bco) int8
    b_ref,    # (1, bco) int32
    *rest,    # [shift_ref (1, bco) int32,]
              # [skip_ref (1, conv_rows, Wo, bco) int8,]
              # [buf_ref (aliased merge buffer, write-only via o_ref),]
              # o_ref, acc_ref
    strides: Tuple[int, int],
    conv_hw: Tuple[int, int],   # conv rows/cols produced by this band
    cin_steps: int,
    has_shift_vec: bool,
    has_skip: bool,
    shift: int,
    relu: bool,
    pool: Optional[Tuple[int, int]],
    skip_shifts: Tuple[int, int],
    merge_shift: int,
    merge_relu: bool,
    has_out_buf: bool = False,
    concat_shift: int = 0,
    concat_relu: bool = False,
):
    rest = list(rest)
    shift_ref = rest.pop(0) if has_shift_vec else None
    skip_ref = rest.pop(0) if has_skip else None
    if has_out_buf:
        rest.pop(0)   # aliased merge buffer: never read in-kernel
    o_ref, acc_ref = rest
    x = x_ref[0]                      # (band_in_rows, Wp, bci)
    kh, kw = w_ref.shape[0], w_ref.shape[1]
    bci = x.shape[-1]
    ho, wo = conv_hw
    sh, sw = strides
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _accumulate():
        for i in range(kh):          # static unroll: kh*kw MXU matmuls
            for j in range(kw):
                patch = jax.lax.slice(
                    x,
                    (i, j, 0),
                    (i + (ho - 1) * sh + 1, j + (wo - 1) * sw + 1, bci),
                    (sh, sw, 1),
                )                     # (ho, wo, bci) int8
                acc_ref[...] += jnp.dot(
                    patch.reshape(ho * wo, bci),
                    w_ref[i, j],
                    preferred_element_type=jnp.int32,
                )

    def _finish():
        skip = (skip_ref[0].reshape(ho * wo, -1)
                if skip_ref is not None else None)
        s = shift_ref[...] if shift_ref is not None else shift
        o_ref[0] = _band_epilogue(acc_ref[...], b_ref[...], conv_hw,
                                  s, relu, pool, skip=skip,
                                  skip_shifts=skip_shifts,
                                  merge_shift=merge_shift,
                                  merge_relu=merge_relu,
                                  concat_shift=concat_shift,
                                  concat_relu=concat_relu)

    if cin_steps == 1:
        # whole-Cin contraction: straight-line, no per-step conditionals
        _init()
        _accumulate()
        _finish()
    else:
        ci = pl.program_id(3)         # Cin contraction step (innermost)
        pl.when(ci == 0)(_init)
        _accumulate()
        pl.when(ci == cin_steps - 1)(_finish)


def _qdwconv_band_kernel(
    x_ref,    # (1, band_in_rows, Wp, bc // multiplier) int8 — halo band
    w_ref,    # (KH, KW, bc) int8 — one filter tap per output channel
    b_ref,    # (1, bc) int32
    *rest,    # [shift_ref (1, bc) int32,]
              # [skip_ref (1, conv_rows, Wo, bc) int8,]
              # [buf_ref (aliased merge buffer, write-only via o_ref),]
              # o_ref, acc_ref
    strides: Tuple[int, int],
    conv_hw: Tuple[int, int],
    has_shift_vec: bool,
    has_skip: bool,
    multiplier: int,
    shift: int,
    relu: bool,
    pool: Optional[Tuple[int, int]],
    skip_shifts: Tuple[int, int],
    merge_shift: int,
    merge_relu: bool,
    has_out_buf: bool = False,
    concat_shift: int = 0,
    concat_relu: bool = False,
):
    """Depthwise variant of the row-band kernel: each output channel is
    its own group, so the "per-group Cout tile" degenerates to a channel
    tile and the kh*kw contraction becomes VPU multiply-accumulates
    (channels ride the 128-wide lane axis; there is no cross-channel
    reduction to feed the MXU).  Per-channel requant rides a
    ``(1, bc)`` int32 shift row exactly as in the dense kernel — the
    channel tile IS the lane dim, so depthwise layers (the biggest
    per-channel accuracy winners) pay one row per tile.

    With a channel ``multiplier`` m > 1 (ONNX group=Cin, Cout=m·Cin)
    the input tile holds ``bc // m`` channels and each feeds the m
    adjacent output lanes — ``jnp.repeat`` on the lane axis reproduces
    ONNX's group→output-channel order (output channel c convolves input
    channel c // m).  The channel tile is always a multiple of m, so
    every tile maps to a whole input-channel slice.  The residual-skip
    and concat-merge epilogues are identical to the dense kernel's."""
    rest = list(rest)
    shift_ref = rest.pop(0) if has_shift_vec else None
    skip_ref = rest.pop(0) if has_skip else None
    if has_out_buf:
        rest.pop(0)   # aliased merge buffer: never read in-kernel
    o_ref, acc_ref = rest
    x = x_ref[0]                      # (band_in_rows, Wp, bc // m)
    if multiplier > 1:
        x = jnp.repeat(x, multiplier, axis=-1)
    kh, kw = w_ref.shape[0], w_ref.shape[1]
    bc = o_ref.shape[-1]
    ho, wo = conv_hw
    sh, sw = strides

    acc_ref[...] = jnp.zeros_like(acc_ref)
    for i in range(kh):              # static unroll: kh*kw VPU FMAs
        for j in range(kw):
            patch = jax.lax.slice(
                x,
                (i, j, 0),
                (i + (ho - 1) * sh + 1, j + (wo - 1) * sw + 1, bc),
                (sh, sw, 1),
            )                         # (ho, wo, bc) int8
            acc_ref[...] += (patch.reshape(ho * wo, bc).astype(jnp.int32)
                             * w_ref[i, j].astype(jnp.int32))

    skip = (skip_ref[0].reshape(ho * wo, -1)
            if skip_ref is not None else None)
    s = shift_ref[...] if shift_ref is not None else shift
    o_ref[0] = _band_epilogue(acc_ref[...], b_ref[...], conv_hw,
                              s, relu, pool, skip=skip,
                              skip_shifts=skip_shifts,
                              merge_shift=merge_shift,
                              merge_relu=merge_relu,
                              concat_shift=concat_shift,
                              concat_relu=concat_relu)


def band_geometry(block_h: int, kh: int, sh: int,
                  pool: Optional[Tuple[int, int]]) -> Tuple[int, int, int]:
    """Row-band halo arithmetic shared by the kernel and the DSE
    resource model.

    For a band of ``block_h`` *final* output rows (post-pool when a pool
    is fused) returns ``(conv_rows, in_rows, in_step)``:

      conv_rows — conv output rows the band must compute
                  (= ``(block_h-1)*ps + pw`` with a fused pool: the last
                  pool window carries ``pw-ps`` rows past the stride);
      in_rows   — input rows the band must read (conv halo ``kh-1``);
      in_step   — input-row distance between consecutive band starts
                  (< in_rows: the difference is the halo overlap).
    """
    if pool is not None:
        pw, ps = pool
        conv_rows = (block_h - 1) * ps + pw
        conv_step = block_h * ps
    else:
        conv_rows = block_h
        conv_step = block_h
    in_rows = (conv_rows - 1) * sh + kh
    in_step = conv_step * sh
    return conv_rows, in_rows, in_step


def default_block_h(oh: int, wo: int) -> int:
    """Default row-band height: enough rows that each band's matmul has
    a healthy M dimension (targets >= ~1024 conv pixels per band, the
    MXU sweet spot) without approaching the whole-plane working set."""
    target_rows = max(1, -(-1024 // max(wo, 1)))
    return min(oh, target_rows, 32)


def _qconv2d_into(
    x, w, b, out_buf, *,
    strides, shift, relu, pool, block_cout, block_h, block_cin,
    skip, skip_shifts, merge_shift, merge_relu,
    out_off, concat_shift, concat_relu, interpret,
):
    """Concat-epilogue variant of the dense band call: writes the conv's
    Cout tiles into channels ``[out_off, out_off + Cout)`` of the shared
    merge buffer ``out_buf`` and returns the whole (aliased) buffer.

    The buffer has the *exact* merge geometry — no Cout or row padding
    is allowed to leak into it — so output-side tiles use **clamped**
    unblocked index maps (``min(i*tile, size-tile)``): a ragged final
    tile re-computes part of its predecessor's rows/channels with
    identical values instead of writing padding.  Unwritten channels
    (the other producers' slices) pass through untouched via
    ``input_output_aliases``."""
    n, hp, wp, cin = x.shape
    kh, kw, _cin2, cout = w.shape
    sh, sw = strides
    ho = (hp - kh) // sh + 1
    wo = (wp - kw) // sw + 1
    if b is None:
        b = jnp.zeros((cout,), jnp.int32)
    per_channel = isinstance(shift, tuple)
    if per_channel:
        assert len(shift) == cout, (len(shift), cout)

    if pool is not None:
        pwin, pstr = pool
        oh, ow = (ho - pwin) // pstr + 1, (wo - pwin) // pstr + 1
    else:
        oh, ow = ho, wo
    ps = pool[1] if pool is not None else 1
    nb, ohb, owb, c_tot = out_buf.shape
    assert (nb, ohb, owb) == (n, oh, ow), (out_buf.shape, (n, oh, ow))
    assert out_off + cout <= c_tot, (out_off, cout, c_tot)

    bco = min(block_cout, cout)
    n_co = -(-cout // bco)

    bci = min(block_cin or cin, cin)
    cinp = _rup(cin, bci)
    cin_steps = cinp // bci
    if cinp > cin:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cinp - cin)))
        w = jnp.pad(w, ((0, 0), (0, 0), (0, cinp - cin), (0, 0)))

    bh = min(block_h or default_block_h(oh, wo), oh)
    conv_rows, band_in_rows, _in_step = band_geometry(bh, kh, sh, pool)
    n_bands = -(-oh // bh)
    rows_needed = (oh - bh) * ps * sh + band_in_rows
    if rows_needed > hp:
        x = jnp.pad(x, ((0, 0), (0, rows_needed - hp), (0, 0), (0, 0)))

    def ostart(hi):          # clamped band start (final-output rows)
        return jnp.minimum(hi * bh, oh - bh)

    def cstart(co):          # clamped Cout-tile start
        return jnp.minimum(co * bco, cout - bco)

    brow = b.reshape(1, cout)
    in_specs = [
        pl.BlockSpec((1, band_in_rows, wp, bci),
                     lambda ni, hi, co, ci: (ni, ostart(hi) * ps * sh, 0,
                                             ci * bci),
                     indexing_mode=pl.unblocked),
        pl.BlockSpec((kh, kw, bci, bco),
                     lambda ni, hi, co, ci: (0, 0, ci * bci, cstart(co)),
                     indexing_mode=pl.unblocked),
        pl.BlockSpec((1, bco), lambda ni, hi, co, ci: (0, cstart(co)),
                     indexing_mode=pl.unblocked),
    ]
    operands = [x, w, brow]
    if per_channel:
        svec = jnp.asarray(shift, jnp.int32).reshape(1, cout)
        in_specs.append(
            pl.BlockSpec((1, bco), lambda ni, hi, co, ci: (0, cstart(co)),
                         indexing_mode=pl.unblocked))
        operands.append(svec)
    if skip is not None:
        assert skip.shape == (n, ho, wo, cout), (skip.shape,
                                                 (n, ho, wo, cout))
        skip_rows = (oh - bh) * ps + conv_rows
        if skip_rows > ho:
            skip = jnp.pad(skip, ((0, 0), (0, skip_rows - ho),
                                  (0, 0), (0, 0)))
        in_specs.append(
            pl.BlockSpec((1, conv_rows, wo, bco),
                         lambda ni, hi, co, ci: (ni, ostart(hi) * ps, 0,
                                                 cstart(co)),
                         indexing_mode=pl.unblocked))
        operands.append(skip)

    out_spec = pl.BlockSpec(
        (1, bh, ow, bco),
        lambda ni, hi, co, ci: (ni, ostart(hi), 0, out_off + cstart(co)),
        indexing_mode=pl.unblocked)
    in_specs.append(out_spec)        # aliased merge buffer (same tiles)
    operands.append(out_buf)

    return pl.pallas_call(
        functools.partial(
            _qconv_band_kernel,
            strides=strides,
            conv_hw=(conv_rows, wo),
            cin_steps=cin_steps,
            has_shift_vec=per_channel,
            has_skip=skip is not None,
            has_out_buf=True,
            shift=0 if per_channel else shift,
            relu=relu,
            pool=pool,
            skip_shifts=skip_shifts,
            merge_shift=merge_shift,
            merge_relu=merge_relu,
            concat_shift=concat_shift,
            concat_relu=concat_relu,
        ),
        grid=(n, n_bands, n_co, cin_steps),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_buf.shape, jnp.int8),
        scratch_shapes=[pltpu.VMEM((conv_rows * wo, bco), jnp.int32)],
        input_output_aliases={len(operands) - 1: 0},
        compiler_params=pltpu.TPUCompilerParams(
            # ragged tiles revisit rows/channels (same values), so the
            # band and Cout axes are not parallel-safe here
            dimension_semantics=("parallel", "arbitrary", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(*operands)


@functools.partial(
    jax.jit,
    static_argnames=("strides", "shift", "relu", "pool", "block_cout",
                     "block_h", "block_cin", "skip_shifts", "merge_shift",
                     "merge_relu", "out_off", "concat_shift", "concat_relu",
                     "interpret"),
)
def qconv2d(
    x: jnp.ndarray,  # (N, Hp, Wp, Cin) int8, pre-padded (VALID conv)
    w: jnp.ndarray,  # (KH, KW, Cin, Cout) int8
    b: Optional[jnp.ndarray],  # (Cout,) int32
    *,
    strides: Tuple[int, int] = (1, 1),
    shift=0,         # int | length-Cout tuple (per-channel shift vector)
    relu: bool = True,
    pool: Optional[Tuple[int, int]] = None,
    block_cout: int = 128,
    block_h: Optional[int] = None,
    block_cin: Optional[int] = None,
    skip: Optional[jnp.ndarray] = None,  # (N, Ho, Wo, Cout) int8 residual
    skip_shifts: Tuple[int, int] = (0, 0),
    merge_shift: int = 0,
    merge_relu: bool = False,
    out_buf: Optional[jnp.ndarray] = None,  # shared concat merge buffer
    out_off: int = 0,
    concat_shift: int = 0,
    concat_relu: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    """Row-banded fused int8 conv.  ``block_cin=None`` contracts the
    whole Cin per grid step (the pre-tiling behaviour); otherwise the
    contraction runs in ``block_cin``-channel slices on an extra
    (innermost) grid axis.  ``skip`` is an optional residual operand in
    the *conv output* geometry (pre-pool); see ``_band_epilogue``.

    ``shift`` as a length-Cout tuple selects the per-channel requant
    path: the counts are staged as a ``(1, Cout)`` int32 operand with a
    per-Cout-block BlockSpec (the bias row's twin) and the epilogue
    applies a per-lane round-half-up shift vector.  A scalar ``shift``
    compiles the exact pre-existing per-tensor kernel (no extra
    operand, same jaxpr).

    ``out_buf`` selects the concat-epilogue path (``_qconv2d_into``):
    the result lands in channels ``[out_off, out_off + Cout)`` of the
    shared merge buffer — after this operand's ``concat_shift``
    alignment and the merge's ``concat_relu`` — and the *whole buffer*
    is returned instead of a standalone tensor."""
    if out_buf is not None:
        return _qconv2d_into(
            x, w, b, out_buf, strides=strides, shift=shift, relu=relu,
            pool=pool, block_cout=block_cout, block_h=block_h,
            block_cin=block_cin, skip=skip, skip_shifts=skip_shifts,
            merge_shift=merge_shift, merge_relu=merge_relu,
            out_off=out_off, concat_shift=concat_shift,
            concat_relu=concat_relu, interpret=interpret)
    n, hp, wp, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, (x.shape, w.shape)
    sh, sw = strides
    ho = (hp - kh) // sh + 1
    wo = (wp - kw) // sw + 1
    if b is None:
        b = jnp.zeros((cout,), jnp.int32)

    per_channel = isinstance(shift, tuple)
    if per_channel:
        assert len(shift) == cout, (len(shift), cout)

    bco = min(block_cout, _rup(cout, 128))
    coutp = _rup(cout, bco)
    wpad = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, coutp - cout)))
    bpad = jnp.pad(b, (0, coutp - cout)).reshape(1, coutp)

    bci = min(block_cin or cin, cin)
    cinp = _rup(cin, bci)
    cin_steps = cinp // bci
    if cinp > cin:  # zero channels contribute nothing to the dot
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cinp - cin)))
        wpad = jnp.pad(wpad, ((0, 0), (0, 0), (0, cinp - cin), (0, 0)))

    if pool is not None:
        pwin, pstr = pool
        oh, ow = (ho - pwin) // pstr + 1, (wo - pwin) // pstr + 1
    else:
        oh, ow = ho, wo

    bh = min(block_h or default_block_h(oh, wo), oh)
    conv_rows, band_in_rows, in_step = band_geometry(bh, kh, sh, pool)
    n_bands = -(-oh // bh)
    ohp = n_bands * bh
    # Rows past the last valid output row read zero-padding (zero ==
    # symmetric quantization zero-point); their outputs are sliced off.
    rows_needed = (n_bands - 1) * in_step + band_in_rows
    if rows_needed > hp:
        x = jnp.pad(x, ((0, 0), (0, rows_needed - hp), (0, 0), (0, 0)))

    in_specs = [
        # Overlapping halo bands: element-offset (unblocked) indexing;
        # the map ignores `co`, so the band slice stays resident across
        # the Cout tiles (no per-tile input re-read).
        pl.BlockSpec((1, band_in_rows, wp, bci),
                     lambda ni, hi, co, ci: (ni, hi * in_step, 0, ci * bci),
                     indexing_mode=pl.unblocked),
        pl.BlockSpec((kh, kw, bci, bco),
                     lambda ni, hi, co, ci: (0, 0, ci, co)),
        pl.BlockSpec((1, bco), lambda ni, hi, co, ci: (0, co)),
    ]
    operands = [x, wpad, bpad]
    if per_channel:
        # per-lane shift counts ride next to the bias row (same
        # per-Cout-block spec; padded lanes shift by 0 and are sliced)
        svec = jnp.pad(jnp.asarray(shift, jnp.int32),
                       (0, coutp - cout)).reshape(1, coutp)
        in_specs.append(
            pl.BlockSpec((1, bco), lambda ni, hi, co, ci: (0, co)))
        operands.append(svec)
    if skip is not None:
        assert skip.shape == (n, ho, wo, cout), (skip.shape, (n, ho, wo, cout))
        # Conv-row band of the residual operand.  Bands of conv rows
        # overlap when a pool is fused (the pool-window carry), so the
        # skip spec is unblocked too; its rows step by the *conv* row
        # stride between bands (= in_step / conv stride).
        conv_step = bh * (pool[1] if pool is not None else 1)
        skip_rows = (n_bands - 1) * conv_step + conv_rows
        skip = jnp.pad(skip, ((0, 0), (0, max(0, skip_rows - ho)),
                              (0, 0), (0, coutp - cout)))
        in_specs.append(
            pl.BlockSpec((1, conv_rows, wo, bco),
                         lambda ni, hi, co, ci: (ni, hi * conv_step, 0,
                                                 co * bco),
                         indexing_mode=pl.unblocked))
        operands.append(skip)

    out = pl.pallas_call(
        functools.partial(
            _qconv_band_kernel,
            strides=strides,
            conv_hw=(conv_rows, wo),
            cin_steps=cin_steps,
            has_shift_vec=per_channel,
            has_skip=skip is not None,
            shift=0 if per_channel else shift,
            relu=relu,
            pool=pool,
            skip_shifts=skip_shifts,
            merge_shift=merge_shift,
            merge_relu=merge_relu,
        ),
        grid=(n, n_bands, coutp // bco, cin_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh, ow, bco),
                               lambda ni, hi, co, ci: (ni, hi, 0, co)),
        out_shape=jax.ShapeDtypeStruct((n, ohp, ow, coutp), jnp.int8),
        scratch_shapes=[pltpu.VMEM((conv_rows * wo, bco), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[:, :oh, :, :cout]


@functools.partial(
    jax.jit,
    static_argnames=("strides", "shift", "relu", "pool", "block_c",
                     "block_h", "skip_shifts", "merge_shift", "merge_relu",
                     "out_off", "concat_shift", "concat_relu", "interpret"),
)
def qdwconv2d(
    x: jnp.ndarray,  # (N, Hp, Wp, Cin) int8, pre-padded (VALID conv)
    w: jnp.ndarray,  # (KH, KW, Cout) int8 — one 2-D filter per out channel
    b: Optional[jnp.ndarray],  # (Cout,) int32
    *,
    strides: Tuple[int, int] = (1, 1),
    shift=0,         # int | length-Cout tuple (per-channel shift vector)
    relu: bool = True,
    pool: Optional[Tuple[int, int]] = None,
    block_c: int = 128,
    block_h: Optional[int] = None,
    skip: Optional[jnp.ndarray] = None,  # (N, Ho, Wo, Cout) int8 residual
    skip_shifts: Tuple[int, int] = (0, 0),
    merge_shift: int = 0,
    merge_relu: bool = False,
    out_buf: Optional[jnp.ndarray] = None,  # shared concat merge buffer
    out_off: int = 0,
    concat_shift: int = 0,
    concat_relu: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    """Depthwise (group == Cin, Cout = m·Cin for integer channel
    multiplier m ≥ 1) row-banded int8 conv with the same fused
    ReLU/requant/max-pool/skip/concat tail as :func:`qconv2d`.  Grid is
    ``(batch, H/block_h, Cout/block_c)`` — the channel tile is the
    per-group Cout tile; with m > 1 each tile reads the matching
    ``block_c / m`` input channels (the tile is kept a multiple of m).
    ``shift`` as a length-Cout tuple stages the per-channel shift row,
    ``skip`` fuses a residual add, and ``out_buf``/``out_off`` write the
    result into a channel-offset slice of a shared concat merge buffer,
    all exactly as in :func:`qconv2d`."""
    n, hp, wp, c_in = x.shape
    kh, kw, cout = w.shape
    assert cout % c_in == 0, (x.shape, w.shape)
    m = cout // c_in
    sh, sw = strides
    ho = (hp - kh) // sh + 1
    wo = (wp - kw) // sw + 1
    if b is None:
        b = jnp.zeros((cout,), jnp.int32)

    per_channel = isinstance(shift, tuple)
    if per_channel:
        assert len(shift) == cout, (len(shift), cout)

    if pool is not None:
        pwin, pstr = pool
        oh, ow = (ho - pwin) // pstr + 1, (wo - pwin) // pstr + 1
    else:
        oh, ow = ho, wo
    ps = pool[1] if pool is not None else 1

    bh = min(block_h or default_block_h(oh, wo), oh)
    conv_rows, band_in_rows, in_step = band_geometry(bh, kh, sh, pool)
    n_bands = -(-oh // bh)
    conv_step = bh * ps

    if out_buf is not None:
        # Concat-epilogue path: exact merge geometry, clamped tiles
        # (see _qconv2d_into for the revisit-consistency argument).
        nb, ohb, owb, c_tot = out_buf.shape
        assert (nb, ohb, owb) == (n, oh, ow), (out_buf.shape, (n, oh, ow))
        assert out_off + cout <= c_tot, (out_off, cout, c_tot)
        bc = min(block_c, cout)
        bc = max(bc - bc % m, m)     # whole input channels per tile
        n_c = -(-cout // bc)
        rows_needed = (oh - bh) * ps * sh + band_in_rows
        if rows_needed > hp:
            x = jnp.pad(x, ((0, 0), (0, rows_needed - hp), (0, 0), (0, 0)))

        def ostart(hi):
            return jnp.minimum(hi * bh, oh - bh)

        def cstart(ci):
            # m | bc and m | cout, so the clamped start stays a whole
            # input-channel boundary
            return jnp.minimum(ci * bc, cout - bc)

        brow = b.reshape(1, cout)
        in_specs = [
            pl.BlockSpec((1, band_in_rows, wp, bc // m),
                         lambda ni, hi, ci: (ni, ostart(hi) * ps * sh, 0,
                                             cstart(ci) // m),
                         indexing_mode=pl.unblocked),
            pl.BlockSpec((kh, kw, bc),
                         lambda ni, hi, ci: (0, 0, cstart(ci)),
                         indexing_mode=pl.unblocked),
            pl.BlockSpec((1, bc), lambda ni, hi, ci: (0, cstart(ci)),
                         indexing_mode=pl.unblocked),
        ]
        operands = [x, w, brow]
        if per_channel:
            svec = jnp.asarray(shift, jnp.int32).reshape(1, cout)
            in_specs.append(
                pl.BlockSpec((1, bc), lambda ni, hi, ci: (0, cstart(ci)),
                             indexing_mode=pl.unblocked))
            operands.append(svec)
        if skip is not None:
            assert skip.shape == (n, ho, wo, cout), (skip.shape,
                                                     (n, ho, wo, cout))
            skip_rows = (oh - bh) * ps + conv_rows
            if skip_rows > ho:
                skip = jnp.pad(skip, ((0, 0), (0, skip_rows - ho),
                                      (0, 0), (0, 0)))
            in_specs.append(
                pl.BlockSpec((1, conv_rows, wo, bc),
                             lambda ni, hi, ci: (ni, ostart(hi) * ps, 0,
                                                 cstart(ci)),
                             indexing_mode=pl.unblocked))
            operands.append(skip)
        out_spec = pl.BlockSpec(
            (1, bh, ow, bc),
            lambda ni, hi, ci: (ni, ostart(hi), 0, out_off + cstart(ci)),
            indexing_mode=pl.unblocked)
        in_specs.append(out_spec)
        operands.append(out_buf)
        return pl.pallas_call(
            functools.partial(
                _qdwconv_band_kernel,
                strides=strides,
                conv_hw=(conv_rows, wo),
                has_shift_vec=per_channel,
                has_skip=skip is not None,
                has_out_buf=True,
                multiplier=m,
                shift=0 if per_channel else shift,
                relu=relu,
                pool=pool,
                skip_shifts=skip_shifts,
                merge_shift=merge_shift,
                merge_relu=merge_relu,
                concat_shift=concat_shift,
                concat_relu=concat_relu,
            ),
            grid=(n, n_bands, n_c),
            in_specs=in_specs,
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(out_buf.shape, jnp.int8),
            scratch_shapes=[pltpu.VMEM((conv_rows * wo, bc), jnp.int32)],
            input_output_aliases={len(operands) - 1: 0},
            compiler_params=pltpu.TPUCompilerParams(
                dimension_semantics=("parallel", "arbitrary", "arbitrary")),
            interpret=interpret,
        )(*operands)

    bc = min(block_c, _rup(cout, 128))
    bc = max(bc - bc % m, m)         # whole input channels per tile
    cp = _rup(cout, bc)              # m | bc  =>  m | cp
    if cp > cout:  # zero channels: zero weights/bias keep them inert
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cp // m - c_in)))
    wpad = jnp.pad(w, ((0, 0), (0, 0), (0, cp - cout)))
    bpad = jnp.pad(b, (0, cp - cout)).reshape(1, cp)

    ohp = n_bands * bh
    rows_needed = (n_bands - 1) * in_step + band_in_rows
    if rows_needed > hp:
        x = jnp.pad(x, ((0, 0), (0, rows_needed - hp), (0, 0), (0, 0)))

    in_specs = [
        # Halo band, channel-tiled: unblocked element offsets (rows
        # overlap between bands; channels advance by whole tiles).
        pl.BlockSpec((1, band_in_rows, wp, bc // m),
                     lambda ni, hi, ci: (ni, hi * in_step, 0,
                                         ci * (bc // m)),
                     indexing_mode=pl.unblocked),
        pl.BlockSpec((kh, kw, bc), lambda ni, hi, ci: (0, 0, ci)),
        pl.BlockSpec((1, bc), lambda ni, hi, ci: (0, ci)),
    ]
    operands = [x, wpad, bpad]
    if per_channel:
        svec = jnp.pad(jnp.asarray(shift, jnp.int32),
                       (0, cp - cout)).reshape(1, cp)
        in_specs.append(pl.BlockSpec((1, bc), lambda ni, hi, ci: (0, ci)))
        operands.append(svec)
    if skip is not None:
        assert skip.shape == (n, ho, wo, cout), (skip.shape,
                                                 (n, ho, wo, cout))
        # Conv-row band of the residual operand (see qconv2d): bands of
        # conv rows overlap when a pool is fused, so unblocked rows
        # stepping by the conv row stride; channels pad to the tile grid.
        skip_rows = (n_bands - 1) * conv_step + conv_rows
        skip = jnp.pad(skip, ((0, 0), (0, max(0, skip_rows - ho)),
                              (0, 0), (0, cp - cout)))
        in_specs.append(
            pl.BlockSpec((1, conv_rows, wo, bc),
                         lambda ni, hi, ci: (ni, hi * conv_step, 0,
                                             ci * bc),
                         indexing_mode=pl.unblocked))
        operands.append(skip)

    out = pl.pallas_call(
        functools.partial(
            _qdwconv_band_kernel,
            strides=strides,
            conv_hw=(conv_rows, wo),
            has_shift_vec=per_channel,
            has_skip=skip is not None,
            multiplier=m,
            shift=0 if per_channel else shift,
            relu=relu,
            pool=pool,
            skip_shifts=skip_shifts,
            merge_shift=merge_shift,
            merge_relu=merge_relu,
        ),
        grid=(n, n_bands, cp // bc),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh, ow, bc),
                               lambda ni, hi, ci: (ni, hi, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((n, ohp, ow, cp), jnp.int8),
        scratch_shapes=[pltpu.VMEM((conv_rows * wo, bc), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[:, :oh, :, :cout]


@functools.partial(
    jax.jit,
    static_argnames=("groups", "strides", "shift", "relu", "pool",
                     "block_h", "interpret"),
)
def qgconv2d(
    x: jnp.ndarray,  # (N, Hp, Wp, Cin) int8, pre-padded (VALID conv)
    w: jnp.ndarray,  # (KH, KW, Cin/groups, Cout) int8
    b: Optional[jnp.ndarray],  # (Cout,) int32
    *,
    groups: int,
    strides: Tuple[int, int] = (1, 1),
    shift=0,         # int | length-Cout tuple (per-channel shift vector)
    relu: bool = True,
    pool: Optional[Tuple[int, int]] = None,
    block_h: Optional[int] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Ragged grouped conv (1 < groups < Cin, or any group count the
    dense/depthwise kernels don't cover): row-banded Pallas path that
    puts the *group* on its own grid axis.  Grid is
    ``(batch, H/block_h, groups)``; each step contracts one group's
    ``Cin/groups`` input slice against its ``Cout/groups`` filter tile —
    the dense band kernel body with a single Cin step, so the group
    tile rides the MXU exactly like a dense Cout tile.  Groups are
    disjoint in both input and output channels (blocked channel specs;
    no halo on the channel axis)."""
    n, hp, wp, cin = x.shape
    kh, kw, cin_g, cout = w.shape
    assert cin == cin_g * groups, (x.shape, w.shape, groups)
    assert cout % groups == 0, (cout, groups)
    cout_g = cout // groups
    sh, sw = strides
    ho = (hp - kh) // sh + 1
    wo = (wp - kw) // sw + 1
    if b is None:
        b = jnp.zeros((cout,), jnp.int32)

    per_channel = isinstance(shift, tuple)
    if per_channel:
        assert len(shift) == cout, (len(shift), cout)

    if pool is not None:
        pwin, pstr = pool
        oh, ow = (ho - pwin) // pstr + 1, (wo - pwin) // pstr + 1
    else:
        oh, ow = ho, wo

    bh = min(block_h or default_block_h(oh, wo), oh)
    conv_rows, band_in_rows, in_step = band_geometry(bh, kh, sh, pool)
    n_bands = -(-oh // bh)
    ohp = n_bands * bh
    rows_needed = (n_bands - 1) * in_step + band_in_rows
    if rows_needed > hp:
        x = jnp.pad(x, ((0, 0), (0, rows_needed - hp), (0, 0), (0, 0)))

    brow = b.reshape(1, cout)
    in_specs = [
        # Halo band restricted to one group's input-channel slice.
        pl.BlockSpec((1, band_in_rows, wp, cin_g),
                     lambda ni, hi, gi: (ni, hi * in_step, 0, gi * cin_g),
                     indexing_mode=pl.unblocked),
        pl.BlockSpec((kh, kw, cin_g, cout_g),
                     lambda ni, hi, gi: (0, 0, 0, gi)),
        pl.BlockSpec((1, cout_g), lambda ni, hi, gi: (0, gi)),
    ]
    operands = [x, w, brow]
    if per_channel:
        svec = jnp.asarray(shift, jnp.int32).reshape(1, cout)
        in_specs.append(
            pl.BlockSpec((1, cout_g), lambda ni, hi, gi: (0, gi)))
        operands.append(svec)

    out = pl.pallas_call(
        functools.partial(
            _qconv_band_kernel,
            strides=strides,
            conv_hw=(conv_rows, wo),
            cin_steps=1,
            has_shift_vec=per_channel,
            has_skip=False,
            shift=0 if per_channel else shift,
            relu=relu,
            pool=pool,
            skip_shifts=(0, 0),
            merge_shift=0,
            merge_relu=False,
        ),
        grid=(n, n_bands, groups),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh, ow, cout_g),
                               lambda ni, hi, gi: (ni, hi, 0, gi)),
        out_shape=jax.ShapeDtypeStruct((n, ohp, ow, cout), jnp.int8),
        scratch_shapes=[pltpu.VMEM((conv_rows * wo, cout_g), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[:, :oh, :, :]


def band_input_bytes(hp: int, wp: int, cin: int, kh: int, ho: int, *,
                     sh: int = 1,
                     block_h: Optional[int] = None,
                     pool: Optional[Tuple[int, int]] = None,
                     block_cin: Optional[int] = None) -> int:
    """int8 bytes of the input halo band one grid step holds in VMEM —
    the term the Cin contraction tile bounds (``block_cin=None`` means
    the whole-Cin contraction: the band carries every input channel)."""
    bh = min(block_h or ho, ho)
    _conv_rows, band_in_rows, _step = band_geometry(bh, kh, sh, pool)
    band_in_rows = min(band_in_rows, hp)
    return band_in_rows * wp * min(block_cin or cin, cin)


def vmem_bytes(hp: int, wp: int, cin: int, kh: int, kw: int, bco: int,
               ho: int, wo: int, *,
               sh: int = 1,
               sw: Optional[int] = None,
               block_h: Optional[int] = None,
               pool: Optional[Tuple[int, int]] = None,
               block_cin: Optional[int] = None,
               skip: bool = False,
               per_channel: bool = False) -> int:
    """Per-grid-step working-set estimate used by the DSE resource
    model: one halo row band (one Cin slice of it when ``block_cin`` is
    set) + weight tile + int32 accumulator scratch + output band, plus
    the residual skip band (``skip_vmem_bytes``) when a residual add is
    fused into the epilogue and the int32 per-lane shift row
    (``shift_vec_bytes``) when the layer is per-channel quantized.
    ``ho``/``wo`` are *final* output rows/cols (post-pool when ``pool``
    is fused); ``block_h=None`` means untiled (the whole plane in one
    band — the old kernel's working set)."""
    bh = min(block_h or ho, ho)
    conv_rows, _band_in_rows, _step = band_geometry(bh, kh, sh, pool)
    bci = min(block_cin or cin, cin)
    conv_wo = (wp - kw) // (sw or sh) + 1 if pool is not None else wo
    return (band_input_bytes(hp, wp, cin, kh, ho, sh=sh, block_h=block_h,
                             pool=pool, block_cin=block_cin)  # x band int8
            + kh * kw * bci * bco            # w tile int8
            + 4 * conv_rows * conv_wo * bco  # acc scratch int32
            + bh * wo * bco                  # y band int8
            + skip_vmem_bytes(conv_rows, conv_wo, bco, skip)
            + shift_vec_bytes(bco, per_channel))


def skip_vmem_bytes(conv_rows: int, conv_wo: int, bco: int,
                    skip: bool = True) -> int:
    """int8 bytes of the residual skip band a fused-merge grid step
    holds alongside the conv working set (conv-output geometry,
    pre-pool)."""
    return conv_rows * conv_wo * bco if skip else 0


def shift_vec_bytes(lanes: int, per_channel: bool = True) -> int:
    """int32 bytes of the per-lane requant-shift row a per-channel
    quantized grid step holds next to the bias row (the epilogue's
    shift-vector operand; zero in per-tensor mode, where the shift is
    a compile-time constant)."""
    return 4 * lanes if per_channel else 0


def dw_vmem_bytes(wp: int, c: int, kh: int, kw: int, bc: int,
                  ho: int, wo: int, *,
                  sh: int = 1,
                  sw: Optional[int] = None,
                  block_h: Optional[int] = None,
                  pool: Optional[Tuple[int, int]] = None,
                  per_channel: bool = False,
                  multiplier: int = 1,
                  skip: bool = False) -> int:
    """Per-grid-step working set of the depthwise row-band kernel.  The
    input band is channel-tiled (unlike the dense kernel, which must see
    every Cin for the contraction), so ``bc`` bounds every term
    (including the per-channel shift row in per-channel mode).  ``c`` is
    the *output* channel count; with a channel ``multiplier`` m > 1 the
    input band carries only ``bc / m`` channels (each feeds m output
    lanes in-register), and ``skip`` adds the fused residual band in
    conv-output geometry, as in :func:`vmem_bytes`."""
    bh = min(block_h or ho, ho)
    conv_rows, band_in_rows, _step = band_geometry(bh, kh, sh, pool)
    conv_wo = (wp - kw) // (sw or sh) + 1 if pool is not None else wo
    bc = min(bc, c)
    bc_in = -(-bc // multiplier)
    return (band_in_rows * wp * bc_in        # x band int8 (channel tile)
            + kh * kw * bc                   # per-channel taps int8
            + 4 * conv_rows * conv_wo * bc   # acc scratch int32
            + bh * wo * bc                   # y band int8
            + skip_vmem_bytes(conv_rows, conv_wo, bc, skip)
            + shift_vec_bytes(bc, per_channel))


def gconv_vmem_bytes(wp: int, cin_g: int, cout_g: int, kh: int, kw: int,
                     ho: int, wo: int, *,
                     sh: int = 1,
                     sw: Optional[int] = None,
                     block_h: Optional[int] = None,
                     pool: Optional[Tuple[int, int]] = None,
                     per_channel: bool = False) -> int:
    """Per-grid-step working set of the ragged grouped-conv band kernel
    (:func:`qgconv2d`): one group's input-channel slice of the halo
    band, its filter tile, the int32 accumulator, and the group's
    output band — the group axis is a grid axis, so per-step VMEM never
    scales with the group count."""
    bh = min(block_h or ho, ho)
    conv_rows, band_in_rows, _step = band_geometry(bh, kh, sh, pool)
    conv_wo = (wp - kw) // (sw or sh) + 1 if pool is not None else wo
    return (band_in_rows * wp * cin_g        # x band int8 (group slice)
            + kh * kw * cin_g * cout_g       # w tile int8
            + 4 * conv_rows * conv_wo * cout_g  # acc scratch int32
            + bh * wo * cout_g               # y band int8
            + shift_vec_bytes(cout_g, per_channel))


def _rup(x: int, mult: int) -> int:
    return -(-x // mult) * mult
