"""Fused int8 conv + ReLU + max-pool Pallas kernel — the flagship
"pipelined kernel" of the paper (§3.2.3, Fig. 5), adapted to TPU.

FPGA -> TPU adaptation (see DESIGN.md §2): the paper streams a
line-buffer convolution through OpenCL pipes; the TPU-native equivalent
keeps the conv -> ReLU -> requantize -> max-pool chain resident in VMEM
inside ONE kernel (fusion = pipes: the intermediate feature map never
round-trips through HBM) and expresses the convolution as kh*kw
shifted int8 matmuls on the MXU (im2col-free sliced dot products).

Parallelism parameters map exactly onto the paper's degrees of freedom:
  * ``N_l`` (compute lanes)      -> ``block_cout`` (output-channel tile)
  * ``N_i`` (input vector width) -> the Cin contraction width (whole Cin
    per dot here; the DSE scores VMEM pressure of both).

Grid: (batch, Cout/block_cout).  Each step loads the full (padded)
input plane (int8 HxWxCin — e.g. 224x224x64 = 3.2 MiB, comfortably
inside the ~16 MiB VMEM budget for every AlexNet/VGG layer) plus one
weight tile (KH, KW, Cin, block_cout).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT8_MIN, INT8_MAX = -128, 127


def _qconv_kernel(
    x_ref,   # (1, Hp, Wp, Cin) int8 (pre-padded)
    w_ref,   # (KH, KW, Cin, bco) int8
    b_ref,   # (1, bco) int32
    o_ref,   # (1, Ho', Wo', bco) int8 (post-pool if fused)
    *,
    strides: Tuple[int, int],
    out_hw: Tuple[int, int],
    shift: int,
    relu: bool,
    pool: Optional[Tuple[int, int]],
):
    x = x_ref[0]                      # (Hp, Wp, Cin)
    kh, kw = w_ref.shape[0], w_ref.shape[1]
    cin = x.shape[-1]
    bco = o_ref.shape[-1]
    ho, wo = out_hw
    sh, sw = strides

    acc = jnp.zeros((ho * wo, bco), jnp.int32)
    for i in range(kh):              # static unroll: kh*kw MXU matmuls
        for j in range(kw):
            patch = jax.lax.slice(
                x,
                (i, j, 0),
                (i + (ho - 1) * sh + 1, j + (wo - 1) * sw + 1, cin),
                (sh, sw, 1),
            )                         # (ho, wo, cin) int8
            acc += jnp.dot(
                patch.reshape(ho * wo, cin),
                w_ref[i, j],
                preferred_element_type=jnp.int32,
            )

    acc = acc + b_ref[...].astype(jnp.int32)  # (1,bco) broadcasts
    if shift > 0:
        acc = jax.lax.shift_right_arithmetic(acc + (1 << (shift - 1)), shift)
    if relu:
        acc = jnp.maximum(acc, 0)
    y = jnp.clip(acc, INT8_MIN, INT8_MAX).astype(jnp.int8).reshape(ho, wo, bco)

    if pool is not None:
        pw, ps = pool
        pho, pwo = (ho - pw) // ps + 1, (wo - pw) // ps + 1
        pooled = jnp.full((pho, pwo, bco), INT8_MIN, jnp.int8)
        for pi in range(pw):          # static unroll over the pool window
            for pj in range(pw):
                win = jax.lax.slice(
                    y,
                    (pi, pj, 0),
                    (pi + (pho - 1) * ps + 1, pj + (pwo - 1) * ps + 1, bco),
                    (ps, ps, 1),
                )
                pooled = jnp.maximum(pooled, win)
        y = pooled

    o_ref[0] = y


@functools.partial(
    jax.jit,
    static_argnames=("strides", "shift", "relu", "pool", "block_cout", "interpret"),
)
def qconv2d(
    x: jnp.ndarray,  # (N, Hp, Wp, Cin) int8, pre-padded (VALID conv)
    w: jnp.ndarray,  # (KH, KW, Cin, Cout) int8
    b: Optional[jnp.ndarray],  # (Cout,) int32
    *,
    strides: Tuple[int, int] = (1, 1),
    shift: int = 0,
    relu: bool = True,
    pool: Optional[Tuple[int, int]] = None,
    block_cout: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    n, hp, wp, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, (x.shape, w.shape)
    sh, sw = strides
    ho = (hp - kh) // sh + 1
    wo = (wp - kw) // sw + 1
    if b is None:
        b = jnp.zeros((cout,), jnp.int32)

    bco = min(block_cout, _rup(cout, 128))
    coutp = _rup(cout, bco)
    wpad = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, coutp - cout)))
    bpad = jnp.pad(b, (0, coutp - cout)).reshape(1, coutp)

    if pool is not None:
        pwin, pstr = pool
        oh, ow = (ho - pwin) // pstr + 1, (wo - pwin) // pstr + 1
    else:
        oh, ow = ho, wo

    out = pl.pallas_call(
        functools.partial(
            _qconv_kernel,
            strides=strides,
            out_hw=(ho, wo),
            shift=shift,
            relu=relu,
            pool=pool,
        ),
        grid=(n, coutp // bco),
        in_specs=[
            pl.BlockSpec((1, hp, wp, cin), lambda ni, co: (ni, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, bco), lambda ni, co: (0, 0, 0, co)),
            pl.BlockSpec((1, bco), lambda ni, co: (0, co)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, bco), lambda ni, co: (ni, 0, 0, co)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, coutp), jnp.int8),
        interpret=interpret,
    )(x, wpad, bpad)
    return out[..., :cout]


def vmem_bytes(hp: int, wp: int, cin: int, kh: int, kw: int, bco: int,
               ho: int, wo: int) -> int:
    """Working-set estimate used by the DSE resource model: input plane +
    weight tile + int32 accumulator + output tile."""
    return (hp * wp * cin            # x int8
            + kh * kw * cin * bco    # w int8
            + 4 * ho * wo * bco      # acc int32
            + ho * wo * bco)         # y int8


def _rup(x: int, mult: int) -> int:
    return -(-x // mult) * mult
