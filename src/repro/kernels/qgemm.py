"""int8 GEMM + bias + requantize Pallas kernel (the paper's fused
conv/fully-connected matrix unit, §3.2.3: "convolution kernel and the
fully connected kernel can be fused together as a single 3-D
matrix-matrix multiplication unit").

TPU mapping: int8 operands feed the MXU with int32 accumulation; block
shapes default to (128, 128, 128) tiles — multiples of the (32, 128)
int8 native tile — and the DSE's ``N_i``/``N_l`` map to the contraction
and output tile widths.  ``shift`` may be a length-N tuple (per-output-
channel quantized FC layers): the counts are staged as a ``(1, N)``
int32 operand sharing the bias row's BlockSpec and the epilogue
applies a per-lane round-half-up shift vector; a scalar ``shift``
compiles the exact per-tensor kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

INT8_MIN, INT8_MAX = -128, 127

#: Round-half-up shift (scalar or per-lane row) + relu + int8 clip —
#: the oracle's own implementation (ref.py imports only jax/jnp, so no
#: cycle): the kernel epilogue cannot drift from what tests pin.
_requant = ref.requant


def _qgemm_kernel(x_ref, w_ref, b_ref, *rest, k_steps: int,
                  has_shift_vec: bool, shift: int, relu: bool):
    rest = list(rest)
    s_ref = rest.pop(0) if has_shift_vec else None
    o_ref, acc_ref = rest

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        acc = acc_ref[...] + b_ref[...].astype(jnp.int32)
        s = s_ref[...] if s_ref is not None else shift
        o_ref[...] = _requant(acc, s, relu)


@functools.partial(
    jax.jit,
    static_argnames=("shift", "relu", "block_m", "block_n", "block_k", "interpret"),
)
def qgemm(
    x: jnp.ndarray,  # (M, K) int8
    w: jnp.ndarray,  # (K, N) int8
    b: Optional[jnp.ndarray],  # (N,) int32 or None
    *,
    shift,           # int | length-N tuple (per-channel shift vector)
    relu: bool = False,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Blocked int8 GEMM; shapes need not divide blocks (zero padding is
    applied and sliced off — zero is the symmetric quantization zero)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    if b is None:
        b = jnp.zeros((n,), jnp.int32)
    per_channel = isinstance(shift, tuple)
    if per_channel:
        assert len(shift) == n, (len(shift), n)
    bm, bn, bk = min(block_m, _rup(m, 8)), min(block_n, _rup(n, 128)), min(block_k, _rup(k, 128))
    mp, np_, kp = _rup(m, bm), _rup(n, bn), _rup(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n)).reshape(1, np_)
    k_steps = kp // bk
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
    ]
    operands = [xp, wp, bp]
    if per_channel:
        svec = jnp.pad(jnp.asarray(shift, jnp.int32),
                       (0, np_ - n)).reshape(1, np_)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.append(svec)
    out = pl.pallas_call(
        functools.partial(_qgemm_kernel, k_steps=k_steps,
                          has_shift_vec=per_channel,
                          shift=0 if per_channel else shift, relu=relu),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        # M/N tiles are independent; only the K walk carries the
        # accumulator — lets Mosaic double-buffer the K-tile DMAs
        # behind the current tile's matmul (the conv kernels already
        # declare this; the FC kernel was the only one missing it)
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]


def _rup(x: int, mult: int) -> int:
    return -(-x // mult) * mult
