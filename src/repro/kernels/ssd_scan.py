"""Mamba-2 SSD (state-space duality) chunked-scan Pallas kernel.

The SSD decomposition [arXiv:2405.21060] splits the linear recurrence
    S_t = exp(dt_t a) S_{t-1} + dt_t x_t B_t^T ;   y_t = S_t C_t + D x_t
into Q-length chunks: inside a chunk the output is an attention-like
masked (C B^T) matmul (MXU work); across chunks a small (P, N) state is
carried.  Grid: (batch*heads, n_chunks) with the chunk axis innermost —
the carried state lives in VMEM scratch across chunk iterations, exactly
the "deeply pipelined" structure the paper builds with OpenCL pipes
(DESIGN.md §2: fusion/scratch-carry is the TPU analogue of a FIFO).

Validated in interpret mode against ``ref.ssd_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref,    # (1, Q, P)
                dt_ref,   # (1, Q)
                a_ref,    # (1, 1)
                b_ref,    # (1, Q, N)
                c_ref,    # (1, Q, N)
                d_ref,    # (1, 1)
                y_ref,    # (1, Q, P)
                s_ref,    # scratch (P, N) f32
                *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    a = a_ref[0, 0].astype(jnp.float32)       # scalar
    bmat = b_ref[0].astype(jnp.float32)       # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)       # (Q, N)

    logdec = jnp.cumsum(dt * a)               # (Q,)  L_t
    # intra-chunk: scores[t, s] = exp(L_t - L_s) * dt_s  for s <= t
    diff = logdec[:, None] - logdec[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    diff = jnp.where(tri, diff, 0.0)          # mask before exp (overflow)
    gmat = jnp.where(tri, jnp.exp(diff) * dt[None, :], 0.0)
    scores = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32) * gmat
    y = jnp.dot(scores, x, preferred_element_type=jnp.float32)  # (Q, P)

    # inter-chunk: carried state contribution
    s_prev = s_ref[...]                       # (P, N)
    y += jnp.exp(logdec)[:, None] * jnp.dot(
        cmat, s_prev.T, preferred_element_type=jnp.float32)

    # state update: S = exp(L_Q) S_prev + sum_s exp(L_Q - L_s) dt_s x_s B_s^T
    tail = jnp.exp(logdec[-1] - logdec) * dt  # (Q,)
    s_new = jnp.exp(logdec[-1]) * s_prev + jnp.dot(
        x.T, bmat * tail[:, None], preferred_element_type=jnp.float32)
    s_ref[...] = s_new

    y += d_ref[0, 0].astype(jnp.float32) * x
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,   # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H) positive
    a: jnp.ndarray,   # (H,) negative
    b: jnp.ndarray,   # (B, L, G, N)
    c: jnp.ndarray,   # (B, L, G, N)
    d: Optional[jnp.ndarray] = None,  # (H,)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Chunked SSD forward; L must be a chunk multiple (wrapper pads)."""
    B_, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    group = H // G
    q = min(chunk, L)
    lp = _rup(L, q)
    if lp != L:
        x = jnp.pad(x, ((0, 0), (0, lp - L), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, lp - L), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, lp - L), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, lp - L), (0, 0), (0, 0)))
    if d is None:
        d = jnp.zeros((H,), jnp.float32)

    # (B, H, L, ...) layouts so the grid axis is leading
    xt = x.transpose(0, 2, 1, 3).reshape(B_ * H, lp, P)
    dtt = dt.transpose(0, 2, 1).reshape(B_ * H, lp)
    bt = b.transpose(0, 2, 1, 3).reshape(B_ * G, lp, N)
    ct = c.transpose(0, 2, 1, 3).reshape(B_ * G, lp, N)
    av = jnp.asarray(a, jnp.float32).reshape(H, 1)
    dv = jnp.asarray(d, jnp.float32).reshape(H, 1)

    def bc_index(bh, ci):
        return ((bh // H) * G + (bh % H) // group, ci, 0)

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=q),
        grid=(B_ * H, lp // q),
        in_specs=[
            pl.BlockSpec((1, q, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, q), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh % H, 0)),
            pl.BlockSpec((1, q, N), bc_index),
            pl.BlockSpec((1, q, N), bc_index),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh % H, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, P), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B_ * H, lp, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, av, bt, ct, dv)
    y = out.reshape(B_, H, lp, P).transpose(0, 2, 1, 3)
    return y[:, :L]


def _rup(x: int, mult: int) -> int:
    return -(-x // mult) * mult
