"""Blocked online-softmax (flash) attention Pallas kernel, GQA-aware.

TPU target: grid (batch*heads, q_blocks, kv_blocks) with the kv axis
innermost so the (m, l, acc) running statistics live in VMEM scratch
across kv iterations.  GQA is expressed in the K/V BlockSpec index maps
(query head h reads kv head h // group), so no repeat/materialisation
of K/V ever happens.  Causal and sliding-window masks are fused.

Validated in interpret mode against ``ref.attention_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, kv_steps: int,
                  causal: bool, window: Optional[int], q_offset: int,
                  kv_len: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0].astype(jnp.float32)              # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)            # fully-masked rows -> 0
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "scale",
                     "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, H, Sq, D)
    k: jnp.ndarray,  # (B, HKV, Skv, D)
    v: jnp.ndarray,  # (B, HKV, Skv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    scale = scale if scale is not None else d ** -0.5

    bq = min(block_q, _rup(sq, 8))
    bk = min(block_k, _rup(skv, 128))
    sqp, skvp = _rup(sq, bq), _rup(skv, bk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sqp - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skvp - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skvp - skv), (0, 0)))
    # flatten (B, H) -> grid axis; kv index map implements GQA sharing
    qf = qp.reshape(b * h, sqp, d)
    kf = kp.reshape(b * hkv, skvp, d)
    vf = vp.reshape(b * hkv, skvp, d)
    kv_steps = skvp // bk

    def kv_index(bh, qi, ki):
        return ((bh // h) * hkv + (bh % h) // group, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, block_q=bq, block_k=bk,
            kv_steps=kv_steps, causal=causal, window=window,
            q_offset=q_offset, kv_len=skv),
        grid=(b * h, sqp // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # m
            pltpu.VMEM((bq, 1), jnp.float32),   # l
            pltpu.VMEM((bq, d), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sqp, d)[:, :, :sq]


def _rup(x: int, mult: int) -> int:
    return -(-x // mult) * mult
