"""Pure-jnp oracles for every Pallas kernel in this package.

These define the *exact* semantics the kernels must reproduce
(``tests/test_kernels_*.py`` sweep shapes/dtypes and assert_allclose
against these).  All integer arithmetic follows the paper's fixed-point
rules: int8 operands, int32 accumulation, round-half-up arithmetic
right-shift requantization (shift = m_w + m_x - m_y), fused ReLU.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MIN, INT8_MAX = -128, 127


def _is_scalar_shift(shift) -> bool:
    return isinstance(shift, int) or (
        hasattr(shift, "ndim") and getattr(shift, "ndim", 1) == 0)


def round_shift(v: jnp.ndarray, shift) -> jnp.ndarray:
    """Round-half-up arithmetic right shift (no clip/relu).  ``shift``
    is a Python int (per-tensor) or an int32 vector broadcast against
    the **last axis** of ``v`` (per-output-channel lanes) — the shared
    requant primitive of every oracle and both epilogue modes."""
    if _is_scalar_shift(shift):
        if shift > 0:
            v = jax.lax.shift_right_arithmetic(
                v + (1 << (shift - 1)), shift)
        return v
    s = jnp.asarray(shift, jnp.int32)
    half = jnp.where(s > 0, jnp.left_shift(1, jnp.maximum(s - 1, 0)), 0)
    # jnp.right_shift broadcasts and is arithmetic for signed ints
    return jnp.right_shift(v + half, s)


def requant(acc: jnp.ndarray, shift, relu: bool) -> jnp.ndarray:
    """int32 accumulator -> int8: round-half-up shift, relu, clip.
    ``shift`` may be a per-lane int32 vector (per-channel quantization);
    lanes ride the last axis of ``acc``."""
    acc = round_shift(acc, shift)
    if relu:
        acc = jnp.maximum(acc, 0)
    return jnp.clip(acc, INT8_MIN, INT8_MAX).astype(jnp.int8)


def align_shift(v: jnp.ndarray, shift: int) -> jnp.ndarray:
    """Round-half-up arithmetic right shift (no clip) — the operand
    alignment step of a residual merge: an int8 operand at fixed-point
    position m is moved to position m - shift."""
    if shift > 0:
        v = jax.lax.shift_right_arithmetic(v + (1 << (shift - 1)), shift)
    return v


def qgemm_ref(
    x: jnp.ndarray,  # (M, K) int8
    w: jnp.ndarray,  # (K, N) int8
    b: Optional[jnp.ndarray],  # (N,) int32
    shift: int,
    relu: bool = False,
) -> jnp.ndarray:
    acc = jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32))
    if b is not None:
        acc = acc + b.astype(jnp.int32)[None, :]
    return requant(acc, shift, relu)


def qconv2d_ref(
    x: jnp.ndarray,  # (N, H, W, Cin) int8, already zero-padded
    w: jnp.ndarray,  # (KH, KW, Cin/groups, Cout) int8
    b: Optional[jnp.ndarray],  # (Cout,) int32
    strides: Tuple[int, int],
    shift: int,
    relu: bool = True,
    pool: Optional[Tuple[int, int]] = None,  # (window, stride)
    groups: int = 1,
) -> jnp.ndarray:
    """Fused conv+ReLU+maxpool, NHWC/HWIO, VALID padding (pad upstream).
    ``groups`` follows ONNX Conv semantics (groups == Cin == Cout is
    depthwise); the int32 accumulator is exact, so this is the
    bit-for-bit oracle for both band kernels and the grouped fallback."""
    acc = jax.lax.conv_general_dilated(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        window_strides=strides,
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    if b is not None:
        acc = acc + b.astype(jnp.int32)[None, None, None, :]
    y = requant(acc, shift, relu)
    if pool is not None:
        pw, ps = pool
        y = jax.lax.reduce_window(
            y, jnp.int8(INT8_MIN), jax.lax.max,
            (1, pw, pw, 1), (1, ps, ps, 1), "VALID")
    return y


def qadd_ref(
    xs,                      # sequence of int8 operands, same shape
    align_shifts,            # per-operand right shifts to a common scale
    shift: int,              # requant shift from the common scale to m_y
    relu: bool = False,
) -> jnp.ndarray:
    """Residual-merge oracle: align each int8 operand to the common
    fixed-point position (round-half-up right shift in int32), add, then
    requantize to the output scale.  With all shifts zero this is a pure
    saturating int8 add."""
    acc = None
    for x, s in zip(xs, align_shifts):
        v = align_shift(x.astype(jnp.int32), s)
        acc = v if acc is None else acc + v
    return requant(acc, shift, relu)


def qconcat_ref(
    xs,                      # sequence of int8 operands
    align_shifts,            # per-operand right shifts to the common scale
    axis: int = -1,
    relu: bool = False,
) -> jnp.ndarray:
    """Channel-merge oracle: align each int8 operand to the common
    fixed-point position (round-half-up right shift in int32, clipped
    back to int8 — a zero shift is the identity), concatenate, then
    apply the optional fused post-merge ReLU.  Concatenation itself
    never changes values, so this per-operand alignment is the *entire*
    fixed-point semantics of a ``Concat`` stage — and therefore exactly
    what a producer conv's concat epilogue must apply before writing
    its channel slice of the shared merge buffer."""
    aligned = [
        jnp.clip(align_shift(x.astype(jnp.int32), s),
                 INT8_MIN, INT8_MAX).astype(jnp.int8)
        if s else x
        for x, s in zip(xs, align_shifts)
    ]
    y = jnp.concatenate(aligned, axis=axis)
    if relu:
        y = jnp.maximum(y, 0)
    return y


def maxpool2d_ref(x: jnp.ndarray, window: int, stride: int) -> jnp.ndarray:
    """Standalone int8 NHWC max-pool."""
    return jax.lax.reduce_window(
        x, jnp.int8(INT8_MIN), jax.lax.max,
        (1, window, window, 1), (1, stride, stride, 1), "VALID")


def avgpool2d_ref(x: jnp.ndarray, window: int, stride: int,
                  pads: Tuple[int, int, int, int] = (0, 0, 0, 0)
                  ) -> jnp.ndarray:
    """Standalone int8 NHWC average-pool: int32 sum, round-half-up
    divide (fixed-point semantics — the scale is unchanged).  Padded
    windows divide by the real window population (the ONNX
    ``count_include_pad=0`` default): the per-window divisor is the
    number of non-pad taps, computed by pooling an all-ones plane with
    zero padding."""
    padding = ((0, 0), (pads[0], pads[2]), (pads[1], pads[3]), (0, 0))
    dims, strides = (1, window, window, 1), (1, stride, stride, 1)
    summed = jax.lax.reduce_window(
        x.astype(jnp.int32), jnp.int32(0), jax.lax.add,
        dims, strides, padding)
    if any(pads):
        counts = jax.lax.reduce_window(
            jnp.ones(x.shape[1:3], jnp.int32)[None, :, :, None],
            jnp.int32(0), jax.lax.add, dims, strides, padding)
        q = jnp.floor_divide(summed + counts // 2, counts)
    else:
        count = window * window
        q = jnp.floor_divide(summed + count // 2, count)
    return jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8)


def attention_ref(
    q: jnp.ndarray,  # (B, H, Sq, D)
    k: jnp.ndarray,  # (B, HKV, Skv, D)
    v: jnp.ndarray,  # (B, HKV, Skv, D)
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Grouped-query attention oracle.  ``q_offset`` is the absolute
    position of q[0] (for decode/prefill continuation).  ``window`` is a
    sliding-attention span: key j visible to query i iff i-window < j <= i.
    """
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    assert h % hkv == 0
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)


def ssd_ref(
    x: jnp.ndarray,   # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H)  -- positive (post-softplus)
    a: jnp.ndarray,   # (H,)       -- negative
    b: jnp.ndarray,   # (B, L, G, N)
    c: jnp.ndarray,   # (B, L, G, N)
    d: Optional[jnp.ndarray] = None,  # (H,) skip connection
    init_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential state-space-duality oracle (Mamba-2 §SSD):
        S_t = exp(dt_t a) S_{t-1} + dt_t x_t B_t^T ;  y_t = S_t C_t + D x_t
    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    B_, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    g = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = jnp.repeat(b.astype(jnp.float32), g, axis=2)  # (B,L,H,N)
    cf = jnp.repeat(c.astype(jnp.float32), g, axis=2)

    def step(s, t):
        decay = jnp.exp(dtf[:, t] * a[None, :])  # (B,H)
        contrib = jnp.einsum("bh,bhp,bhn->bhpn", dtf[:, t], xf[:, t], bf[:, t])
        s = decay[..., None, None] * s + contrib
        y = jnp.einsum("bhpn,bhn->bhp", s, cf[:, t])
        return s, y

    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((B_, H, P, N), jnp.float32))
    s_fin, ys = jax.lax.scan(step, s0, jnp.arange(L))
    y = jnp.moveaxis(ys, 0, 1)  # (B,L,H,P)
    if d is not None:
        y = y + d[None, None, :, None] * xf
    return y.astype(x.dtype), s_fin
