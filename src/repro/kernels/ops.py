"""Public jit'd wrappers for the Pallas kernels.

Handles layout conversion from the ONNX-lite world (NCHW / OIHW) to the
TPU-native layouts the kernels use (NHWC / HWIO), zero-padding for
convolution pads (zero == symmetric quantization zero-point), and the
interpret-mode switch: on this CPU container every kernel runs with
``interpret=True`` (Python-evaluated, bit-exact semantics); on a real
TPU the same calls lower to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import qconv as _qconv
from . import qgemm as _qgemm
from . import flash_attention as _flash
from . import ssd_scan as _ssd
from . import ref as ref  # re-export oracles for callers/tests


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def qgemm(x, w, b=None, *, shift: int, relu: bool = False,
          block_m: int = 128, block_n: int = 128, block_k: int = 128,
          interpret: Optional[bool] = None):
    interpret = default_interpret() if interpret is None else interpret
    return _qgemm.qgemm(x, w, b, shift=shift, relu=relu, block_m=block_m,
                        block_n=block_n, block_k=block_k, interpret=interpret)


def qconv2d_nchw(
    x: jnp.ndarray,  # (N, Cin, H, W) int8
    w: jnp.ndarray,  # (Cout, Cin, KH, KW) int8 (OIHW, ONNX layout)
    b: Optional[jnp.ndarray],
    *,
    strides: Tuple[int, int] = (1, 1),
    pads: Tuple[int, int, int, int] = (0, 0, 0, 0),
    shift: int = 0,
    relu: bool = True,
    pool: Optional[Tuple[int, int]] = None,
    block_cout: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """ONNX-layout entry point for the fused conv+ReLU+pool kernel.
    Returns NCHW int8 (post-pool when ``pool`` is given)."""
    interpret = default_interpret() if interpret is None else interpret
    xh = jnp.transpose(x, (0, 2, 3, 1))          # NHWC
    xh = jnp.pad(xh, ((0, 0), (pads[0], pads[2]), (pads[1], pads[3]), (0, 0)))
    wh = jnp.transpose(w, (2, 3, 1, 0))          # HWIO
    y = _qconv.qconv2d(xh, wh, b, strides=strides, shift=shift, relu=relu,
                       pool=pool, block_cout=block_cout, interpret=interpret)
    return jnp.transpose(y, (0, 3, 1, 2))


def maxpool2d_nchw(x: jnp.ndarray, window: int, stride: int,
                   pads: Tuple[int, int, int, int] = (0, 0, 0, 0)) -> jnp.ndarray:
    """Standalone int8 max-pool (for pools not fused behind a conv)."""
    xh = jnp.transpose(x, (0, 2, 3, 1))
    if any(pads):
        xh = jnp.pad(xh, ((0, 0), (pads[0], pads[2]), (pads[1], pads[3]), (0, 0)),
                     constant_values=ref.INT8_MIN)
    y = ref.maxpool2d_ref(xh, window, stride)
    return jnp.transpose(y, (0, 3, 1, 2))


def avgpool2d_nchw(x: jnp.ndarray, window: int, stride: int,
                   pads: Tuple[int, int, int, int] = (0, 0, 0, 0)) -> jnp.ndarray:
    """Standalone int8 average-pool (AveragePool / GlobalAveragePool)."""
    xh = jnp.transpose(x, (0, 2, 3, 1))
    if any(pads):
        xh = jnp.pad(xh, ((0, 0), (pads[0], pads[2]),
                          (pads[1], pads[3]), (0, 0)))
    y = ref.avgpool2d_ref(xh, window, stride)
    return jnp.transpose(y, (0, 3, 1, 2))


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_offset: int = 0,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    interpret = default_interpret() if interpret is None else interpret
    return _flash.flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)


def ssd_scan(x, dt, a, b, c, d=None, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    interpret = default_interpret() if interpret is None else interpret
    return _ssd.ssd_scan(x, dt, a, b, c, d, chunk=chunk, interpret=interpret)
