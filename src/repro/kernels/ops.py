"""Public jit'd wrappers for the Pallas kernels.

Two families of entry points (see DESIGN.md §3):

  * ``*_nhwc`` — TPU-native layouts (NHWC activations, HWIO weights).
    These are what the whole-network fused executor calls: activations
    stay NHWC int8 from network ingress to egress, so no per-layer
    transposes ever reach XLA.
  * ``*_nchw`` — ONNX-layout compatibility wrappers (NCHW / OIHW) that
    transpose around the NHWC paths.  Kept for direct callers and
    layout-parity tests; the executor does not use them.

The wrappers also handle zero-padding for convolution pads (zero ==
symmetric quantization zero-point; max-pool pads with INT8_MIN) and the
interpret-mode switch: on this CPU container every kernel runs with
``interpret=True`` (Python-evaluated, bit-exact semantics); on a real
TPU the same calls lower to Mosaic.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import qconv as _qconv
from . import qgemm as _qgemm
from . import flash_attention as _flash
from . import ssd_scan as _ssd
from . import ref as ref  # re-export oracles for callers/tests


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def qgemm(x, w, b=None, *, shift, relu: bool = False,
          block_m: int = 128, block_n: int = 128, block_k: int = 128,
          interpret: Optional[bool] = None):
    """``shift`` is an int (per-tensor) or a length-N tuple (per-output-
    channel weight scales — the per-lane shift vector path)."""
    interpret = default_interpret() if interpret is None else interpret
    return _qgemm.qgemm(x, w, b, shift=shift, relu=relu, block_m=block_m,
                        block_n=block_n, block_k=block_k, interpret=interpret)


# ------------------------------------------------------ NHWC-native paths

def qconv2d_nhwc(
    x: jnp.ndarray,  # (N, H, W, Cin) int8, unpadded
    w: jnp.ndarray,  # (KH, KW, Cin/groups, Cout) int8 (HWIO)
    b: Optional[jnp.ndarray],
    *,
    strides: Tuple[int, int] = (1, 1),
    pads: Tuple[int, int, int, int] = (0, 0, 0, 0),
    shift=0,
    relu: bool = True,
    pool: Optional[Tuple[int, int]] = None,
    groups: int = 1,
    block_cout: int = 128,
    block_h: Optional[int] = None,
    block_cin: Optional[int] = None,
    skip: Optional[jnp.ndarray] = None,
    skip_shifts: Tuple[int, int] = (0, 0),
    merge_shift: int = 0,
    merge_relu: bool = False,
    out_buf: Optional[jnp.ndarray] = None,
    out_off: int = 0,
    concat_shift: int = 0,
    concat_relu: bool = False,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """TPU-layout entry point for the fused conv+ReLU+pool row-band
    kernels.  Returns NHWC int8 (post-pool when ``pool`` is given).

    Dispatch on ``groups`` (ONNX Conv semantics):
      * 1 — dense row-band MXU kernel (:func:`qconv.qconv2d`);
      * Cin with integer channel multiplier (Cout = m·Cin, 1×1 filter
        slice) — depthwise row-band VPU kernel (:func:`qconv.qdwconv2d`);
      * anything else (ragged groups) — the grouped row-band kernel
        (:func:`qconv.qgconv2d`), one group per grid step.

    ``shift`` is an int (per-tensor requant) or a length-Cout tuple
    (per-output-channel weight scales: the band epilogue applies a
    per-lane shift vector — every dispatch target supports it).
    ``block_cin`` tiles the dense kernel's Cin contraction (the DSE's
    ``N_i`` axis); ``skip`` fuses a residual add into the epilogue and
    ``out_buf``/``out_off``/``concat_shift``/``concat_relu`` write the
    result into a channel slice of a shared concat merge buffer (dense
    and depthwise kernels — the parser never folds merges onto ragged
    grouped producers)."""
    interpret = default_interpret() if interpret is None else interpret
    cin = x.shape[-1]
    cout = w.shape[-1]
    if any(pads):
        x = jnp.pad(x, ((0, 0), (pads[0], pads[2]), (pads[1], pads[3]),
                        (0, 0)))
    if groups == 1:
        return _qconv.qconv2d(x, w, b, strides=strides, shift=shift,
                              relu=relu, pool=pool, block_cout=block_cout,
                              block_h=block_h, block_cin=block_cin,
                              skip=skip, skip_shifts=skip_shifts,
                              merge_shift=merge_shift, merge_relu=merge_relu,
                              out_buf=out_buf, out_off=out_off,
                              concat_shift=concat_shift,
                              concat_relu=concat_relu,
                              interpret=interpret)
    if groups == cin and cout % cin == 0 and w.shape[2] == 1:
        return _qconv.qdwconv2d(x, w.reshape(w.shape[0], w.shape[1], cout),
                                b, strides=strides, shift=shift, relu=relu,
                                pool=pool, block_c=block_cout,
                                block_h=block_h,
                                skip=skip, skip_shifts=skip_shifts,
                                merge_shift=merge_shift,
                                merge_relu=merge_relu,
                                out_buf=out_buf, out_off=out_off,
                                concat_shift=concat_shift,
                                concat_relu=concat_relu,
                                interpret=interpret)
    # ragged grouped conv: banded Pallas path, group on its own grid axis
    assert skip is None and out_buf is None, \
        "merge fusion requires the dense or depthwise band kernel"
    return _qconv.qgconv2d(x, w, b, groups=groups, strides=strides,
                           shift=shift, relu=relu, pool=pool,
                           block_h=block_h, interpret=interpret)


def qadd_nhwc(xs, align_shifts, *, shift: int = 0,
              relu: bool = False) -> jnp.ndarray:
    """Residual-merge stage: align int8 operands to a common fixed-point
    position, add in int32, requantize back to int8.  Elementwise VPU
    work with no reduction — XLA fuses it into the surrounding int8
    dataflow, so a dedicated Pallas kernel would buy nothing."""
    return ref.qadd_ref(xs, align_shifts, shift, relu)


def qconcat_nhwc(xs, align_shifts, *, axis: int = -1,
                 relu: bool = False) -> jnp.ndarray:
    """Channel-merge stage: align each int8 operand to the common scale,
    then concatenate (values are unchanged by concat, so there is no
    output requant beyond the per-operand alignment).  ``relu`` applies
    a fused post-merge ReLU (relu∘concat == concat∘relu per operand).
    Delegates to :func:`ref.qconcat_ref` — ONE definition of the merge
    semantics, shared with the producer-epilogue concat fusion."""
    return ref.qconcat_ref(xs, align_shifts, axis=axis, relu=relu)


def maxpool2d_nhwc(x: jnp.ndarray, window: int, stride: int,
                   pads: Tuple[int, int, int, int] = (0, 0, 0, 0)
                   ) -> jnp.ndarray:
    """Standalone int8-native NHWC max-pool (pools not fused behind a
    conv).  Stays in the executor's no-transpose NHWC dataflow; the
    reduction runs directly on int8 (identity = INT8_MIN)."""
    return jax.lax.reduce_window(
        x, jnp.int8(ref.INT8_MIN), jax.lax.max,
        (1, window, window, 1), (1, stride, stride, 1),
        ((0, 0), (pads[0], pads[2]), (pads[1], pads[3]), (0, 0)))


def avgpool2d_nhwc(x: jnp.ndarray, window: int, stride: int,
                   pads: Tuple[int, int, int, int] = (0, 0, 0, 0)
                   ) -> jnp.ndarray:
    """Standalone int8-native NHWC average-pool (AveragePool /
    GlobalAveragePool): int32 window sum, round-half-up divide — the
    fixed-point scale is unchanged, so the result feeds the next int8
    stage directly.

    Padded windows divide by the **real** window population (the ONNX
    ``count_include_pad=0`` default), not by ``window*window`` — a
    border window that covers only 4 of 9 taps averages those 4, so pad
    pixels never drag the mean toward zero."""
    return ref.avgpool2d_ref(x, window, stride, pads)


# -------------------------------------- ONNX-layout (NCHW) compatibility

def qconv2d_nchw(
    x: jnp.ndarray,  # (N, Cin, H, W) int8
    w: jnp.ndarray,  # (Cout, Cin, KH, KW) int8 (OIHW, ONNX layout)
    b: Optional[jnp.ndarray],
    *,
    strides: Tuple[int, int] = (1, 1),
    pads: Tuple[int, int, int, int] = (0, 0, 0, 0),
    shift: int = 0,
    relu: bool = True,
    pool: Optional[Tuple[int, int]] = None,
    block_cout: int = 128,
    block_h: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """ONNX-layout wrapper around :func:`qconv2d_nhwc`.  Returns NCHW
    int8 (post-pool when ``pool`` is given)."""
    xh = jnp.transpose(x, (0, 2, 3, 1))          # NHWC
    wh = jnp.transpose(w, (2, 3, 1, 0))          # HWIO
    y = qconv2d_nhwc(xh, wh, b, strides=strides, pads=pads, shift=shift,
                     relu=relu, pool=pool, block_cout=block_cout,
                     block_h=block_h, interpret=interpret)
    return jnp.transpose(y, (0, 3, 1, 2))


def maxpool2d_nchw(x: jnp.ndarray, window: int, stride: int,
                   pads: Tuple[int, int, int, int] = (0, 0, 0, 0)) -> jnp.ndarray:
    """ONNX-layout wrapper around :func:`maxpool2d_nhwc`."""
    xh = jnp.transpose(x, (0, 2, 3, 1))
    return jnp.transpose(maxpool2d_nhwc(xh, window, stride, pads),
                         (0, 3, 1, 2))


def avgpool2d_nchw(x: jnp.ndarray, window: int, stride: int,
                   pads: Tuple[int, int, int, int] = (0, 0, 0, 0)) -> jnp.ndarray:
    """ONNX-layout wrapper around :func:`avgpool2d_nhwc`."""
    xh = jnp.transpose(x, (0, 2, 3, 1))
    return jnp.transpose(avgpool2d_nhwc(xh, window, stride, pads),
                         (0, 3, 1, 2))


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_offset: int = 0,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    interpret = default_interpret() if interpret is None else interpret
    return _flash.flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)


def ssd_scan(x, dt, a, b, c, d=None, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    interpret = default_interpret() if interpret is None else interpret
    return _ssd.ssd_scan(x, dt, a, b, c, d, chunk=chunk, interpret=interpret)
