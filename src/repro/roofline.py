"""Roofline analysis from compiled XLA artifacts (no hardware needed).

Three terms per (arch × shape × mesh) cell, all in seconds-per-step on
TPU v5e constants (197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link ICI,
4 links/chip):

  compute    = per-device HLO FLOPs   / peak_FLOP/s
  memory     = per-device HLO bytes   / HBM_bw
  collective = per-device collective bytes / (links × link_bw)

``cost_analysis()`` on an SPMD-partitioned module reports *per-device*
quantities (verified empirically), so no further division by chip count
is needed.  Collective bytes are not in cost_analysis: we parse the
compiled HLO text and sum operand bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute
(per-shard shapes; all-reduce counted twice for the bidirectional
ring phase structure).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

import numpy as np


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalised across jax versions:
    0.4.x returns a list with one dict per program, newer jax returns
    the dict directly.  Always returns a dict (possibly empty)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    return ca

PEAK_FLOPS = 197e12          # bf16 per chip
PEAK_INT8 = 394e12
HBM_BW = 819e9               # bytes/s
ICI_LINK_BW = 50e9           # bytes/s per link
ICI_LINKS = 4                # 2-D torus

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")

# matches e.g.:  %x = bf16[16,512]{1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    if not dims:
        return nbytes
    return int(np.prod([int(d) for d in dims.split(",")])) * nbytes


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


# ops whose operands/results must round-trip HBM even under perfect
# elementwise fusion (the TPU compiler fuses elementwise chains into
# these; the CPU backend's cost_analysis does not, so raw
# "bytes accessed" is a pessimistic bound — we report both).
_HEAVY_OPS = ("dot", "convolution", "gather", "scatter",
              "dynamic-slice", "dynamic-update-slice")
_DEF_RE = re.compile(r"%([\w.\-]+)\s*=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\]")
_HEAVY_RE = re.compile(
    r"%[\w.\-]+\s*=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^\n=]*?\s"
    r"(dot|convolution|gather|scatter|dynamic-slice|dynamic-update-slice)"
    r"\(([^)]*)\)")


def essential_bytes(hlo_text: str,
                    exclude_trailing: Optional[set] = None) -> float:
    """Fusion-adjusted HBM traffic: sum of operand+result bytes of the
    heavy ops only (matmuls/convs/gathers/scatters/slices).  Entry
    args/outputs are added by the caller from memory_analysis.

    ``exclude_trailing``: set of (dim[-2], dim[-1]) pairs to drop —
    used for flash-attention accounting, where the (seq, chunk) score
    and probability tensors live in VMEM inside the Pallas kernel and
    never round-trip HBM (kernels/flash_attention.py, validated in
    interpret mode)."""
    shapes: Dict[str, Tuple[int, Tuple[int, ...]]] = {}
    for m in _DEF_RE.finditer(hlo_text):
        dims = tuple(int(x) for x in m.group(3).split(",")) if m.group(3) \
            else ()
        shapes[m.group(1)] = (_shape_bytes(m.group(2), m.group(3)), dims)

    def excluded(dims: Tuple[int, ...]) -> bool:
        return bool(exclude_trailing) and len(dims) >= 2 \
            and (dims[-2], dims[-1]) in exclude_trailing

    total = 0.0
    for m in _HEAVY_RE.finditer(hlo_text):
        dtype, dims_s, _op, args = m.groups()
        dims = tuple(int(x) for x in dims_s.split(",")) if dims_s else ()
        if not excluded(dims):
            total += _shape_bytes(dtype, dims_s)
        for a in args.split(","):
            a = a.strip()
            if a.startswith("%") and a[1:] in shapes:
                nbytes, adims = shapes[a[1:]]
                if not excluded(adims):
                    total += nbytes
    return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    by_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        # async pairs (-start/-done) would double count; the regex strips
        # the suffix so skip the matching -done by position pairing:
        span = hlo_text[m.start():m.end()]
        if "-done(" in span:
            continue
        b = _shape_bytes(dtype, dims)
        if kind == "all-reduce":
            b *= 2          # reduce-scatter + all-gather phases on the ring
        counts[kind] += 1
        by_kind[kind] += b
    return CollectiveStats(counts, by_kind)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float         # raw cost_analysis (unfused bound)
    collective_bytes_per_dev: float
    t_compute: float
    t_memory: float              # raw bytes / HBM_bw (pessimistic)
    t_collective: float
    model_flops: float           # 6·N·D or 2·N·D_tok, whole step
    peak_bytes_per_dev: float    # memory_analysis residency
    collective_counts: Dict[str, int]
    essential_bytes_per_dev: float = 0.0   # fused-traffic bound
    t_memory_fused: float = 0.0

    @property
    def dominant(self) -> str:
        """Bottleneck under the fused-memory estimate (the TPU compiler
        fuses the elementwise chains the CPU backend counts one by one;
        both memory bounds are reported)."""
        terms = {"compute": self.t_compute,
                 "memory": self.t_memory_fused or self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_step(self) -> float:
        """Lower-bound step time: max of the three overlapped terms
        (fused-memory estimate)."""
        return max(self.t_compute, self.t_memory_fused or self.t_memory,
                   self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO FLOPs × chips) — remat/redundancy waste."""
        total_hlo = self.flops_per_dev * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip-seconds the *useful* model FLOPs occupy —
        the MFU-style score this repo optimizes (1.0 == roofline)."""
        denom = self.t_step * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, t_step=self.t_step,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def analyze(compiled, *, arch: str, shape: str, mesh_name: str,
            chips: int, model_flops: float) -> RooflineReport:
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        peak = float(ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes)
    except Exception:
        peak = 0.0
    stats = parse_collectives(compiled.as_text())
    coll = stats.total_bytes
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_dev=flops, bytes_per_dev=bytes_acc,
        collective_bytes_per_dev=coll,
        t_compute=flops / PEAK_FLOPS,
        t_memory=bytes_acc / HBM_BW,
        t_collective=coll / (ICI_LINKS * ICI_LINK_BW),
        model_flops=model_flops,
        peak_bytes_per_dev=peak,
        collective_counts={k: v for k, v in stats.counts.items() if v},
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS per step: 6·N_active·D for training (fwd+bwd),
    2·N_active·D_tokens for inference cells (fwd only).  N excludes
    embedding tables (standard convention)."""
    n = cfg.active_param_count() - cfg.vocab_size * cfg.d_model
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    tokens = shape.global_batch              # one new token per sequence
    return 2.0 * n * tokens
