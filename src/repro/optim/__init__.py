"""Optimizers (pure JAX): AdamW with fp32 master weights + global-norm
clipping, SGD-momentum, and the train-state plumbing shared by the
launcher and the dry-run.  Optimizer state shards like the params
(plus ZeRO-1 on the data axis via the sharding policy).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    # (step+1)/warmup so step 0 trains at lr/warmup, not at zero
    warm = jnp.minimum((step.astype(jnp.float32) + 1.0)
                       / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def init_opt_state(params: Params, cfg: OptimizerConfig) -> Params:
    # jnp.array copies: master must never alias params (donation safety
    # when compute dtype is already f32)
    master = jax.tree.map(lambda p: jnp.array(p, jnp.float32), params)
    if cfg.name == "sgd":
        return {"master": master,
                "mu": jax.tree.map(jnp.zeros_like, master)}
    return {"master": master,
            "mu": jax.tree.map(jnp.zeros_like, master),
            "nu": jax.tree.map(jnp.zeros_like, master)}


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jnp.ndarray]:
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * factor, grads), norm


def apply_update(params: Params, grads: Params, opt_state: Params,
                 step: jnp.ndarray, cfg: OptimizerConfig
                 ) -> Tuple[Params, Params, Dict[str, jnp.ndarray]]:
    """One optimizer step.  ``params`` are the compute-dtype copies;
    masters stay fp32.  Returns (params, opt_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)

    if cfg.name == "sgd":
        new_mu = jax.tree.map(
            lambda m, g: cfg.beta1 * m + g, opt_state["mu"], grads)
        new_master = jax.tree.map(
            lambda p, m: p - lr * (m + cfg.weight_decay * p),
            opt_state["master"], new_mu)
        new_state = {"master": new_master, "mu": new_mu}
    else:
        b1, b2 = cfg.beta1, cfg.beta2
        new_mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                              opt_state["mu"], grads)
        new_nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                              opt_state["nu"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                             + cfg.weight_decay * p)

        new_master = jax.tree.map(upd, opt_state["master"], new_mu, new_nu)
        new_state = {"master": new_master, "mu": new_mu, "nu": new_nu}

    new_params = jax.tree.map(
        lambda mp, p: mp.astype(p.dtype), new_master, params)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def make_train_step(model, opt_cfg: OptimizerConfig,
                    compression=None, n_micro: int = 1,
                    grad_spec=None, act_constraint=None) -> Callable:
    """Build the jittable train step: loss -> grads (optionally
    accumulated over n_micro microbatches, overlapping per-microbatch
    reductions with the next microbatch's compute) -> (optional
    compressed DP reduction) -> clip -> AdamW -> recast.

    ``grad_spec``: optional pytree of PartitionSpecs constraining the
    gradients (ZeRO-2 style: the data-parallel gradient all-reduce
    becomes a reduce-scatter and each shard updates its slice of the
    optimizer state — grads never materialise replicated)."""

    def train_step(state: Params, batch: Dict[str, Any]):
        params = state["params"]

        if n_micro > 1:
            from repro.distributed import make_accumulating_step
            loss, grads = make_accumulating_step(
                model.loss, n_micro,
                unroll=getattr(model, "unroll", False),
                grad_spec=grad_spec,
                act_constraint=act_constraint)(params, batch)
        else:
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch))(params)
        if grad_spec is not None:
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, grad_spec,
                is_leaf=lambda x: hasattr(x, "shape"))
        if compression is not None:
            grads = compression(grads)
        new_params, new_opt, metrics = apply_update(
            params, grads, state["opt"], state["step"], opt_cfg)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step


def init_train_state(model, key: jax.Array, opt_cfg: OptimizerConfig
                     ) -> Params:
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}
