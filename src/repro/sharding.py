"""Sharding policies for the architecture fleet.

This is the paper's "hardware-aware fitter" lifted to a TPU pod (see
DESIGN.md §4): a ``ShardingPolicy`` is one *option* in the pod-scale
design space — it decides, per parameter and activation, how the
(pod, data, model) mesh axes are used, under the same style of
divisibility constraints the paper applies to (N_i, N_l):

  * weights: 2-D "megatron" TP — column-parallel in, row-parallel out,
    experts on the model axis, vocab padded to a shardable multiple;
  * activations: batch on (pod, data);
  * decode KV caches: sequence-sharded on the model axis (plus data
    when batch == 1), consumed by shard_map flash-decoding — this is
    what lets a 500k-token cache fit;
  * anything whose dim does not divide the axis stays replicated (the
    fitter simply scores that option worse, as the paper's fitter does
    with infeasible (N_i, N_l)).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

Params = Dict[str, Any]

# rule table: leaf-name -> spec builder over (model_axis,)
# a rule is a tuple pattern where "M" marks the model-sharded dim.
_PARAM_RULES: Dict[str, Tuple] = {
    # embeddings / head
    "embed": ("M", None),
    "lm_head": (None, "M"),
    "dec_pos": (None, None),
    # attention
    "wq": (None, "M"), "wk": (None, "M"), "wv": (None, "M"),
    "wo": ("M", None),
    "bq": ("M",), "bk": ("M",), "bv": ("M",),
    "q_norm": (None,), "k_norm": (None,),
    # mlp
    "w_gate": (None, "M"), "w_up": (None, "M"), "w_down": ("M", None),
    "b_up": ("M",), "b_down": (None,),
    # moe (expert-parallel on the model axis)
    "router": (None, None),
    "moe/w_gate": ("M", None, None), "moe/w_up": ("M", None, None),
    "moe/w_down": ("M", None, None),
    # norms
    "scale": (None,), "bias": (None,),
    # mamba2 (d_inner / heads on the model axis; B/C per-group replicated)
    "w_z": (None, "M"), "w_x": (None, "M"),
    "w_b": (None, None), "w_c": (None, None), "w_dt": (None, "M"),
    "conv_x": (None, "M"), "conv_b": (None, None), "conv_c": (None, None),
    "conv_bias_x": ("M",), "conv_bias_b": (None,), "conv_bias_c": (None,),
    "a_log": ("M",), "dt_bias": ("M",), "d_skip": ("M",),
    "gate_norm": ("M",), "w_out": ("M", None),
}


@dataclasses.dataclass
class PolicyOptions:
    """The DSE-explorable knobs of a sharding policy."""

    shard_model: bool = True          # use the model axis at all
    shard_activation_heads: bool = True
    seq_shard_decode: bool = True     # flash-decoding over sharded caches
    zero1: bool = True                # optimizer state sharded on data
    remat: str = "dots"
    activation_dp: bool = True        # constrain (B,S,D) batch to data axes
    # Megatron-style sequence parallelism: residual-stream activations
    # sharded (batch -> data, seq -> model); norms/elementwise go local,
    # TP all-reduces become reduce-scatter + all-gather pairs, and
    # activation residency drops by the model-axis size.
    sequence_parallel: bool = False
    n_micro: int = 1                  # gradient-accumulation microbatches
    zero2_grads: bool = False         # reduce-scatter grads (ZeRO-2)


class ShardingPolicy:
    def __init__(self, mesh: Mesh, cfg: ModelConfig,
                 options: Optional[PolicyOptions] = None):
        self.mesh = mesh
        self.cfg = cfg
        self.opt = options or PolicyOptions()
        axes = mesh.axis_names
        self.model_axis = "model" if "model" in axes else None
        self.dp_axes: Tuple[str, ...] = tuple(
            a for a in ("pod", "data") if a in axes)
        self.model_size = (mesh.shape["model"]
                           if self.model_axis else 1)
        self.dp_size = int(np.prod([mesh.shape[a] for a in self.dp_axes])
                           ) if self.dp_axes else 1
        self.seq_sharded_decode = (self.opt.seq_shard_decode
                                   and self.model_axis is not None)
        self._decode_seq_axes: Optional[Tuple[str, ...]] = None

    # --------------------------------------------------------- param specs
    def _rule_for(self, path: Tuple[str, ...], ndim: int) -> P:
        name = path[-1]
        key = name
        if "moe" in path and name in ("w_gate", "w_up", "w_down"):
            key = f"moe/{name}"
        rule = _PARAM_RULES.get(key)
        if rule is None:
            return P()
        spec = tuple(
            (self.model_axis if (x == "M" and self.opt.shard_model
                                 and self.model_axis) else None)
            for x in rule)
        # stacked layer/group leading dims -> prepend Nones
        while len(spec) < ndim:
            spec = (None,) + spec
        return P(*spec)

    def param_specs(self, params: Params) -> Params:
        def spec(path, leaf):
            names = tuple(
                p.key if hasattr(p, "key") else str(p) for p in path)
            ps = self._rule_for(names, np.ndim(leaf))
            return self._validated(ps, np.shape(leaf))
        return jax.tree_util.tree_map_with_path(spec, params)

    def _validated(self, ps: P, shape: Tuple[int, ...]) -> P:
        """Divisibility guard: drop axes that do not divide the dim
        (the fitter's feasibility rule)."""
        fixed = []
        for dim, axis in zip(shape, tuple(ps) + (None,) * len(shape)):
            if axis is None:
                fixed.append(None)
                continue
            size = int(np.prod([self.mesh.shape[a] for a in
                                (axis if isinstance(axis, tuple) else (axis,))]))
            fixed.append(axis if dim % size == 0 else None)
        return P(*fixed)

    def param_shardings(self, params: Params) -> Params:
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s),
            self.param_specs(params),
            is_leaf=lambda x: isinstance(x, P))

    # ----------------------------------------------------- batch/cache specs
    def batch_specs(self, batch: Dict[str, Any],
                    shape: ShapeConfig) -> Dict[str, Any]:
        dp = self.dp_axes if len(self.dp_axes) > 1 else (
            self.dp_axes[0] if self.dp_axes else None)

        def spec(path, leaf):
            names = tuple(p.key if hasattr(p, "key") else str(p)
                          for p in path)
            nd = len(leaf.shape)
            if "cache" in names:
                return self._validated(self.cache_spec(names, nd, leaf.shape),
                                       leaf.shape)
            name = names[-1]
            if name == "positions" and nd == 3:   # (3, B, S) M-RoPE
                return self._validated(P(None, dp, None), leaf.shape)
            if name == "lengths":
                return self._validated(P(dp), leaf.shape)
            if name in ("tokens", "labels"):
                return self._validated(P(dp, None), leaf.shape)
            if name in ("embeds", "audio_embeds"):
                return self._validated(P(dp, None, None), leaf.shape)
            return P()

        return jax.tree_util.tree_map_with_path(spec, batch)

    def cache_spec(self, names: Tuple[str, ...], ndim: int,
                   shape: Tuple[int, ...]) -> P:
        """Decode caches.  KV caches (…, B, KV, S, hd): batch on data,
        sequence on model (plus data when batch cannot use it).  Mamba
        states: batch on data, inner/heads on model."""
        dp = self.dp_axes if len(self.dp_axes) > 1 else (
            self.dp_axes[0] if self.dp_axes else None)
        name = names[-1]
        if name in ("k", "v", "xk", "xv"):
            batch_dim = shape[-4]
            seq_axis: Any = None
            if self.seq_sharded_decode and name in ("k", "v"):
                seq_axis = self.model_axis
                if batch_dim == 1 and self.dp_axes:
                    seq_axis = self.dp_axes + (self.model_axis,)
                    dp = None
            lead = (None,) * (ndim - 4)
            self._decode_seq_axes = (
                seq_axis if isinstance(seq_axis, tuple)
                else ((seq_axis,) if seq_axis else None))
            return P(*lead, dp if shape[-4] > 1 else None, None,
                     seq_axis, None)
        if name == "ssm":               # (L, B, H, P, N)
            lead = (None,) * (ndim - 4)
            return P(*lead, dp if shape[-4] > 1 else None,
                     self.model_axis, None, None)
        if name.startswith("conv"):     # (L, B, K-1, C)
            lead = (None,) * (ndim - 3)
            return P(*lead, dp if shape[-3] > 1 else None, None,
                     self.model_axis if name.endswith("x") else None)
        return P()

    def batch_shardings(self, batch, shape):
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s),
            self.batch_specs(batch, shape),
            is_leaf=lambda x: isinstance(x, P))

    # ------------------------------------------------ activation constraints
    def act(self, x: jnp.ndarray) -> jnp.ndarray:
        """(B, S, D) residual-stream constraint: batch over data axes,
        plus sequence over the model axis when sequence_parallel."""
        if not self.opt.activation_dp or not self.dp_axes:
            return x
        dp = self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        if x.shape[0] % self.dp_size != 0:
            return x
        if (self.opt.sequence_parallel and self.model_axis and x.ndim >= 3
                and x.shape[1] % self.model_size == 0):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh,
                                 P(dp, self.model_axis,
                                   *(None,) * (x.ndim - 2))))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(dp, *(None,) * (x.ndim - 1))))

    def mamba_inner(self, x: jnp.ndarray) -> jnp.ndarray:
        """(B, L, d_inner): d_inner on the model axis."""
        if not self.model_axis or x.shape[-1] % self.model_size != 0:
            return self.act(x)
        dp = self.dp_axes if len(self.dp_axes) > 1 else (
            self.dp_axes[0] if self.dp_axes else None)
        if x.shape[0] % self.dp_size != 0:
            dp = None
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(dp, None, self.model_axis)))

    def attn_qkv(self, q, k, v):
        """(B, H, S, hd): heads on model when divisible, else leave the
        partitioner to choose (scored by the fitter)."""
        if (not self.opt.shard_activation_heads or not self.model_axis):
            return q, k, v
        dp = self.dp_axes if len(self.dp_axes) > 1 else (
            self.dp_axes[0] if self.dp_axes else None)
        if q.shape[0] % self.dp_size != 0:
            dp = None

        def c(x):
            if x.shape[1] % self.model_size == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(self.mesh,
                                     P(dp, self.model_axis, None, None)))
            if dp is not None:
                # heads indivisible: still pin the batch axis — an
                # unconstrained activation lets the partitioner invent
                # shardings that force involuntary full
                # rematerialisation (global-tensor copies) across the
                # scan body on some jax/XLA versions
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(self.mesh, P(dp, None, None, None)))
            return x
        return c(q), c(k), c(v)

    # ------------------------------------------- shard_map flash-decoding
    def sharded_decode_attention(self, q: jnp.ndarray, k_cache: jnp.ndarray,
                                 v_cache: jnp.ndarray, lengths: jnp.ndarray,
                                 window: Optional[int]) -> jnp.ndarray:
        """Decode attention over a sequence-sharded cache: each shard
        computes local (m, l, o) online-softmax stats; a log-sum-exp
        combine over the sequence axes yields the exact result.  The
        collective is O(B*H*d) — independent of cache length."""
        seq_axes = self._decode_seq_axes or (
            (self.model_axis,) if self.model_axis else None)
        if seq_axes is None:
            from repro.models.layers import decode_attention
            return decode_attention(q, k_cache, v_cache, lengths, window)
        b = q.shape[0]
        dp = None
        if b > 1 and self.dp_axes and b % self.dp_size == 0 \
                and not any(a in seq_axes for a in self.dp_axes):
            dp = (self.dp_axes if len(self.dp_axes) > 1
                  else self.dp_axes[0])
        qspec = P(dp, None, None, None)
        cspec = P(dp, None, seq_axes if len(seq_axes) > 1 else seq_axes[0],
                  None)
        lspec = P(dp)

        hkv = k_cache.shape[1]
        g = q.shape[1] // hkv
        scale = q.shape[-1] ** -0.5

        def local(q_l, k_l, v_l, len_l):
            # global offset of this shard's cache slice
            idx = jnp.zeros((), jnp.int32)
            mult = 1
            for a in reversed(seq_axes):
                idx = idx + jax.lax.axis_index(a) * mult
                # static mesh extent (jax.lax.axis_size is newer-jax)
                mult = mult * int(self.mesh.shape[a])
            chunk = k_l.shape[2]
            offset = idx * chunk
            qg = q_l.reshape(q_l.shape[0], hkv, g, -1).astype(jnp.float32)
            s = jnp.einsum("bkgd,bksd->bkgs", qg,
                           k_l.astype(jnp.float32)) * scale
            kpos = offset + jnp.arange(chunk)[None, :]
            mask = kpos < len_l[:, None]
            if window is not None:
                mask &= kpos > (len_l[:, None] - 1 - window)
            s = jnp.where(mask[:, None, None, :], s, -1e30)
            m_l = jnp.max(s, axis=-1, keepdims=True)
            p = jnp.exp(s - m_l)
            l_l = jnp.sum(p, axis=-1, keepdims=True)
            o_l = jnp.einsum("bkgs,bksd->bkgd", p,
                             v_l.astype(jnp.float32))
            # combine across sequence shards
            m = jax.lax.pmax(m_l, seq_axes)
            w = l_l * jnp.exp(m_l - m)
            o = jax.lax.psum(o_l * jnp.exp(m_l - m), seq_axes)
            denom = jax.lax.psum(w, seq_axes)
            o = o / jnp.maximum(denom, 1e-30)
            return o.reshape(q_l.shape[0], -1, 1, q_l.shape[-1]
                             ).astype(q_l.dtype)

        from repro.launch.mesh import shard_map as compat_shard_map
        return compat_shard_map(
            local, mesh=self.mesh,
            in_specs=(qspec, cspec, cspec, lspec),
            out_specs=qspec,
        )(q, k_cache, v_cache, lengths)

    # --------------------------------------------------------------- zero-1
    def optimizer_spec(self, param_spec: P, shape: Tuple[int, ...]) -> P:
        """ZeRO-1: additionally shard optimizer state on the data axis
        along the first still-replicated, divisible dim."""
        if not self.opt.zero1 or not self.dp_axes:
            return param_spec
        axis = self.dp_axes[-1]          # 'data'
        size = self.mesh.shape[axis]
        spec = list(tuple(param_spec) + (None,) * (len(shape) - len(param_spec)))
        for i, (dim, cur) in enumerate(zip(shape, spec)):
            if cur is None and dim % size == 0 and dim >= size:
                spec[i] = axis
                return P(*spec)
        return param_spec
