"""Sharded, elastic, fault-tolerant checkpointing.

Layout (one directory per step):

    <dir>/step_000100/
        manifest.json      # tree structure, global shapes/dtypes, step
        arrays/<name>.npy  # one file per leaf (zstd-compressed .npz opt)
    <dir>/LATEST           # atomic pointer (tmp + rename)

Design points for the 1000-node posture:
  * atomic publish: data is fully written before LATEST flips;
  * **elastic reshard on load**: the manifest stores *global* shapes,
    the loader hands each leaf to the new mesh/sharding regardless of
    the saving topology (device_put against the target sharding);
  * async save: a background thread serialises a host snapshot so the
    train loop only blocks for the device->host gather;
  * preemption hook: SIGTERM triggers a final synchronous save;
  * resume: ``latest_step`` + stateless data pipeline (step-keyed).

Leaves are gathered to host (fine at test scale; per-shard TensorStore
writes are the drop-in replacement at fleet scale and the manifest
format already carries what that needs).
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import tempfile
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[name] = leaf
    return flat


def _unflatten_into(skeleton: Any, flat: Dict[str, np.ndarray]) -> Any:
    def fill(path, leaf):
        name = SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        return flat[name]
    return jax.tree_util.tree_map_with_path(fill, skeleton)


def save(directory: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous atomic checkpoint write."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(os.path.join(tmp, "arrays"))
    flat = _flatten(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for name, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace(SEP, "__") + ".npy"
        # raw-bytes payload: round-trips extension dtypes (bf16/fp8)
        # that plain np.save cannot
        np.save(os.path.join(tmp, "arrays", fname),
                np.frombuffer(arr.tobytes(), np.uint8))
        manifest["leaves"][name] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _publish_latest(directory, final)
    return final


def _publish_latest(directory: str, final: str) -> None:
    ptr = os.path.join(directory, "LATEST")
    fd, tmp = tempfile.mkstemp(dir=directory)
    with os.fdopen(fd, "w") as f:
        f.write(os.path.basename(final))
    os.replace(tmp, ptr)


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[-1])


def restore(directory: str, skeleton: Any,
            shardings: Optional[Any] = None,
            step: Optional[int] = None) -> Tuple[Any, int, Dict]:
    """Load a checkpoint, resharding each leaf onto ``shardings`` (any
    mesh shape — elastic scale-up/down) or to host arrays if None."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    root = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    flat: Dict[str, np.ndarray] = {}
    skel_flat = _flatten(skeleton)
    for name, meta in manifest["leaves"].items():
        raw = np.load(os.path.join(root, "arrays", meta["file"]))
        arr = np.frombuffer(raw.tobytes(), _np_dtype(meta["dtype"])
                            ).reshape(meta["shape"])
        want = skel_flat.get(name)
        if want is not None and tuple(arr.shape) != tuple(want.shape):
            raise ValueError(
                f"leaf {name}: checkpoint shape {arr.shape} != "
                f"model shape {tuple(want.shape)}")
        flat[name] = arr
    tree = _unflatten_into(skeleton, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda leaf, s: jax.device_put(jnp.asarray(leaf), s),
            tree, shardings)
    return tree, manifest["step"], manifest.get("extra", {})


def gc_old(directory: str, keep: int = 3) -> List[str]:
    """Keep the newest ``keep`` checkpoints; never delete LATEST's target."""
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp"))
    victims = steps[:-keep] if keep else []
    latest = latest_step(directory)
    removed = []
    for v in victims:
        if latest is not None and v == f"step_{latest:08d}":
            continue
        shutil.rmtree(os.path.join(directory, v))
        removed.append(v)
    return removed


class AsyncCheckpointer:
    """Background-thread checkpointer with at-most-one pending save and
    a SIGTERM preemption hook (final synchronous save, then re-raise)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._last: Optional[Tuple[int, Any, Dict]] = None
        self._lock = threading.Lock()
        self._orig_handler = None

    def save_async(self, step: int, tree: Any,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        # np.array(copy=True): device_get on an already-host array is a
        # no-op view — the snapshot must be isolated from later mutation
        host_tree = jax.tree.map(
            lambda x: np.array(jax.device_get(x), copy=True), tree)
        self.wait()
        with self._lock:
            self._last = (step, host_tree, extra or {})

        def run():
            save(self.directory, step, host_tree, extra)
            gc_old(self.directory, self.keep)

        self._thread = threading.Thread(target=run, daemon=False)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def install_preemption_hook(self, state_fn: Callable[[], Tuple[int, Any]]
                                ) -> None:
        """On SIGTERM: final synchronous checkpoint, then default action."""
        def handler(signum, frame):
            step, tree = state_fn()
            self.wait()
            save(self.directory, step, tree, {"preempted": True})
            signal.signal(signal.SIGTERM, signal.SIG_DFL)
            os.kill(os.getpid(), signal.SIGTERM)

        self._orig_handler = signal.signal(signal.SIGTERM, handler)
