"""qverify CLI — static design-rule checks over the model zoo.

Runs the :mod:`repro.core.verify` rule catalog (DESIGN.md §13) over the
named builders, calibrating each with the standard seeded random input
and checking every requested (quant mode, fusion mode) combination.
The process exits non-zero when any error-severity diagnostic fires —
the CI gate runs this over all five zoo builders, per-tensor and
per-channel, and requires a clean report.

    PYTHONPATH=src python -m repro.launch.verify \
        --models resnet_tiny,googlenet_tiny --per-channel both

``--jaxpr-probes`` additionally traces each fused interpret-mode
executor and runs the QV501/QV502 structural probes (no standalone
integer add / concatenate may reach XLA in a fully fused program) —
opt-in because tracing is not free.  ``--vmem-budget`` arms the
QV401/QV402 resource rules against a declared on-chip byte budget.
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import parser as P
from repro.core import verify as V
from repro.core.synthesis import CNN2Gate

ZOO_MODELS = ("resnet_tiny", "mobilenet_tiny", "googlenet_tiny",
              "squeezenet_tiny", "resnet18")


def _modes(choice: str) -> List[bool]:
    return {"off": [False], "on": [True], "both": [False, True]}[choice]


def verify_model(name: str, per_channel: bool, fused: bool, *,
                 n_i: int = 16, n_l: int = 32,
                 block_h: Optional[int] = None,
                 vmem_budget: Optional[int] = None,
                 checkpoints: Sequence[int] = (),
                 jaxpr_probes: bool = False,
                 seed: int = 0) -> V.VerificationReport:
    """Build + statically verify one (model, quant mode, fusion mode)
    combination; returns the report (QV5xx probes included on demand).
    """
    from repro.models import cnn

    graph = getattr(cnn, name)(batch=1)
    parsed = P.parse(graph, fuse_skip=fused, fuse_concat=fused)
    gate = CNN2Gate(parsed)
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(parsed.input_shape) * 0.5).astype(np.float32)
    # build_quantized already runs the build-time subset and would raise
    # on an error; the explicit pass below re-runs the full catalog and
    # *collects* (so one bad combination cannot mask another's report)
    gate.calibrate_quantization(x, per_channel=per_channel)
    rep = gate.verify(n_i=n_i, n_l=n_l, block_h=block_h,
                      vmem_budget=vmem_budget, checkpoints=checkpoints)
    if jaxpr_probes and fused:
        rep.diagnostics += V.structural_probes(
            gate.quantized, n_i=n_i, n_l=n_l, block_h=block_h)
    return rep


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Static program verification (DRC) over the model "
                    "zoo (DESIGN.md §13)")
    ap.add_argument("--models", default=",".join(ZOO_MODELS),
                    help=f"comma-separated subset of {ZOO_MODELS}")
    ap.add_argument("--per-channel", default="both",
                    choices=("off", "on", "both"),
                    help="weight-quantization modes to check")
    ap.add_argument("--fused", default="both",
                    choices=("off", "on", "both"),
                    help="skip/concat fusion modes to check")
    ap.add_argument("--n-i", type=int, default=16)
    ap.add_argument("--n-l", type=int, default=32)
    ap.add_argument("--block-h", type=int, default=None)
    ap.add_argument("--vmem-budget", type=int, default=None,
                    help="arm QV401/QV402 against this on-chip byte "
                         "budget (default: unarmed)")
    ap.add_argument("--checkpoints", default="",
                    help="comma-separated boundary indices to prove "
                         "(QV304) and charge (QV402)")
    ap.add_argument("--jaxpr-probes", action="store_true",
                    help="also trace fused executors for QV501/QV502")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in sorted(V.RULES):
            print(f"{rid}  {V.RULES[rid]}")
        return 0

    names = [m.strip() for m in args.models.split(",") if m.strip()]
    unknown = [m for m in names if m not in ZOO_MODELS]
    if unknown:
        ap.error(f"unknown model(s) {unknown}; choose from {ZOO_MODELS}")
    ckpts = [int(c) for c in args.checkpoints.split(",") if c.strip()]

    n_errors = 0
    n_combos = 0
    counts: Dict[str, int] = {}
    for name in names:
        for pc in _modes(args.per_channel):
            for fused in _modes(args.fused):
                n_combos += 1
                tag = (f"{name} [{'per-channel' if pc else 'per-tensor'}"
                       f", {'fused' if fused else 'unfused'}]")
                try:
                    rep = verify_model(
                        name, pc, fused, n_i=args.n_i, n_l=args.n_l,
                        block_h=args.block_h,
                        vmem_budget=args.vmem_budget,
                        checkpoints=ckpts,
                        jaxpr_probes=args.jaxpr_probes, seed=args.seed)
                except V.VerificationError as e:
                    # build-time rejection IS a verifier result
                    rep = V.VerificationReport(list(e.diagnostics))
                for d in rep.diagnostics:
                    counts[d.rule_id] = counts.get(d.rule_id, 0) + 1
                if rep.ok:
                    extra = (f" ({len(rep.warnings)} warning(s))"
                             if rep.warnings else "")
                    print(f"[verify] {tag}: clean{extra}")
                else:
                    n_errors += len(rep.errors)
                    print(f"[verify] {tag}: {len(rep.errors)} error(s)")
                    for d in rep.diagnostics:
                        print(f"[verify]   {d}")
    summary = ", ".join(f"{r}x{n}" for r, n in sorted(counts.items())) \
        or "none"
    print(f"[verify] {n_combos} combination(s), {n_errors} error(s); "
          f"diagnostics: {summary}")
    return 1 if n_errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
