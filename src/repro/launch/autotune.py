import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Pod-scale DSE autotuner — the paper's hardware-aware fitter on TPU.

Runs BF-DSE / RL-DSE (Algorithm-1 reward shaping, unchanged) over the
``ShardingSpace`` of a cell, with XLA as the vendor compiler:

    PYTHONPATH=src python -m repro.launch.autotune \
        --arch qwen2-1.5b --shape train_4k --algo rl \
        --axes remat=none,dots,full --axes n_micro=1,8 \
        --out results/autotune.json

or over the CNN (N_i, N_l, block_h) space of a parsed model, with the
calibrated board estimator + row-band working-set model as the
compiler (the third axis is the conv kernel's row-band height):

    PYTHONPATH=src python -m repro.launch.autotune \
        --cnn alexnet --board ARRIA10 --algo rl \
        --block-h 4,8,16,32 --out results/autotune_cnn.json
"""
import argparse
import json
from typing import List, Tuple

from repro.core import dse
from repro.core.spaces import (DEFAULT_BLOCK_H_OPTIONS, DEFAULT_POD_AXES,
                               CNNDesignSpace, ShardingSpace)


def parse_axes(specs: List[str]) -> List[Tuple[str, list]]:
    if not specs:
        return DEFAULT_POD_AXES
    axes = []
    for s in specs:
        name, vals = s.split("=")
        parsed = []
        for v in vals.split(","):
            if v in ("True", "False"):
                parsed.append(v == "True")
            else:
                try:
                    parsed.append(int(v))
                except ValueError:
                    parsed.append(v)
        axes.append((name, parsed))
    return axes


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="pod mode: LM architecture for the ShardingSpace")
    ap.add_argument("--cnn", default=None,
                    choices=["tiny", "alexnet", "vgg16"],
                    help="CNN mode: explore (N_i, N_l, block_h) for this "
                         "model instead of the pod ShardingSpace")
    ap.add_argument("--board", default="ARRIA10",
                    help="CNN mode: FPGA profile to score against")
    ap.add_argument("--block-h", default=None,
                    help="CNN mode: comma-separated row-band heights "
                         f"(default {DEFAULT_BLOCK_H_OPTIONS})")
    ap.add_argument("--checkpoint-k", default=None,
                    help="CNN mode: comma-separated candidate counts of "
                         "stage-boundary recovery snapshots (adds the "
                         "ckpt_k axis; snapshot bytes are charged "
                         "against the on-chip memory quota — include 0 "
                         "so resilience is only bought when it fits)")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--algo", default="rl", choices=["rl", "bf"])
    ap.add_argument("--axes", action="append", default=[])
    ap.add_argument("--eval-depth", type=int, default=4)
    ap.add_argument("--episodes", type=int, default=6)
    ap.add_argument("--steps-per-episode", type=int, default=8)
    ap.add_argument("--lut-threshold", type=float, default=100.0,
                    help="tolerated HBM-residency quota %% (the paper's "
                         "user-provided T_th; raise it when scoring with "
                         "the conservative unfused CPU-backend bound)")
    ap.add_argument("--robust", action="store_true",
                    help="wrap the space in a RobustEvaluator (timeout, "
                         "retry, quarantine, resumable journal)")
    ap.add_argument("--eval-timeout-s", type=float, default=None,
                    help="robust mode: per-candidate wall-clock budget")
    ap.add_argument("--eval-retries", type=int, default=2,
                    help="robust mode: retries for raising evaluations")
    ap.add_argument("--journal", default=None,
                    help="robust mode: JSON journal path; rerunning with "
                         "the same journal resumes the sweep")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if (args.arch is None) == (args.cnn is None):
        ap.error("exactly one of --arch (pod mode) / --cnn (CNN mode) "
                 "is required")

    if args.cnn is not None:
        from repro.core.parser import parse
        from repro.core.resources import FPGA_BOARDS
        from repro.models import cnn as cnn_models
        graph = {"tiny": cnn_models.tiny_cnn, "alexnet": cnn_models.alexnet,
                 "vgg16": cnn_models.vgg16}[args.cnn]()
        try:
            bh = ([int(v) for v in args.block_h.split(",")] if args.block_h
                  else list(DEFAULT_BLOCK_H_OPTIONS))
        except ValueError:
            ap.error("--block-h must be comma-separated ints, "
                     f"got {args.block_h!r}")
        try:
            ck = ([int(v) for v in args.checkpoint_k.split(",")]
                  if args.checkpoint_k else None)
        except ValueError:
            ap.error("--checkpoint-k must be comma-separated ints, "
                     f"got {args.checkpoint_k!r}")
        space = CNNDesignSpace(parse(graph), FPGA_BOARDS[args.board],
                               block_h_options=bh,
                               checkpoint_options=ck)
    else:
        space = ShardingSpace(args.arch, args.shape,
                              axes=parse_axes(args.axes),
                              eval_depth=args.eval_depth)
    robust = None
    if args.robust or args.journal or args.eval_timeout_s is not None:
        robust = dse.RobustEvaluator(space,
                                     timeout_s=args.eval_timeout_s,
                                     retries=args.eval_retries,
                                     journal_path=args.journal)
        space = robust
    thresholds = dict(dse.DEFAULT_THRESHOLDS)
    thresholds["lut"] = args.lut_threshold
    thresholds["mem"] = max(thresholds["mem"], args.lut_threshold)
    print(f"option space: {len(space.options())} options "
          "x one compiler call each")
    if args.algo == "bf":
        res = dse.brute_force(space, thresholds=thresholds)
    else:
        res = dse.rl_dse(space, thresholds=thresholds,
                         episodes=args.episodes,
                         steps_per_episode=args.steps_per_episode)
    names = space.axis_names()
    print(f"best option: {dict(zip(names, res.best)) if res.best else None}")
    print(f"F_avg={res.f_max:.1f}  compiles={res.evaluations}  "
          f"wall={res.wall_time_s:.0f}s")
    if robust is not None:
        print(f"robust: {robust.stats}")
        for opt, why in robust.quarantined_options():
            print(f"quarantined: {dict(zip(names, opt))} ({why})")
    if res.best_report is not None:
        print("quotas:", {k: round(v, 1)
                          for k, v in res.best_report.percents.items()})
        print("projected:", {k: (round(v, 3) if isinstance(v, float) else v)
                             for k, v in res.best_report.raw.items()})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        payload = {
            "arch": args.arch or args.cnn, "shape": args.shape,
            "board": args.board if args.cnn else None, "algo": args.algo,
            "best": dict(zip(names, res.best)) if res.best else None,
            "f_max": res.f_max, "evaluations": res.evaluations,
            "history": [
                {"option": dict(zip(names, o)), "f_avg": f, "fits": ok}
                for o, f, ok in res.history],
        }
        if robust is not None:
            from repro.core import telemetry as tele
            payload["robust"] = {
                "stats": robust.stats,
                "quarantined": [
                    {"option": dict(zip(names, o)), "reason": why}
                    for o, why in robust.quarantined_options()],
                # registry mirror of the stats (dse.* counters plus
                # whatever else incremented this process) — same shape
                # as BENCH_profile.json's telemetry block
                "telemetry": tele.get_registry().snapshot(),
            }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1, default=str)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
