import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Pod-scale DSE autotuner — the paper's hardware-aware fitter on TPU.

Runs BF-DSE / RL-DSE (Algorithm-1 reward shaping, unchanged) over the
``ShardingSpace`` of a cell, with XLA as the vendor compiler:

    PYTHONPATH=src python -m repro.launch.autotune \
        --arch qwen2-1.5b --shape train_4k --algo rl \
        --axes remat=none,dots,full --axes n_micro=1,8 \
        --out results/autotune.json
"""
import argparse
import json
from typing import List, Tuple

from repro.core import dse
from repro.core.spaces import DEFAULT_POD_AXES, ShardingSpace


def parse_axes(specs: List[str]) -> List[Tuple[str, list]]:
    if not specs:
        return DEFAULT_POD_AXES
    axes = []
    for s in specs:
        name, vals = s.split("=")
        parsed = []
        for v in vals.split(","):
            if v in ("True", "False"):
                parsed.append(v == "True")
            else:
                try:
                    parsed.append(int(v))
                except ValueError:
                    parsed.append(v)
        axes.append((name, parsed))
    return axes


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--algo", default="rl", choices=["rl", "bf"])
    ap.add_argument("--axes", action="append", default=[])
    ap.add_argument("--eval-depth", type=int, default=4)
    ap.add_argument("--episodes", type=int, default=6)
    ap.add_argument("--steps-per-episode", type=int, default=8)
    ap.add_argument("--lut-threshold", type=float, default=100.0,
                    help="tolerated HBM-residency quota %% (the paper's "
                         "user-provided T_th; raise it when scoring with "
                         "the conservative unfused CPU-backend bound)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    space = ShardingSpace(args.arch, args.shape, axes=parse_axes(args.axes),
                          eval_depth=args.eval_depth)
    thresholds = dict(dse.DEFAULT_THRESHOLDS)
    thresholds["lut"] = args.lut_threshold
    thresholds["mem"] = max(thresholds["mem"], args.lut_threshold)
    print(f"option space: {len(space.options())} options "
          f"x one XLA compile each")
    if args.algo == "bf":
        res = dse.brute_force(space, thresholds=thresholds)
    else:
        res = dse.rl_dse(space, thresholds=thresholds,
                         episodes=args.episodes,
                         steps_per_episode=args.steps_per_episode)
    names = [n for n, _ in space._axes]
    print(f"best option: {dict(zip(names, res.best)) if res.best else None}")
    print(f"F_avg={res.f_max:.1f}  compiles={res.evaluations}  "
          f"wall={res.wall_time_s:.0f}s")
    if res.best_report is not None:
        print("quotas:", {k: round(v, 1)
                          for k, v in res.best_report.percents.items()})
        print("projected:", {k: (round(v, 3) if isinstance(v, float) else v)
                             for k, v in res.best_report.raw.items()})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        payload = {
            "arch": args.arch, "shape": args.shape, "algo": args.algo,
            "best": dict(zip(names, res.best)) if res.best else None,
            "f_max": res.f_max, "evaluations": res.evaluations,
            "history": [
                {"option": dict(zip(names, o)), "f_avg": f, "fits": ok}
                for o, f, ok in res.history],
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1, default=str)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
