"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2-1.5b --preset smoke --steps 200 \
        --ckpt-dir /tmp/run1 --ckpt-every 50

Production posture baked in:
  * resume-from-latest on start (elastic: any mesh shape can restore);
  * async sharded checkpoints + SIGTERM preemption hook;
  * straggler monitor (sustained outliers trigger an early snapshot);
  * step-keyed deterministic data (resume == replay);
  * microbatch gradient accumulation + optional int8 gradient
    compression with error feedback;
  * donated train state (no double residency).

On this CPU container you run the smoke presets; on a pod the same
driver runs the full configs with ``--mesh production``.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro import configs
from repro.data.pipeline import DataConfig, make_source
from repro.distributed import (StragglerMonitor, ef_compress,
                               init_error_feedback)
from repro.launch import mesh as mesh_mod
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import Model
from repro.optim import (OptimizerConfig, init_train_state, make_train_step)
from repro.sharding import PolicyOptions, ShardingPolicy


def build(args) -> Dict[str, Any]:
    cfg = (configs.get_smoke(args.arch) if args.preset == "smoke"
           else configs.get(args.arch))
    if args.mesh == "production":
        mesh = make_production_mesh()
    else:
        mesh = make_host_mesh(data=args.data_par, model=args.model_par)
    policy = ShardingPolicy(mesh, cfg, PolicyOptions(remat=args.remat))
    model = Model(cfg, remat=args.remat, policy=policy)
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=args.warmup)
    return dict(cfg=cfg, mesh=mesh, policy=policy, model=model,
                opt_cfg=opt_cfg)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--mesh", default="host", choices=["host", "production"])
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    parts = build(args)
    cfg, mesh, policy, model = (parts["cfg"], parts["mesh"],
                                parts["policy"], parts["model"])
    opt_cfg = parts["opt_cfg"]

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.global_batch, seed=args.seed)
    source = make_source(data_cfg)

    with mesh_mod.set_mesh(mesh):
        state = init_train_state(model, jax.random.key(args.seed), opt_cfg)
        step_fn = make_train_step(model, opt_cfg)

        if args.grad_compression == "int8_ef":
            base_loss = model.loss

            def step_fn(state, batch):  # noqa: F811 - compressed variant
                def loss_fn(p):
                    return base_loss(p, batch)
                loss, grads = jax.value_and_grad(loss_fn)(state["params"])
                grads, new_ef = ef_compress(grads, state["ef"])
                from repro.optim import apply_update
                new_params, new_opt, metrics = apply_update(
                    state["params"], grads, state["opt"], state["step"],
                    opt_cfg)
                return ({"params": new_params, "opt": new_opt,
                         "step": state["step"] + 1, "ef": new_ef},
                        dict(metrics, loss=loss))

            state["ef"] = init_error_feedback(state["params"])

        start_step = 0
        checkpointer: Optional[ckpt.AsyncCheckpointer] = None
        if args.ckpt_dir:
            checkpointer = ckpt.AsyncCheckpointer(args.ckpt_dir)
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                state, start_step, _ = ckpt.restore(args.ckpt_dir, state)
                state = jax.tree.map(jnp.asarray, state)
                print(f"resumed from step {start_step}")

        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        monitor = StragglerMonitor()
        metrics_log = []

        if checkpointer is not None:
            checkpointer.install_preemption_hook(
                lambda: (int(np.asarray(jax.device_get(state["step"]))),
                         state))

        for step in range(start_step, args.steps):
            batch_np = source.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            monitor.start()
            state, metrics = jit_step(state, batch)
            loss = float(np.asarray(jax.device_get(metrics["loss"])))
            ev = monitor.stop(step)
            if ev is not None:
                print(f"[straggler] step {ev.step}: {ev.duration_s:.2f}s "
                      f"({ev.ratio:.1f}x median)")
            if monitor.should_checkpoint and checkpointer is not None:
                checkpointer.save_async(step + 1, state)
            if step % args.log_every == 0 or step == args.steps - 1:
                gn = float(np.asarray(jax.device_get(metrics["grad_norm"])))
                print(f"step {step:5d} loss {loss:.4f} gnorm {gn:.3f}",
                      flush=True)
            metrics_log.append({"step": step, "loss": loss})
            if (checkpointer is not None and args.ckpt_every
                    and (step + 1) % args.ckpt_every == 0):
                checkpointer.save_async(step + 1, state)

        if checkpointer is not None:
            checkpointer.save_async(args.steps, state)
            checkpointer.wait()

    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(metrics_log, f)
    first = np.mean([m["loss"] for m in metrics_log[:5]])
    last = np.mean([m["loss"] for m in metrics_log[-5:]])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
