"""Modeled-vs-measured cost attribution (DESIGN.md §12).

Runs a model through the **stage-timed executor**
(``make_executor(stage_timed=True)``: one jitted sub-closure per DAG
stage, ``block_until_ready`` between stages), joins the measured
per-stage wall microseconds against the analytical cost models —
Table-1 latency, modeled DDR bytes, row-band VMEM working sets
(:func:`repro.core.resources.modeled_stage_costs`) — and emits
``BENCH_profile.json`` with per-stage model-vs-wall ratios and a
Spearman rank-correlation summary.  That correlation is the
calibration signal the measured-cost DSE item needs: a model that
rank-orders stages like the wall clock does can steer the search even
when its absolute scale is off (the wall here is a CPU interpret-mode
proxy, so *ranks*, not ratios, are the honest comparison).

Also exports the span trace (stage spans from the timed runs + any
guard/DSE/serve spans recorded in the process) as Chrome-trace JSON —
load ``trace.json`` in Perfetto or chrome://tracing.

    PYTHONPATH=src python -m repro.launch.profile \
        --models resnet_tiny,googlenet_tiny --board ARRIA10 \
        --trace results/trace.json

The report refuses to ship partial coverage: every scheduled stage
must appear in both the measured and the modeled rows (CI smoke-runs
this on resnet_tiny and relies on that invariant).
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import pipeline as pipe
from repro.core import telemetry as tele
from repro.core.resources import FPGA_BOARDS, modeled_stage_costs
from repro.core.synthesis import CNN2Gate

PROFILE_MODELS = ("resnet_tiny", "googlenet_tiny", "mobilenet_tiny",
                  "squeezenet_tiny", "tiny_cnn", "alexnet")


def _ranks(v: np.ndarray) -> np.ndarray:
    """Average ranks (1-based, ties share their mean rank)."""
    v = np.asarray(v, np.float64)
    order = np.argsort(v, kind="stable")
    ranks = np.empty(len(v), np.float64)
    sv = v[order]
    i = 0
    while i < len(v):
        j = i
        while j + 1 < len(v) and sv[j + 1] == sv[i]:
            j += 1
        ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    return ranks


def spearman(a: Sequence[float], b: Sequence[float]) -> Optional[float]:
    """Spearman rank correlation (None when undefined: fewer than two
    points, or one side constant)."""
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    if len(a) < 2 or len(a) != len(b):
        return None
    ra, rb = _ranks(a), _ranks(b)
    if ra.std() == 0.0 or rb.std() == 0.0:
        return None
    return float(np.corrcoef(ra, rb)[0, 1])


def profile_model(name: str, board: str = "ARRIA10", n_i: int = 16,
                  n_l: int = 32, block_h: Optional[int] = None,
                  iters: int = 3, warmup: int = 1, seed: int = 0,
                  tracer: Optional[tele.Tracer] = None) -> Dict:
    """Measure one model stage-by-stage and join against the analytical
    models.  Returns the per-model attribution document (the value
    stored under ``results[<name>]`` in ``BENCH_profile.json``)."""
    from repro.models import cnn

    tracer = tracer if tracer is not None else tele.get_tracer()
    graph = getattr(cnn, name)(batch=1)
    gate = CNN2Gate.from_graph(graph)
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(gate.parsed.input_shape) * 0.5
         ).astype(np.float32)
    gate.calibrate_quantization(x)

    ex = pipe.make_executor(gate.quantized, n_i, n_l, block_h=block_h,
                            interpret=True, stage_timed=True,
                            tracer=tracer)
    with tracer.span(f"profile.warmup:{name}", cat="profile"):
        for _ in range(max(warmup, 1)):   # compile every sub-closure
            ex(x)
    runs: List[List[Dict]] = []
    with tracer.span(f"profile.measure:{name}", cat="profile",
                     args={"iters": iters}):
        for _ in range(max(iters, 1)):
            _, timings = ex(x)
            runs.append(timings)

    # median wall per stage across iters (schedule order is identical
    # in every run — the stage program is static)
    measured: Dict[str, Dict] = {}
    for i, row in enumerate(runs[0]):
        walls = [r[i]["wall_us"] for r in runs]
        measured[row["stage"]] = {"kind": row["kind"],
                                  "wall_us": float(np.median(walls))}

    modeled = modeled_stage_costs(gate.parsed, FPGA_BOARDS[board],
                                  n_i, n_l, block_h=block_h,
                                  per_channel=gate.per_channel)
    missing = [s for s in modeled if s not in measured]
    if missing:
        raise RuntimeError(
            f"attribution report for {name!r} is missing measured "
            f"times for scheduled stages {missing} — the stage-timed "
            "executor and the schedule disagree")

    rows: List[Dict] = []
    for stage, cost in modeled.items():
        wall_us = measured[stage]["wall_us"]
        model_us = cost["model_s"] * 1e6
        rows.append({
            "stage": stage, "kind": cost["kind"],
            "wall_us": wall_us, "model_us": model_us,
            "t_compute_us": cost["t_compute_s"] * 1e6,
            "t_memory_us": cost["t_memory_s"] * 1e6,
            "ddr_bytes": cost["ddr_bytes"],
            "vmem_bytes": cost["vmem_bytes"],
            "macs": cost["macs"],
            "model_wall_ratio": (model_us / wall_us if wall_us > 0
                                 else None),
        })
    overhead = {s: m["wall_us"] for s, m in measured.items()
                if s not in modeled}          # ingress/egress pseudo-stages

    walls = [r["wall_us"] for r in rows]
    models = [r["model_us"] for r in rows]
    return {
        "board": board, "n_i": n_i, "n_l": n_l, "block_h": block_h,
        "iters": iters, "seed": seed,
        "stages": rows,
        "overhead_us": overhead,
        "summary": {
            "n_stages": len(rows),
            "wall_us_total": float(np.sum(walls)),
            "model_us_total": float(np.sum(models)),
            "rank_corr_model_vs_wall": spearman(models, walls),
            "rank_corr_macs_vs_wall": spearman(
                [r["macs"] for r in rows], walls),
            "rank_corr_ddr_vs_wall": spearman(
                [r["ddr_bytes"] for r in rows], walls),
        },
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Per-stage modeled-vs-measured cost attribution "
                    "(DESIGN.md §12)")
    ap.add_argument("--models", default="resnet_tiny,googlenet_tiny",
                    help=f"comma-separated subset of {PROFILE_MODELS}")
    ap.add_argument("--board", default="ARRIA10",
                    choices=sorted(FPGA_BOARDS))
    ap.add_argument("--n-i", type=int, default=16)
    ap.add_argument("--n-l", type=int, default=32)
    ap.add_argument("--block-h", type=int, default=None)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="results/trace.json",
                    help="Chrome-trace/Perfetto span export path")
    ap.add_argument("--no-bench-json", action="store_true",
                    help="skip writing the top-level BENCH_profile.json")
    args = ap.parse_args(argv)

    names = [m.strip() for m in args.models.split(",") if m.strip()]
    unknown = [m for m in names if m not in PROFILE_MODELS]
    if unknown:
        ap.error(f"unknown model(s) {unknown}; choose from "
                 f"{PROFILE_MODELS}")

    tracer = tele.get_tracer()
    results: Dict[str, Dict] = {}
    for name in names:
        doc = profile_model(name, board=args.board, n_i=args.n_i,
                            n_l=args.n_l, block_h=args.block_h,
                            iters=args.iters, warmup=args.warmup,
                            seed=args.seed, tracer=tracer)
        results[name] = doc
        s = doc["summary"]
        corr = s["rank_corr_model_vs_wall"]
        corr_txt = f"{corr:.3f}" if corr is not None else "n/a"
        print(f"[profile] {name}: {s['n_stages']} stages, "
              f"wall {s['wall_us_total']:.0f}us, "
              f"modeled {s['model_us_total']:.1f}us, "
              f"rank corr model-vs-wall {corr_txt}")
        worst = max(doc["stages"],
                    key=lambda r: r["wall_us"])
        print(f"[profile]   hottest stage: {worst['stage']} "
              f"({worst['kind']}) wall {worst['wall_us']:.0f}us, "
              f"modeled {worst['model_us']:.2f}us, "
              f"ddr {worst['ddr_bytes']}B, vmem {worst['vmem_bytes']}B")

    # the process observability payload rides along: DSE robustness
    # counters, guard outcomes, serve histograms — whatever ran here
    payload = {"models": results,
               "telemetry": tele.get_registry().snapshot()}
    if not args.no_bench_json:
        from benchmarks.common import write_bench_json
        path = write_bench_json("profile", payload)
        print(f"[profile] wrote {path}")
    if args.trace:
        print(f"[profile] wrote {tracer.export(args.trace)} "
              f"({len(tracer.events())} spans)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
