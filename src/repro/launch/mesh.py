"""Production mesh construction + jax version-compat shims.

Kept as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialisation.

This module is also the single home of the jax 0.4.x/0.5+/0.6+ API
compatibility layer the launch and sharding paths (and the tests) go
through instead of calling the moving jax surface directly:

  * :func:`make_compat_mesh` — ``jax.make_mesh`` with ``axis_types``
    passed only when both the ``AxisType`` enum *and* the kwarg exist
    (``jax.sharding.AxisType`` appeared in jax 0.5; on 0.4.x meshes
    are implicitly Auto on every axis, which is exactly what passing
    ``AxisType.Auto`` requests on newer versions);
  * :func:`set_mesh` — ``jax.set_mesh`` (0.6+) falling back to the
    legacy ``with mesh:`` resource-env context manager, which is what
    ``set_mesh`` replaced;
  * :func:`shard_map` — ``jax.shard_map`` (0.6+, ``check_vma``)
    falling back to ``jax.experimental.shard_map.shard_map`` (0.4.x,
    ``check_rep`` — the same flag under its pre-varying-manual-axes
    name).
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.5 explicit-sharding API; absent on older runtimes
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _make_mesh_accepts_axis_types() -> bool:
    """``axis_types=`` landed in ``jax.make_mesh`` after the enum
    itself; inspect the signature so an enum-but-no-kwarg jax never
    raises TypeError at call time."""
    try:
        return "axis_types" in inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):  # pragma: no cover - builtin/odd repr
        return False


def _axis_type_kwargs(n_axes: int):
    if AxisType is None or not _make_mesh_accepts_axis_types():
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_compat_mesh(shape, names):
    """Version-portable ``jax.make_mesh``: explicit Auto axis types on
    jax versions that have them, plain mesh (implicitly Auto) on 0.4.x.
    Every mesh the launch path or the test suite builds goes through
    here — constructing ``AxisType`` directly is what broke the seed
    suite on jax 0.4.37."""
    shape = tuple(int(s) for s in shape)
    names = tuple(names)
    return jax.make_mesh(shape, names, **_axis_type_kwargs(len(names)))


def set_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh: the modern
    ``jax.set_mesh`` where it exists, else the legacy resource-env
    context (``with mesh:``) it replaced."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Version-portable ``shard_map`` with replication checking off by
    default (the repo's callers all pass explicit out_specs)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _legacy
    return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check)


def jit_shardings(mesh, tree):
    """Map a tree of ``PartitionSpec`` leaves onto ``NamedSharding``
    for ``jax.jit(in_shardings=...)``.  Newer jax resolves bare specs
    against the ambient mesh; 0.4.x only accepts ``Sharding``
    instances — explicit ``NamedSharding`` is the portable spelling."""
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree, is_leaf=lambda x: isinstance(x, PartitionSpec))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; the multi-pod mesh adds a leading
    'pod' axis (2 pods = 512 chips).  'pod' is an outer data-parallel
    axis: scaling to N pods only grows this axis (elastic by
    construction — see DESIGN.md §5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return make_compat_mesh((data, model), ("data", "model"))
