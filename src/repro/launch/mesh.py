"""Production mesh construction.

Kept as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax
import numpy as np

try:  # jax >= 0.5 explicit-sharding API; absent on older runtimes
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _axis_type_kwargs(n_axes: int):
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; the multi-pod mesh adds a leading
    'pod' axis (2 pods = 512 chips).  'pod' is an outer data-parallel
    axis: scaling to N pods only grows this axis (elastic by
    construction — see DESIGN.md §5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_type_kwargs(2))
