import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Performance hillclimbing driver (§Perf of EXPERIMENTS.md).

Runs named iteration configurations against a chosen (arch × shape)
cell and records the roofline terms before/after, so the
hypothesis → change → measure → validate log is reproducible:

    PYTHONPATH=src python -m repro.launch.perf --cell qwen2.5-32b/train_4k \
        --iter baseline --iter micro8 ... --out results/perf.json
"""
import argparse
import json
from typing import Any, Dict

from repro.launch.dryrun import lower_cell
from repro.sharding import PolicyOptions

# named iteration configurations: (PolicyOptions kwargs, cfg_override,
# flash_accounting)
ITERATIONS: Dict[str, Dict[str, Any]] = {
    # paper-faithful baseline: remat=dots, plain DP+TP, chunked attention
    "baseline": dict(),
    # activation-memory attack
    "micro4": dict(policy=dict(n_micro=4)),
    "micro8": dict(policy=dict(n_micro=8)),
    "micro16": dict(policy=dict(n_micro=16)),
    "seqpar": dict(policy=dict(sequence_parallel=True)),
    "seqpar_micro8": dict(policy=dict(sequence_parallel=True, n_micro=8)),
    "remat_full": dict(policy=dict(remat="full")),
    "remat_none": dict(policy=dict(remat="none")),
    "remat_full_micro8": dict(policy=dict(remat="full", n_micro=8)),
    "seqpar_remat_full_micro8": dict(policy=dict(
        sequence_parallel=True, remat="full", n_micro=8)),
    # attention-memory attack: Pallas flash kernel accounting
    "flash": dict(flash=True),
    "flash_seqpar_micro8": dict(policy=dict(sequence_parallel=True,
                                            n_micro=8), flash=True),
    "flash_seqpar": dict(policy=dict(sequence_parallel=True), flash=True),
    "flash_micro8": dict(policy=dict(n_micro=8), flash=True),
    "flash_seqpar_micro16": dict(policy=dict(sequence_parallel=True,
                                             n_micro=16), flash=True),
    "flash_seqpar_micro4": dict(policy=dict(sequence_parallel=True,
                                            n_micro=4), flash=True),
    # ZeRO-2: reduce-scatter grads into the optimizer-shard layout
    "flash_micro8_zero2": dict(policy=dict(n_micro=8, zero2_grads=True),
                               flash=True),
    "flash_micro16_zero2": dict(policy=dict(n_micro=16, zero2_grads=True),
                                flash=True),
    "flash_seqpar_zero2": dict(policy=dict(sequence_parallel=True,
                                           zero2_grads=True), flash=True),
    "flash_micro16_zero2_rematfull": dict(
        policy=dict(n_micro=16, zero2_grads=True, remat="full"),
        flash=True),
    "flash_micro8_zero2_rematfull": dict(
        policy=dict(n_micro=8, zero2_grads=True, remat="full"),
        flash=True),
    # chunk-size sweeps (memory/compute balance of chunked attention)
    "chunk512": dict(cfg=dict(attention_chunk=512)),
    "chunk2048": dict(cfg=dict(attention_chunk=2048)),
    # MoE routing-group bound (dispatch cost linearisation)
    "moegroup4k": dict(cfg=dict(moe_group_size=4096)),
    "moegroup2k": dict(cfg=dict(moe_group_size=2048)),
    "moegroup4k_flash": dict(cfg=dict(moe_group_size=4096), flash=True),
    "moegroup2k_flash": dict(cfg=dict(moe_group_size=2048), flash=True),
    "moegroup4k_flash_seqpar": dict(cfg=dict(moe_group_size=4096),
                                    policy=dict(sequence_parallel=True),
                                    flash=True),
    # turn off TP (pure DP) / activation-head sharding ablations
    "no_head_shard": dict(policy=dict(shard_activation_heads=False)),
    "no_seq_shard_decode": dict(policy=dict(seq_shard_decode=False)),
}


def run_iteration(arch: str, shape: str, name: str) -> Dict[str, Any]:
    spec = ITERATIONS[name]
    options = PolicyOptions(**spec.get("policy", {}))
    _compiled, meta = lower_cell(
        arch, shape, options=options,
        cfg_override=spec.get("cfg"),
        flash_accounting=spec.get("flash", False))
    meta["iteration"] = name
    return meta


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch/shape")
    ap.add_argument("--iter", action="append", default=[])
    ap.add_argument("--out", default="results/perf.json")
    args = ap.parse_args()
    arch, shape = args.cell.split("/")
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    for name in (args.iter or ["baseline"]):
        key = f"{arch}|{shape}|{name}"
        print(f"=== {key} ===", flush=True)
        meta = run_iteration(arch, shape, name)
        results[key] = meta
        print(json.dumps({k: meta[k] for k in
                          ("t_compute", "t_memory_fused", "t_collective",
                           "dominant", "t_step", "roofline_fraction",
                           "peak_bytes_per_dev")}, default=float),
              flush=True)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=float)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
