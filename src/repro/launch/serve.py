"""Batched serving driver: continuous-batching decode loop.

A fixed pool of sequence slots; finished sequences release their slot
and queued requests claim it (their prompt is prefilled into the slot's
cache region).  Per-slot lengths drive the masked decode attention, so
heterogeneous sequence lengths coexist in one batch — the standard
continuous-batching pattern, expressed functionally.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --preset smoke --slots 4 --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import telemetry as tele
from repro.launch import mesh as mesh_mod
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.sharding import PolicyOptions, ShardingPolicy


class Request:
    def __init__(self, rid: int, prompt: np.ndarray, max_new: int,
                 deadline_s: Optional[float] = None):
        self.rid = rid
        self.prompt = prompt
        self.max_new = max_new
        self.deadline_s = deadline_s
        self.submitted_at: Optional[float] = None
        self.span_ts_us: Optional[float] = None   # tracer-epoch submit time
        self.output: List[int] = []
        self.done = False
        self.rejected = False          # shed at admission (queue full)
        self.expired = False           # deadline passed before completion

    def past_deadline(self, now: float) -> bool:
        return (self.deadline_s is not None
                and self.submitted_at is not None
                and now - self.submitted_at > self.deadline_s)


class Server:
    """Slot-based continuous batching engine.

    Admission is bounded: at most ``max_queue`` requests wait for a
    slot; past that, ``submit`` sheds the request (returns ``False``,
    marks it ``rejected``) instead of growing the queue without limit.
    A request carrying ``deadline_s`` is dropped — queued or mid-decode
    — once its deadline passes (``expired``), freeing its slot for
    requests that can still be served in time.

    A deployment running guarded executors (core/guard.py) next to the
    engine reports each inference's :class:`GuardReport` through
    :meth:`record_guard_report`; the per-outcome counters (clean /
    checkpoint_replayed / reexecuted / fell_back / unrecovered, plus
    ``masked`` for campaign-classified upsets the audit cannot see)
    surface in :meth:`stats` next to the admission counters."""

    #: every guarded-execution outcome the stats payload reports.
    #: ``masked`` is never emitted by a live GuardReport (an upset the
    #: audit never saw is invisible online); it is fed by offline SER
    #: campaign classification (core/ser.py) when a deployment replays
    #: campaign verdicts into its counters.
    GUARD_OUTCOMES = ("clean", "checkpoint_replayed", "reexecuted",
                      "fell_back", "unrecovered", "masked")

    def __init__(self, model: Model, params, slots: int, cache_len: int,
                 max_queue: int = 64,
                 registry: Optional[tele.MetricsRegistry] = None,
                 tracer: Optional[tele.Tracer] = None):
        self.model = model
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.max_queue = max_queue
        self.cache = model.init_cache(slots, cache_len)
        self.lengths = np.zeros((slots,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.rejected = 0
        self.expired = 0
        self.guard_outcomes: Dict[str, int] = {
            k: 0 for k in self.GUARD_OUTCOMES}
        self._decode = jax.jit(model.decode_step)
        # telemetry (DESIGN.md §12): per-request spans, a queue-depth
        # gauge and an end-to-end latency histogram — p50/p95/p99 and
        # tokens/s in stats() derive from these
        self._registry = registry if registry is not None \
            else tele.get_registry()
        self._tracer = tracer if tracer is not None else tele.get_tracer()
        self._latency = self._registry.histogram("serve.request_latency_s")
        self._tokens = self._registry.counter("serve.tokens")
        self._queue_depth = self._registry.gauge("serve.queue_depth")
        self._active_slots = self._registry.gauge("serve.active_slots")
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    def _finish(self, req: Request, outcome: str) -> None:
        """Single completion point: every request that was admitted
        leaves through here exactly once (completed or expired), so the
        latency histogram and the per-request span can't drift from the
        admission counters."""
        req.done = True
        now = time.monotonic()
        self._t_last = now
        if req.submitted_at is not None:
            latency = now - req.submitted_at
            self._latency.record(latency)
            if req.span_ts_us is not None:
                self._tracer.add_span(
                    f"serve.request:{req.rid}", req.span_ts_us,
                    latency * 1e6, cat="serve",
                    args={"rid": req.rid, "outcome": outcome,
                          "tokens": len(req.output)})

    def record_guard_report(self, report) -> str:
        """Count one guarded inference's outcome (a
        :class:`~repro.core.guard.GuardReport` or a bare outcome
        string) into the stats payload; returns the outcome key."""
        outcome = getattr(report, "outcome", report)
        if outcome not in self.guard_outcomes:
            raise ValueError(f"unknown guard outcome {outcome!r} "
                             f"(expected one of {self.GUARD_OUTCOMES})")
        self.guard_outcomes[outcome] += 1
        return outcome

    def stats(self) -> Dict[str, Any]:
        """The server's observable-state payload: admission counters,
        occupancy, the guarded-execution outcome counters, and the
        telemetry-derived latency percentiles + throughput."""
        h = self._latency
        span = ((self._t_last - self._t_first)
                if self._t_first is not None and self._t_last is not None
                else 0.0)
        return {
            "rejected": self.rejected,
            "expired": self.expired,
            "queued": len(self.queue),
            "active": sum(r is not None for r in self.slot_req),
            "guard": dict(self.guard_outcomes),
            "latency_s": {"count": h.count, "mean": h.mean,
                          "p50": h.percentile(50),
                          "p95": h.percentile(95),
                          "p99": h.percentile(99)},
            "tokens": self._tokens.value,
            "tokens_per_s": (self._tokens.value / span if span > 0
                             else None),
        }

    def submit(self, req: Request) -> bool:
        if len(self.queue) >= self.max_queue:
            req.rejected = True
            req.done = True
            self.rejected += 1
            self._registry.counter("serve.rejected").inc()
            return False
        req.submitted_at = time.monotonic()
        if self._t_first is None:
            self._t_first = req.submitted_at
        req.span_ts_us = self._tracer.now_us()
        self.queue.append(req)
        self._queue_depth.set(len(self.queue))
        return True

    def _admit(self) -> None:
        now = time.monotonic()
        live = []
        for req in self.queue:
            if req.past_deadline(now):
                req.expired = True
                self.expired += 1
                self._registry.counter("serve.expired").inc()
                self._finish(req, "expired")
            else:
                live.append(req)
        self.queue = live
        self._queue_depth.set(len(self.queue))
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[s] = req
                # prefill the prompt into this slot, token by token via
                # decode steps (single-slot prefill keeps the example
                # simple; model.prefill covers the bulk path)
                self.lengths[s] = 0
                for tok in req.prompt[:-1]:
                    self._step_slot(s, int(tok))
                req.pending_token = int(req.prompt[-1])

    def _step_slot(self, s: int, token: int) -> int:
        """Advance a single slot by one token (batched with idle slots)."""
        tokens = np.zeros((self.slots, 1), np.int32)
        tokens[s, 0] = token
        logits, self.cache = self._decode(
            self.params,
            {"tokens": jnp.asarray(tokens),
             "lengths": jnp.asarray(self.lengths)},
            self.cache)
        self.lengths[s] += 1
        return int(np.asarray(logits[s, -1]).argmax())

    def step(self) -> None:
        """One decode step across all active slots (true batching)."""
        self._admit()
        now = time.monotonic()
        for s, req in enumerate(self.slot_req):
            if req is not None and req.past_deadline(now):
                req.expired = True
                self.slot_req[s] = None
                self.lengths[s] = 0
                self.expired += 1
                self._registry.counter("serve.expired").inc()
                self._finish(req, "expired")
        self._active_slots.set(sum(r is not None for r in self.slot_req))
        tokens = np.zeros((self.slots, 1), np.int32)
        active = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            tokens[s, 0] = (req.pending_token if req.output == []
                            else req.output[-1])
            active.append(s)
        if not active:
            return
        logits, self.cache = self._decode(
            self.params,
            {"tokens": jnp.asarray(tokens),
             "lengths": jnp.asarray(self.lengths)},
            self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for s in active:
            req = self.slot_req[s]
            self.lengths[s] += 1
            req.output.append(int(nxt[s]))
            self._tokens.inc()
            if (len(req.output) >= req.max_new
                    or self.lengths[s] >= self.cache_len - 1):
                self.slot_req[s] = None
                self.lengths[s] = 0
                self._registry.counter("serve.completed").inc()
                self._finish(req, "completed")

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-queue", type=int, default=64,
                    help="admission bound: submissions past this many "
                         "queued requests are shed")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline; late requests are "
                         "dropped instead of completing")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.preset == "smoke"
           else configs.get(args.arch))
    mesh = make_host_mesh()
    policy = ShardingPolicy(mesh, cfg, PolicyOptions(seq_shard_decode=False))
    model = Model(cfg, policy=policy)
    rng = np.random.default_rng(args.seed)
    with mesh_mod.set_mesh(mesh):
        params = model.init(jax.random.key(args.seed))
        server = Server(model, params, args.slots, args.cache_len,
                        max_queue=args.max_queue)
        reqs = [Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len),
                        args.max_new, deadline_s=args.deadline_s)
                for i in range(args.requests)]
        for r in reqs:
            server.submit(r)
        t0 = time.perf_counter()
        steps = 0
        while server.busy:
            server.step()
            steps += 1
            if steps > args.requests * (args.prompt_len + args.max_new) + 64:
                raise RuntimeError("serving loop did not converge")
        dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, {steps} engine steps)")
    stats = server.stats()
    lat = stats["latency_s"]

    def _ms(v):
        return f"{v * 1e3:.1f}ms" if v is not None else "n/a"

    tps = stats["tokens_per_s"]
    print(f"latency: p50={_ms(lat['p50'])} p95={_ms(lat['p95'])} "
          f"p99={_ms(lat['p99'])} over {lat['count']} requests; "
          "telemetry tokens/s="
          f"{f'{tps:.1f}' if tps is not None else 'n/a'}")
    if server.rejected or server.expired:
        print(f"admission: rejected={stats['rejected']} "
              f"expired={stats['expired']}")
    if any(stats["guard"].values()):
        print("guard: " + " ".join(f"{k}={v}" for k, v
                                   in stats["guard"].items() if v))
    assert all(r.done for r in reqs)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
