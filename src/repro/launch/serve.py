"""Batched serving driver: continuous-batching decode loop.

A fixed pool of sequence slots; finished sequences release their slot
and queued requests claim it (their prompt is prefilled into the slot's
cache region).  Per-slot lengths drive the masked decode attention, so
heterogeneous sequence lengths coexist in one batch — the standard
continuous-batching pattern, expressed functionally.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --preset smoke --slots 4 --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import mesh as mesh_mod
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.sharding import PolicyOptions, ShardingPolicy


class Request:
    def __init__(self, rid: int, prompt: np.ndarray, max_new: int,
                 deadline_s: Optional[float] = None):
        self.rid = rid
        self.prompt = prompt
        self.max_new = max_new
        self.deadline_s = deadline_s
        self.submitted_at: Optional[float] = None
        self.output: List[int] = []
        self.done = False
        self.rejected = False          # shed at admission (queue full)
        self.expired = False           # deadline passed before completion

    def past_deadline(self, now: float) -> bool:
        return (self.deadline_s is not None
                and self.submitted_at is not None
                and now - self.submitted_at > self.deadline_s)


class Server:
    """Slot-based continuous batching engine.

    Admission is bounded: at most ``max_queue`` requests wait for a
    slot; past that, ``submit`` sheds the request (returns ``False``,
    marks it ``rejected``) instead of growing the queue without limit.
    A request carrying ``deadline_s`` is dropped — queued or mid-decode
    — once its deadline passes (``expired``), freeing its slot for
    requests that can still be served in time.

    A deployment running guarded executors (core/guard.py) next to the
    engine reports each inference's :class:`GuardReport` through
    :meth:`record_guard_report`; the per-outcome counters (clean /
    checkpoint_replayed / reexecuted / fell_back / unrecovered, plus
    ``masked`` for campaign-classified upsets the audit cannot see)
    surface in :meth:`stats` next to the admission counters."""

    #: every guarded-execution outcome the stats payload reports.
    #: ``masked`` is never emitted by a live GuardReport (an upset the
    #: audit never saw is invisible online); it is fed by offline SER
    #: campaign classification (core/ser.py) when a deployment replays
    #: campaign verdicts into its counters.
    GUARD_OUTCOMES = ("clean", "checkpoint_replayed", "reexecuted",
                      "fell_back", "unrecovered", "masked")

    def __init__(self, model: Model, params, slots: int, cache_len: int,
                 max_queue: int = 64):
        self.model = model
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.max_queue = max_queue
        self.cache = model.init_cache(slots, cache_len)
        self.lengths = np.zeros((slots,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.rejected = 0
        self.expired = 0
        self.guard_outcomes: Dict[str, int] = {
            k: 0 for k in self.GUARD_OUTCOMES}
        self._decode = jax.jit(model.decode_step)

    def record_guard_report(self, report) -> str:
        """Count one guarded inference's outcome (a
        :class:`~repro.core.guard.GuardReport` or a bare outcome
        string) into the stats payload; returns the outcome key."""
        outcome = getattr(report, "outcome", report)
        if outcome not in self.guard_outcomes:
            raise ValueError(f"unknown guard outcome {outcome!r} "
                             f"(expected one of {self.GUARD_OUTCOMES})")
        self.guard_outcomes[outcome] += 1
        return outcome

    def stats(self) -> Dict[str, Any]:
        """The server's observable-state payload: admission counters,
        occupancy, and the guarded-execution outcome counters."""
        return {
            "rejected": self.rejected,
            "expired": self.expired,
            "queued": len(self.queue),
            "active": sum(r is not None for r in self.slot_req),
            "guard": dict(self.guard_outcomes),
        }

    def submit(self, req: Request) -> bool:
        if len(self.queue) >= self.max_queue:
            req.rejected = True
            req.done = True
            self.rejected += 1
            return False
        req.submitted_at = time.monotonic()
        self.queue.append(req)
        return True

    def _admit(self) -> None:
        now = time.monotonic()
        live = []
        for req in self.queue:
            if req.past_deadline(now):
                req.expired = True
                req.done = True
                self.expired += 1
            else:
                live.append(req)
        self.queue = live
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[s] = req
                # prefill the prompt into this slot, token by token via
                # decode steps (single-slot prefill keeps the example
                # simple; model.prefill covers the bulk path)
                self.lengths[s] = 0
                for tok in req.prompt[:-1]:
                    self._step_slot(s, int(tok))
                req.pending_token = int(req.prompt[-1])

    def _step_slot(self, s: int, token: int) -> int:
        """Advance a single slot by one token (batched with idle slots)."""
        tokens = np.zeros((self.slots, 1), np.int32)
        tokens[s, 0] = token
        logits, self.cache = self._decode(
            self.params,
            {"tokens": jnp.asarray(tokens),
             "lengths": jnp.asarray(self.lengths)},
            self.cache)
        self.lengths[s] += 1
        return int(np.asarray(logits[s, -1]).argmax())

    def step(self) -> None:
        """One decode step across all active slots (true batching)."""
        self._admit()
        now = time.monotonic()
        for s, req in enumerate(self.slot_req):
            if req is not None and req.past_deadline(now):
                req.expired = True
                req.done = True
                self.slot_req[s] = None
                self.lengths[s] = 0
                self.expired += 1
        tokens = np.zeros((self.slots, 1), np.int32)
        active = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            tokens[s, 0] = (req.pending_token if req.output == []
                            else req.output[-1])
            active.append(s)
        if not active:
            return
        logits, self.cache = self._decode(
            self.params,
            {"tokens": jnp.asarray(tokens),
             "lengths": jnp.asarray(self.lengths)},
            self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for s in active:
            req = self.slot_req[s]
            self.lengths[s] += 1
            req.output.append(int(nxt[s]))
            if (len(req.output) >= req.max_new
                    or self.lengths[s] >= self.cache_len - 1):
                req.done = True
                self.slot_req[s] = None
                self.lengths[s] = 0

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-queue", type=int, default=64,
                    help="admission bound: submissions past this many "
                         "queued requests are shed")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline; late requests are "
                         "dropped instead of completing")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.preset == "smoke"
           else configs.get(args.arch))
    mesh = make_host_mesh()
    policy = ShardingPolicy(mesh, cfg, PolicyOptions(seq_shard_decode=False))
    model = Model(cfg, policy=policy)
    rng = np.random.default_rng(args.seed)
    with mesh_mod.set_mesh(mesh):
        params = model.init(jax.random.key(args.seed))
        server = Server(model, params, args.slots, args.cache_len,
                        max_queue=args.max_queue)
        reqs = [Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len),
                        args.max_new, deadline_s=args.deadline_s)
                for i in range(args.requests)]
        for r in reqs:
            server.submit(r)
        t0 = time.perf_counter()
        steps = 0
        while server.busy:
            server.step()
            steps += 1
            if steps > args.requests * (args.prompt_len + args.max_new) + 64:
                raise RuntimeError("serving loop did not converge")
        dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, {steps} engine steps)")
    stats = server.stats()
    if server.rejected or server.expired:
        print(f"admission: rejected={stats['rejected']} "
              f"expired={stats['expired']}")
    if any(stats["guard"].values()):
        print("guard: " + " ".join(f"{k}={v}" for k, v
                                   in stats["guard"].items() if v))
    assert all(r.done for r in reqs)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
