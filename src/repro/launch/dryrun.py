import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-compile every (arch × shape × mesh) cell.

For each cell:  jit(step).lower(**input_specs).compile()  against the
production mesh — proving the sharding config is coherent (no mismatch,
no compile-OOM, collectives legal), then record memory_analysis /
cost_analysis / parsed-collective roofline terms to JSON for
EXPERIMENTS.md and the benchmarks.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape train_4k --mesh single --out results/dryrun.json

The XLA_FLAGS line above MUST precede any jax import (device count
locks at first init); smoke tests / benches never import this module.
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs, roofline
from repro.configs.base import ALL_SHAPES
from repro.launch import mesh as mesh_mod
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim import OptimizerConfig, make_train_step
from repro.sharding import PolicyOptions, ShardingPolicy


def _spec_train_state(model: Model, policy: ShardingPolicy):
    """Shape-only train state + shardings (no allocation)."""
    opt_cfg = OptimizerConfig()
    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pspecs = policy.param_specs(params_shape)

    def opt_like(ps, sh):
        return jax.tree.map(
            lambda spec, leaf: policy.optimizer_spec(spec, leaf.shape),
            ps, sh, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    master32 = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), params_shape)
    state_shape = {
        "params": params_shape,
        "opt": {"master": master32, "mu": master32, "nu": master32},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    ospec = opt_like(pspecs, params_shape)
    state_spec = {
        "params": pspecs,
        "opt": {"master": ospec, "mu": ospec, "nu": ospec},
        "step": jax.sharding.PartitionSpec(),
    }
    return state_shape, state_spec, opt_cfg


def _compile_step(cfg, shape, mesh, options, batch_override=None):
    """Lower + compile one program for a given config (any depth)."""
    policy = ShardingPolicy(mesh, cfg, options)
    model = Model(cfg, remat=options.remat, policy=policy)
    specs = model.input_specs(shape, batch_override=batch_override)
    with mesh_mod.set_mesh(mesh):
        if shape.kind == "train":
            state_shape, state_spec, opt_cfg = _spec_train_state(model, policy)
            grad_spec = (state_spec["opt"]["mu"] if options.zero2_grads
                         else None)
            step_fn = make_train_step(model, opt_cfg,
                                      n_micro=options.n_micro,
                                      grad_spec=grad_spec,
                                      act_constraint=policy.act)
            batch_specs = policy.batch_specs(specs, shape)
            lowered = jax.jit(
                step_fn,
                in_shardings=mesh_mod.jit_shardings(
                    mesh, (state_spec, batch_specs)),
                donate_argnums=(0,),
            ).lower(state_shape, specs)
        elif shape.kind == "prefill":
            params_shape = jax.eval_shape(
                lambda: model.init(jax.random.key(0)))
            pspecs = policy.param_specs(params_shape)
            batch_specs = policy.batch_specs(specs, shape)

            def prefill_fn(params, batch):
                return model.prefill(params, batch, cache_len=shape.seq_len)

            lowered = jax.jit(
                prefill_fn,
                in_shardings=mesh_mod.jit_shardings(
                    mesh, (pspecs, batch_specs)),
            ).lower(params_shape, specs)
        else:  # decode
            params_shape = jax.eval_shape(
                lambda: model.init(jax.random.key(0)))
            pspecs = policy.param_specs(params_shape)
            cache_shape = specs.pop("cache")
            batch_specs = policy.batch_specs(
                dict(specs, cache=cache_shape), shape)
            cache_specs = batch_specs.pop("cache")

            def decode_fn(params, batch, cache):
                return model.decode_step(params, batch, cache)

            lowered = jax.jit(
                decode_fn,
                in_shardings=mesh_mod.jit_shardings(
                    mesh, (pspecs, batch_specs, cache_specs)),
                donate_argnums=(2,),
            ).lower(params_shape, specs, cache_shape)
    return lowered.compile()


def _depth_cfg(cfg, k: int):
    """Reduced-depth variant with identical width/shapes, and the scale
    factor back to full depth."""
    import dataclasses
    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every or cfg.n_layers
        return (dataclasses.replace(cfg, n_layers=every * k,
                                    scan_unroll=True),
                cfg.n_layers // every)
    if cfg.family == "encdec":
        assert cfg.encoder_layers == cfg.n_layers
        return (dataclasses.replace(cfg, n_layers=k, encoder_layers=k,
                                    scan_unroll=True), cfg.n_layers)
    return dataclasses.replace(cfg, n_layers=k, scan_unroll=True), cfg.n_layers


def _costs(compiled, exclude_trailing=None) -> Dict[str, float]:
    ca = roofline.cost_analysis_dict(compiled)
    text = compiled.as_text()
    stats = roofline.parse_collectives(text)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "ess": roofline.essential_bytes(text, exclude_trailing),
        "coll": stats.total_bytes,
        "counts": stats.counts,
    }


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               options: Optional[PolicyOptions] = None,
               batch_override: Optional[int] = None,
               extrapolate: bool = True,
               cfg_override: Optional[Dict[str, Any]] = None,
               flash_accounting: bool = False):
    """Compile one cell; returns (compiled, meta dict).

    The full-depth program is compiled with depth *scans* (fast, proves
    the sharding and gives memory_analysis).  XLA cost_analysis counts
    while bodies ONCE (verified empirically), so FLOPs/bytes/collective
    bytes are recovered exactly by a two-point depth extrapolation:
    compile depth-1 and depth-2 variants fully *unrolled* (cheap) and
    solve  cost(L) = outside + L * per_layer.

    ``cfg_override``: ModelConfig field replacements (perf iterations).
    ``flash_accounting``: exclude (seq, chunk)-shaped score/probability
    tensors from the fused-memory bound — with the validated Pallas
    flash kernel those stay in VMEM and never round-trip HBM.
    """
    import dataclasses as _dc
    cfg = configs.get(arch)
    if cfg_override:
        cfg = _dc.replace(cfg, **cfg_override)
    shape = ALL_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    options = options or PolicyOptions()
    chips = mesh.devices.size
    mesh_name = "multi_pod" if multi_pod else "single_pod"

    exclude = None
    if flash_accounting:
        exclude = set()
        if cfg.attention_impl == "chunked":
            # attention score/probability tensors stay in VMEM inside
            # kernels/flash_attention.py
            sq = shape.seq_len if shape.kind != "decode" else 1
            exclude.add((sq, cfg.attention_chunk))
        if cfg.family in ("ssm", "hybrid"):
            # intra-chunk SSD score tensors stay in VMEM inside
            # kernels/ssd_scan.py
            exclude.add((cfg.ssm_chunk, cfg.ssm_chunk))
        exclude = exclude or None

    t0 = time.perf_counter()
    compiled = _compile_step(cfg, shape, mesh, options, batch_override)
    t_compile = time.perf_counter() - t0

    if extrapolate:
        cfg1, scale = _depth_cfg(cfg, 1)
        cfg2, _ = _depth_cfg(cfg, 2)
        c1 = _costs(_compile_step(cfg1, shape, mesh, options,
                                  batch_override), exclude)
        c2 = _costs(_compile_step(cfg2, shape, mesh, options,
                                  batch_override), exclude)
        def ext(key):
            return max(0.0, max(0.0, 2 * c1[key] - c2[key])
                       + scale * (c2[key] - c1[key]))

        flops, bytes_, ess, coll = (ext("flops"), ext("bytes"), ext("ess"),
                                    ext("coll"))
        counts = {
            k: int(max(0, 2 * c1["counts"].get(k, 0) - c2["counts"].get(k, 0))
                   + scale * (c2["counts"].get(k, 0) - c1["counts"].get(k, 0)))
            for k in set(c1["counts"]) | set(c2["counts"])}
    else:
        c = _costs(compiled, exclude)
        flops, bytes_, ess, coll, counts = (c["flops"], c["bytes"], c["ess"],
                                            c["coll"], c["counts"])

    try:
        ma = compiled.memory_analysis()
        peak = float(ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes)
    except Exception:
        ma, peak = None, 0.0

    # essential traffic: heavy-op bytes + entry args/outputs once
    ess_total = ess + (float(ma.argument_size_in_bytes
                             + ma.output_size_in_bytes) if ma else 0.0)
    rep = roofline.RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_dev=flops, bytes_per_dev=bytes_,
        collective_bytes_per_dev=coll,
        t_compute=flops / roofline.PEAK_FLOPS,
        t_memory=bytes_ / roofline.HBM_BW,
        t_collective=coll / (roofline.ICI_LINKS * roofline.ICI_LINK_BW),
        model_flops=roofline.model_flops_for(cfg, shape),
        peak_bytes_per_dev=peak,
        collective_counts={k: v for k, v in counts.items() if v},
        essential_bytes_per_dev=ess_total,
        t_memory_fused=ess_total / roofline.HBM_BW,
    )
    meta = rep.to_dict()
    meta.update(compile_s=round(t_compile, 2))
    if ma is not None:
        meta.update(arg_bytes=int(ma.argument_size_in_bytes),
                    out_bytes=int(ma.output_size_in_bytes),
                    temp_bytes=int(ma.temp_size_in_bytes))
    return compiled, meta


def cells(archs, shapes):
    for arch in archs:
        for shape in shapes:
            if configs.supports_shape(arch, shape):
                yield arch, shape


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--remat", default="dots",
                    choices=["none", "dots", "full"])
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--print-hlo", action="store_true")
    args = ap.parse_args()

    archs = list(configs.ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = list(ALL_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    options = PolicyOptions(remat=args.remat,
                            seq_shard_decode=not args.no_seq_shard)

    results: Dict[str, Any] = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    failures = []
    for arch, shape in cells(archs, shapes):
        for multi in meshes:
            key = f"{arch}|{shape}|{'multi_pod' if multi else 'single_pod'}"
            print(f"=== {key} ===", flush=True)
            try:
                # roofline extrapolation on the single-pod mesh only; the
                # multi-pod pass is the sharding-coherence proof
                compiled, meta = lower_cell(arch, shape, multi_pod=multi,
                                            options=options,
                                            extrapolate=not multi)
                results[key] = meta
                print(json.dumps(
                    {k: meta[k] for k in
                     ("t_compute", "t_memory", "t_memory_fused",
                      "t_collective", "dominant", "roofline_fraction",
                      "compile_s")},
                    default=float), flush=True)
                if args.print_hlo:
                    print(compiled.as_text()[:4000])
                del compiled
            except Exception as e:  # noqa: BLE001 - record and continue
                failures.append((key, repr(e)))
                traceback.print_exc()
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1, default=float)
    print(f"\n{len(results)} cells recorded -> {args.out}")
    if failures:
        print("FAILURES:")
        for k, e in failures:
            print(" ", k, e)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
