"""CNN2Gate automated high-level synthesis workflow (§4.2, Fig. 4a).

``CNN2Gate`` is the user-facing orchestrator:

    gate = CNN2Gate.from_graph(alexnet())          # ONNX-lite front end
    gate.apply_quantization(specs)                  # given (N, m) pairs
    fit  = gate.explore("ARRIA10", algo="rl")       # hardware-aware DSE
    run  = gate.build(mode="emulation")             # fast CPU verify
    y    = run(x)                                   # inference
    rep  = gate.latency_report("ARRIA10", *fit.best)  # Table-1 model

Modes:
  * ``emulation``  — CPU compile (seconds), Pallas kernels in interpret
    mode; functional verification exactly like the paper's OpenCL
    emulator (the paper stresses this loop: verify before the 10-hour
    synthesis).
  * ``fullflow``   — AOT ``jit(...).lower().compile()`` of the pipeline:
    the TPU-target "synthesis".  On a TPU machine this produces the real
    executable; here it produces the compiled CPU artifact and the
    resource report (our stand-in for the bitstream + fitter report).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cnn import collect_activations
from . import dse as dse_mod
from . import parser as P
from . import pipeline as pipe
from .graph import Graph
from .quantize import (MAX_SHIFT, QuantSpec, best_pow2_exponent,
                       best_pow2_exponents_per_channel)
from .resources import (FPGA_BOARDS, fpga_layer_time_s)
from .spaces import CNNDesignSpace


@dataclasses.dataclass
class LayerTiming:
    name: str
    kind: str
    time_s: float
    t_compute: float
    t_memory: float
    macs: int


@dataclasses.dataclass
class LatencyReport:
    board: str
    n_i: int
    n_l: int
    layers: List[LayerTiming]

    @property
    def total_s(self) -> float:
        return sum(l.time_s for l in self.layers)

    @property
    def gops(self) -> float:
        total_ops = 2 * sum(l.macs for l in self.layers)
        return total_ops / self.total_s / 1e9


class CNN2Gate:
    """Parse -> (apply quantization) -> explore -> build -> run."""

    def __init__(self, parsed: P.ParsedModel):
        self.parsed = parsed
        self.quantized: Optional[pipe.QuantizedModel] = None
        self.specs: Optional[Dict[str, QuantSpec]] = None

    # ---------------------------------------------------------- front end
    @classmethod
    def from_graph(cls, graph: Graph, fuse_skip: bool = True,
                   fuse_concat: bool = True) -> "CNN2Gate":
        """``fuse_skip=False`` keeps residual adds as standalone merge
        stages and ``fuse_concat=False`` keeps channel concats as
        standalone copies — the bit-exact fallback/benchmark baseline
        programs."""
        return cls(P.parse(graph, fuse_skip=fuse_skip,
                           fuse_concat=fuse_concat))

    @classmethod
    def from_file(cls, path: str) -> "CNN2Gate":
        from . import onnx_lite
        return cls.from_graph(onnx_lite.load(path))

    # ------------------------------------------------------- quantization
    def apply_quantization(self, specs: Dict[str, QuantSpec],
                           per_channel: Optional[bool] = None) -> None:
        """Apply *given* per-layer (N, m) pairs (§4.2 Physical domain).
        ``per_channel`` is forwarded to :func:`pipeline.build_quantized`
        (None: honour the specs as given)."""
        self.specs = specs
        self.quantized = pipe.build_quantized(self.parsed, specs,
                                              per_channel=per_channel)

    def calibrate_quantization(self, sample_input: np.ndarray,
                               per_channel: bool = False
                               ) -> Dict[str, QuantSpec]:
        """Convenience PTQ (stand-in for the user's external tool) — a
        graph pass over the DAG stage program, not a linear scan.

        Three passes (DESIGN.md §6):

        1. *stats* — max-abs power-of-two exponent for every named
           tensor in the stage program (from the float activations);
        2. *branch-aware alignment* — the operands of every int8
           ``Add``/``Concat`` must agree on fixed-point position
           (shift-only arithmetic cannot scale up), so merge operands
           form a scale group pinned at the group minimum; iterated to
           fixpoint because groups chain through stacked residuals;
        3. *forward threading* — walk the schedule: each weighted
           stage's ``m_x`` is its input tensor's position, ``m_y`` is
           capped at ``m_w + m_x`` (non-negative requant shift); pools
           pass scale through; merges emit a ``QuantSpec(0, m_common,
           m_y)`` whose requant shift is the post-add renormalisation.

        When a producer's ``m_y`` cap lands below its merge group's
        position, the executor's per-operand alignment shifts absorb
        the residual mismatch — alignment is an optimisation (it makes
        those shifts zero), not a correctness requirement.

        ``per_channel=True`` computes **per-output-channel** weight
        exponents (``m_w`` becomes a length-Cout tuple, the max-abs
        rule applied per Cout slice — DESIGN.md §8): each lane
        quantizes at its own power of two and the band epilogues apply
        a per-lane shift vector.  Activations (``m_x``/``m_y``) stay
        per-tensor, so every merge/alignment rule below is unchanged;
        the ``m_y <= m_w + m_x`` non-negative-shift cap simply uses
        the *minimum* lane exponent (every lane's shift must stay
        representable).  Per-tensor calibration is the default.
        """
        pm = self.parsed
        acts = collect_activations(pm.graph, sample_input)
        acts[pm.input_name] = np.asarray(sample_input)
        weights = pm.graph.initializers

        # pass 1: per-tensor desired positions from activation stats
        # (conv stages with a folded residual add still thread their
        # intermediate tensor — it lives on in li.merge.inputs)
        desired: Dict[str, int] = {}
        for li in pm.layers:
            tensors = list(li.inputs) + [li.output]
            if li.merge is not None:
                tensors += list(li.merge.inputs) + [li.merge.output]
            for t in tensors:
                if t not in desired:
                    desired[t] = best_pow2_exponent(acts[t])
        desired.setdefault(pm.input_name,
                           best_pow2_exponent(acts[pm.input_name]))

        # pass 2: merge-operand scale groups -> group minimum (fixpoint)
        changed = True
        while changed:
            changed = False
            for li in pm.layers:
                if li.kind in (P.ADD, P.CONCAT):
                    operands = li.inputs
                elif li.merge is not None:
                    operands = li.merge.inputs
                else:
                    continue
                m = min(desired[t] for t in operands)
                for t in operands:
                    if desired[t] != m:
                        desired[t] = m
                        changed = True

        # pass 3: forward threading over the schedule
        tensor_m: Dict[str, int] = {pm.input_name: desired[pm.input_name]}
        specs: Dict[str, QuantSpec] = {}
        for li in pm.layers:
            if li.kind in (P.CONV, P.FC):
                if per_channel:
                    m_w = best_pow2_exponents_per_channel(weights[li.weight])
                    m_w_cap = min(m_w)  # every lane's shift must be >= 0
                else:
                    m_w = m_w_cap = best_pow2_exponent(weights[li.weight])
                m_x = tensor_m[li.inputs[0]]

                def lane_clamp(m_w, m_y):
                    # keep every lane's shift m_w[c]+m_x-m_y inside the
                    # int32 round-half-up datapath; lanes at the clamp
                    # lose nothing (their shifted-away bits are already
                    # below one output LSB)
                    if not per_channel:
                        return m_w
                    return tuple(min(mw, MAX_SHIFT + m_y - m_x)
                                 for mw in m_w)

                if li.merge is not None:
                    # the conv's own spec scales its intermediate tensor;
                    # the folded merge gets the same spec a standalone
                    # Add stage would have received
                    m_int = min(desired[li.merge_intermediate],
                                m_w_cap + m_x)
                    specs[li.name] = QuantSpec(
                        m_w=lane_clamp(m_w, m_int), m_x=m_x, m_y=m_int)
                    m_common = min(m_int, tensor_m[li.skip_input])
                    # scale from the *merge* output stats (an absorbed
                    # max-pool passes scale through, as when standalone)
                    m_y = min(desired[li.merge.output], m_common)
                    specs[li.merge.name] = QuantSpec(
                        m_w=0, m_x=m_common, m_y=m_y)
                else:
                    m_y = min(desired[li.output], m_w_cap + m_x)
                    specs[li.name] = QuantSpec(
                        m_w=lane_clamp(m_w, m_y), m_x=m_x, m_y=m_y)
                tensor_m[li.output] = m_y
            elif li.kind == P.POOL:
                tensor_m[li.output] = tensor_m[li.inputs[0]]
            else:  # add / concat
                m_common = min(tensor_m[t] for t in li.inputs)
                if li.kind == P.ADD:
                    m_y = min(desired[li.output], m_common)
                else:  # concat never rescales its operands' values
                    m_y = m_common
                specs[li.name] = QuantSpec(m_w=0, m_x=m_common, m_y=m_y)
                tensor_m[li.output] = m_y
        self.apply_quantization(specs)
        return specs

    # ---------------------------------------------------------------- DSE
    @property
    def per_channel(self) -> bool:
        """True when the *built* program runs any per-channel weight
        spec — the DSE then charges the shift-vector bytes.  Reads the
        quantized layers, not the raw specs: apply_quantization(...,
        per_channel=True) widens scalar specs inside build_quantized,
        so the specs dict alone under-reports the datapath."""
        if self.quantized is not None:
            return any(ql.spec is not None and ql.spec.per_channel
                       for ql in self.quantized.layers)
        return bool(self.specs) and any(
            s.per_channel for s in self.specs.values())

    def verify(self, **kw):
        """Run the static design-rule checks (:mod:`repro.core.verify`)
        over the current program and return the
        :class:`~repro.core.verify.VerificationReport`.  With a built
        program the staged int8 arrays feed the overflow bounds; with
        only specs applied the verifier re-quantizes from the graph
        initializers.  Keyword args forward to ``verify_program``
        (``vmem_budget=``, ``checkpoints=``, ...)."""
        from . import verify as verify_mod
        if self.quantized is not None:
            return verify_mod.verify_quantized(self.quantized, **kw)
        if self.specs is None:
            raise RuntimeError("apply_quantization() or "
                               "calibrate_quantization() first")
        return verify_mod.verify_program(self.parsed, self.specs, **kw)

    def design_space(self, board: str,
                     block_h_options: Optional[List[int]] = None
                     ) -> CNNDesignSpace:
        return CNNDesignSpace(self.parsed, FPGA_BOARDS[board],
                              block_h_options=block_h_options,
                              per_channel=self.per_channel,
                              specs=self.specs)

    def explore(self, board: str, algo: str = "rl",
                thresholds: Optional[Dict[str, float]] = None,
                eval_cost_s: float = 0.0,
                block_h_options: Optional[List[int]] = None,
                **kw) -> dse_mod.DSEResult:
        """Hardware-aware DSE.  With ``block_h_options`` the space grows
        a third axis — the conv kernel's row-band height — and options
        whose row-band working set exceeds the on-chip budget are
        rejected by the resource model (DESIGN.md §4)."""
        space = self.design_space(board, block_h_options=block_h_options)
        if algo == "bf":
            return dse_mod.brute_force(space, thresholds, eval_cost_s)
        if algo == "rl":
            return dse_mod.rl_dse(space, thresholds,
                                  eval_cost_s=eval_cost_s, **kw)
        raise ValueError(f"unknown DSE algorithm {algo!r}")

    # -------------------------------------------------------------- build
    def build(self, mode: str = "emulation", n_i: int = 16, n_l: int = 32,
              block_h: Optional[int] = None
              ) -> Callable[[jnp.ndarray], jnp.ndarray]:
        """Return the whole-network fused executor: ONE jitted closure
        over the staged layer list (no per-call Python layer dispatch).

        emulation: interpret-mode kernels (fast CPU verify).
        fullflow : AOT-compiled executable for the default backend (the
        TPU-target synthesis path; identical numerics).
        """
        if self.quantized is None:
            raise RuntimeError("apply_quantization() or "
                               "calibrate_quantization() first")
        qm = self.quantized
        if mode == "emulation":
            return pipe.make_executor(qm, n_i, n_l, block_h=block_h,
                                      interpret=True)
        if mode == "fullflow":
            interpret = jax.default_backend() != "tpu"
            jitted = pipe.make_executor(qm, n_i, n_l, block_h=block_h,
                                        interpret=interpret)
            sample = jnp.zeros((1,) + self.parsed.input_shape[1:], jnp.float32)
            t0 = time.perf_counter()
            compiled = jitted.lower(sample).compile()  # the "synthesis"
            self.synthesis_time_s = time.perf_counter() - t0
            self.compiled = compiled
            return jitted
        raise ValueError(f"unknown mode {mode!r}")

    def build_guarded(self, x_cal=None, policy=None,
                      qm: Optional[pipe.QuantizedModel] = None,
                      faults: Optional[Dict] = None,
                      mode: str = "emulation", n_i: int = 16,
                      n_l: int = 32, block_h: Optional[int] = None,
                      checkpoints=None):
        """Guarded-execution build (DESIGN.md §9).

        With ``policy=None`` guards are OFF and this returns the plain
        :func:`pipeline.make_executor` closure — the byte-identical
        program (jaxpr-identity probed in tests), zero overhead.

        With a :class:`~repro.core.guard.GuardPolicy`, returns a
        :class:`~repro.core.guard.GuardedExecutor` whose calls yield
        ``(logits, GuardReport)``: per-stage dequant audits against
        envelopes calibrated on ``x_cal`` from the *golden* program,
        plus the reexecute → unfused → per-tensor degradation ladder.
        ``qm``/``faults`` deploy a fault-injected program under the
        guard (defaults: the golden program, no faults);
        ``checkpoints`` (an int K or explicit boundary indices) arms
        the stage-boundary recovery rung (DESIGN.md §11)."""
        if self.quantized is None:
            raise RuntimeError("apply_quantization() or "
                               "calibrate_quantization() first")
        interpret = (True if mode == "emulation"
                     else jax.default_backend() != "tpu")
        if policy is None:
            return pipe.make_executor(qm or self.quantized, n_i, n_l,
                                      block_h=block_h,
                                      interpret=interpret)
        if x_cal is None:
            raise ValueError("guarded mode needs a calibration input "
                             "(x_cal) to record audit envelopes")
        from . import guard as guard_mod
        return guard_mod.GuardedExecutor(
            self, x_cal, policy=policy, qm=qm, faults=faults,
            n_i=n_i, n_l=n_l, block_h=block_h, interpret=interpret,
            checkpoints=checkpoints)

    # ------------------------------------------------------ latency model
    def latency_report(self, board: str, n_i: int, n_l: int) -> LatencyReport:
        """Analytical Table-1/Fig-6 latency model (see resources.py).
        Walks the DAG schedule: merge stages are pure memory traffic
        (both operands stream once, zero MACs), so residual networks
        report the adder path the FPGA would pay."""
        profile = FPGA_BOARDS[board]
        rows: List[LayerTiming] = []
        for li in self.parsed.layers:
            in_b, w_b, out_b = pipe.layer_bytes(li)
            t, tc, tm = fpga_layer_time_s(profile, n_i, n_l, li.macs,
                                          in_b, w_b, out_b)
            rows.append(LayerTiming(li.name, li.kind, t, tc, tm, li.macs))
        return LatencyReport(board=board, n_i=n_i, n_l=n_l, layers=rows)

    # ------------------------------------------------------------ summary
    def summary(self) -> str:
        pm = self.parsed
        lines = [f"model {pm.name}: {len(pm.layers)} pipeline stages, "
                 f"{pm.total_ops / 1e9:.2f} GOp, "
                 f"{pm.total_weights / 1e6:.1f} M weights"]
        for li in pm.layers:
            kind = li.kind
            if li.is_depthwise:
                kind = "dwconv"
            elif li.kind == P.CONV and li.group > 1:
                kind = f"gconv[{li.group}]"
            fused = "+relu" if li.relu else ""
            fused += "+pool" if li.pool is not None else ""
            fused += "+softmax" if li.softmax else ""
            ins = (f" <- {len(li.inputs)} tensors"
                   if len(li.inputs) > 1 else "")
            lines.append(f"  {li.name:<12} {kind}{fused:<14} "
                         f"in={li.in_shape} out={li.out_shape} "
                         f"macs={li.macs / 1e6:.1f}M{ins}")
        return "\n".join(lines)
