"""qverify: static design-rule checks over quantized stage programs.

CNN2Gate's pitch is catching infeasible designs *before* paying for
synthesis — the DSE rejects candidates on modeled resources, and every
FPGA toolflow it cites runs design-rule checks ahead of the build.
This module is that DRC pass for our int8 runtime: a static analyzer
over the (Graph, stage program, QuantSpec set) triple that proves the
invariants the executor otherwise only enforces dynamically (or not at
all), emitting structured :class:`Diagnostic` records instead of
letting a bad spec/graph combination surface as a silent int32
wraparound or a wrong fused program at run time.

Rule catalog (DESIGN.md §13) — every rule is a pure function over
already-available metadata; none of them traces or runs a program:

  ========  =========================================================
  QV101     int32 accumulator overflow: worst-case weighted-stage
            magnitude ``128·Σ|w_q| + |b_q| + rounding half`` per Cout
            lane (per-lane under per-channel specs) proved < 2^31
  QV102     a requant or alignment shift exceeds ``MAX_SHIFT``
  QV103     int32 merge overflow: aligned operand bound
            ``Σ 128 << shift_i + rounding half`` proved < 2^31
  QV201     negative requant shift (``m_y`` above the ``m_w + m_x``
            cap — the shift-only datapath cannot scale up)
  QV202     negative merge alignment (an operand position below the
            common scale)
  QV203     scale-threading conflict: a tensor pinned at two
            different fixed-point positions (``thread_scales`` is
            first-set-wins and would silently drop one)
  QV204     fused/unfused threading mismatch: the fused program's
            tensor positions must agree with the standalone-merge
            program's on every shared tensor
  QV205     unresolved fixed-point position (under-specified specs)
  QV206     malformed spec (per-channel lane count vs Cout,
            per-channel merge spec, strict-mode coercion conflict)
  QV301     fused-concat producer slices do not exactly partition the
            merge buffer's Cout (overlap, gap, or offset mismatch)
  QV302     use of an undefined or liveness-released tensor
  QV303     a fused-concat producer's output escapes its merge (the
            slice only exists inside the shared buffer)
  QV304     invalid checkpoint boundary (outside the schedule, or
            inside a fused-concat group)
  QV401     a stage's VMEM working set exceeds the declared budget
  QV402     retained checkpoint bytes push on-chip memory over budget
  QV501     jaxpr probe: standalone integer add in a skip-fused
            program (the fused epilogue should have absorbed it)
  QV502     jaxpr probe: standalone concatenate in a concat-fused
            program
  ========  =========================================================

``verify_program`` runs the static rules (QV1xx–QV4xx);
``structural_probes`` runs the QV5xx jaxpr probes (those trace an
executor, so they are opt-in — the CLI's ``--jaxpr-probes``).
:func:`pipeline.build_quantized` calls ``verify_program`` on every
program it stages and raises :class:`VerificationError` (a
``ValueError`` via :class:`~repro.core.graph.GraphError`) when any
error-severity diagnostic fires.  Verification never rewrites the
program, so the emitted executor jaxpr is byte-identical with the
verifier on or off.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from . import parser as P
from .graph import GraphError
from .quantize import MAX_SHIFT, QuantSpec, quantize_weights, shift_lanes
from .resources import (checkpoint_bytes, concat_group_spans,
                        conv_band_working_set)

INT32_MAX = 2 ** 31 - 1
#: Worst-case |int8| operand magnitude the datapath can see (INT8_MIN).
INT8_MAG = 128

ERROR = "error"
WARNING = "warning"
INFO = "info"

#: rule id -> one-line description (CLI listing, DESIGN.md §13).
RULES: Dict[str, str] = {
    "QV101": "int32 accumulator overflow (weighted-stage worst case)",
    "QV102": "requant/alignment shift exceeds MAX_SHIFT",
    "QV103": "int32 merge overflow (aligned operand bound)",
    "QV201": "negative requant shift (m_y above the m_w+m_x cap)",
    "QV202": "negative merge alignment (operand below the common scale)",
    "QV203": "scale-threading conflict (tensor pinned twice)",
    "QV204": "fused/unfused threading mismatch",
    "QV205": "unresolved fixed-point position",
    "QV206": "malformed QuantSpec (lanes vs Cout / mode conflict)",
    "QV301": "fused-concat slices do not partition the merge buffer",
    "QV302": "use of an undefined or released tensor",
    "QV303": "fused-concat producer slice escapes its merge",
    "QV304": "invalid checkpoint boundary",
    "QV401": "stage VMEM working set over budget",
    "QV402": "retained checkpoint bytes over budget",
    "QV501": "standalone integer add in a skip-fused program",
    "QV502": "standalone concatenate in a concat-fused program",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One design-rule finding: which rule, how bad, where."""

    rule_id: str
    severity: str
    stage: str = ""
    tensor: str = ""
    detail: str = ""

    def __str__(self) -> str:
        where = " ".join(p for p in (
            f"stage={self.stage}" if self.stage else "",
            f"tensor={self.tensor}" if self.tensor else "") if p)
        msg = f"{self.rule_id} {self.severity}"
        if where:
            msg += f" [{where}]"
        if self.detail:
            msg += f": {self.detail}"
        return msg


@dataclasses.dataclass
class VerificationReport:
    """All diagnostics of one verifier run, in rule order."""

    diagnostics: List[Diagnostic]

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARNING]

    @property
    def ok(self) -> bool:
        return not self.errors

    def by_rule(self, rule_id: str) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.rule_id == rule_id]

    @property
    def rule_ids(self) -> Tuple[str, ...]:
        return tuple(sorted({d.rule_id for d in self.diagnostics}))

    def render(self) -> str:
        if not self.diagnostics:
            return "verification clean (no diagnostics)"
        return "\n".join(str(d) for d in self.diagnostics)

    def raise_if_errors(self) -> "VerificationReport":
        if self.errors:
            raise VerificationError(self.errors)
        return self


class VerificationError(GraphError):
    """A program failed static verification.  Subclasses
    :class:`~repro.core.graph.GraphError` (a ``ValueError``), so
    callers that guarded the old bare raises keep working; carries the
    machine-readable diagnostics so new callers need not parse text."""

    def __init__(self, diagnostics: Sequence[Diagnostic]):
        self.diagnostics = tuple(diagnostics)
        n = len(self.diagnostics)
        msg = (f"program verification failed ({n} error"
               f"{'s' if n != 1 else ''}): "
               + "; ".join(str(d) for d in self.diagnostics))
        super().__init__(msg)


# ------------------------------------------------------ spec structure

def _known_spec_names(parsed: P.ParsedModel) -> set:
    names = {li.name for li in parsed.layers}
    names.update(li.merge.name for li in parsed.layers
                 if li.merge is not None)
    return names


def check_spec_shapes(parsed: P.ParsedModel,
                      specs: Dict[str, QuantSpec]) -> List[Diagnostic]:
    """QV206: per-channel lane counts must match Cout; merge specs must
    stay per-tensor (activations carry one position per tensor); spec
    names should resolve to a stage (or a fused merge's name)."""
    out: List[Diagnostic] = []
    merge_names = {li.merge.name for li in parsed.layers
                   if li.merge is not None}
    for li in parsed.layers:
        spec = specs.get(li.name)
        if spec is None:
            continue
        if li.kind in (P.CONV, P.FC):
            if spec.per_channel and len(spec.m_w) != li.c_out:
                out.append(Diagnostic(
                    "QV206", ERROR, stage=li.name, tensor=li.output,
                    detail=f"per-channel m_w has {len(spec.m_w)} lanes "
                           f"for Cout={li.c_out}"))
        elif spec.per_channel:
            out.append(Diagnostic(
                "QV206", ERROR, stage=li.name, tensor=li.output,
                detail="merge/pool specs are per-tensor (activations "
                       "carry one fixed-point position), got a "
                       f"{len(spec.m_w)}-lane m_w"))
    for name, spec in specs.items():
        if name in merge_names and spec.per_channel:
            out.append(Diagnostic(
                "QV206", ERROR, stage=name,
                detail="fused merge specs are per-tensor, got a "
                       f"{len(spec.m_w)}-lane m_w"))
    unknown = set(specs) - _known_spec_names(parsed)
    for name in sorted(unknown):
        out.append(Diagnostic(
            "QV206", WARNING, stage=name,
            detail="spec names no scheduled stage or fused merge"))
    return out


def check_requant_shifts(parsed: P.ParsedModel,
                         specs: Dict[str, QuantSpec],
                         max_shift: int = MAX_SHIFT) -> List[Diagnostic]:
    """QV201/QV102 on every spec'd stage (and fused merge): each lane's
    requant shift ``m_w + m_x - m_y`` proved in ``[0, max_shift]``."""
    out: List[Diagnostic] = []
    seen: set = set()

    def _check(name: str, tensor: str, spec: QuantSpec) -> None:
        if name in seen:
            return
        seen.add(name)
        lanes = shift_lanes(spec)
        lo, hi = min(lanes), max(lanes)
        if lo < 0:
            lane = "" if len(lanes) == 1 else f" (lane {lanes.index(lo)})"
            out.append(Diagnostic(
                "QV201", ERROR, stage=name, tensor=tensor,
                detail=f"negative requant shift {lo}{lane}: m_y={spec.m_y} "
                       "exceeds the m_w+m_x cap — the shift-only "
                       "datapath cannot scale up"))
        if hi > max_shift:
            lane = "" if len(lanes) == 1 else f" (lane {lanes.index(hi)})"
            out.append(Diagnostic(
                "QV102", ERROR, stage=name, tensor=tensor,
                detail=f"requant shift {hi}{lane} exceeds MAX_SHIFT="
                       f"{max_shift} (the int32 round-half-up constant "
                       "1 << (s-1) must stay representable)"))

    for li in parsed.layers:
        spec = specs.get(li.name)
        if spec is not None and li.kind in (P.CONV, P.FC, P.ADD, P.CONCAT):
            _check(li.name, li.output, spec)
        if li.merge is not None:
            mspec = specs.get(li.merge.name)
            if mspec is not None:
                _check(li.merge.name, li.merge.output, mspec)
    return out


# ------------------------------------------------- scale threading

def thread_scales_checked(
        parsed: P.ParsedModel, specs: Dict[str, QuantSpec]
) -> Tuple[Dict[str, int], List[Diagnostic]]:
    """Re-derive :func:`pipeline.thread_scales` as a *checking* pass:
    the same fixpoint over the same pinning rules, but a tensor pinned
    at two different positions is a QV203 diagnostic instead of a
    silent first-set-wins, a weighted stage without a spec is QV205
    instead of a ``KeyError``, and an unresolved graph input/output is
    QV205 instead of a raise.  Returns the (partial) positions plus the
    diagnostics, so downstream rules can keep analyzing."""
    out: List[Diagnostic] = []
    tensor_m: Dict[str, int] = {}
    conflicts: set = set()
    missing: set = set()

    for _ in range(len(parsed.layers) + 2):
        changed = False

        def _set(t: str, m: int, stage: str, why: str) -> None:
            nonlocal changed
            if t in tensor_m:
                if tensor_m[t] != m and (t, m) not in conflicts:
                    conflicts.add((t, m))
                    out.append(Diagnostic(
                        "QV203", ERROR, stage=stage, tensor=t,
                        detail=f"pinned at m={tensor_m[t]} but {why} "
                               f"implies m={m} — thread_scales would "
                               "silently keep the first"))
                return
            tensor_m[t] = m
            changed = True

        for li in parsed.layers:
            spec = specs.get(li.name)
            if li.kind in (P.CONV, P.FC):
                if spec is None:
                    if li.name not in missing:
                        missing.add(li.name)
                        out.append(Diagnostic(
                            "QV205", ERROR, stage=li.name,
                            tensor=li.output,
                            detail="weighted stage has no QuantSpec"))
                    continue
                _set(li.inputs[0], spec.m_x, li.name,
                     f"{li.name}'s m_x={spec.m_x}")
                if li.kind == P.CONV and li.merge is not None:
                    _set(li.merge_intermediate, spec.m_y, li.name,
                         f"{li.name}'s m_y={spec.m_y}")
                    mspec = specs.get(li.merge.name)
                    if mspec is not None:
                        _set(li.output, mspec.m_y, li.name,
                             f"merge {li.merge.name}'s m_y={mspec.m_y}")
                    elif li.skip_input in tensor_m:
                        m = min(spec.m_y, tensor_m[li.skip_input])
                        _set(li.output, m, li.name,
                             f"fused merge {li.merge.name}'s operand "
                             "minimum")
                else:
                    _set(li.output, spec.m_y, li.name,
                         f"{li.name}'s m_y={spec.m_y}")
            elif li.kind == P.POOL:
                if li.inputs[0] in tensor_m:
                    _set(li.output, tensor_m[li.inputs[0]], li.name,
                         "pool scale passthrough")
                elif li.output in tensor_m:
                    _set(li.inputs[0], tensor_m[li.output], li.name,
                         "pool scale passthrough (backward)")
            else:  # add / concat
                if spec is not None:
                    _set(li.output, spec.m_y, li.name,
                         f"{li.name}'s m_y={spec.m_y}")
                elif all(t in tensor_m for t in li.inputs):
                    m = min(tensor_m[t] for t in li.inputs)
                    _set(li.output, m, li.name,
                         f"{li.name}'s operand minimum")
        if not changed:
            break

    for t in (parsed.input_name, parsed.output_name):
        if t not in tensor_m:
            out.append(Diagnostic(
                "QV205", ERROR, tensor=t,
                detail="could not resolve the fixed-point position "
                       "from the given specs"))
    return tensor_m, out


def check_threading_identity(parsed: P.ParsedModel,
                             specs: Dict[str, QuantSpec]
                             ) -> List[Diagnostic]:
    """QV204: thread the same specs over the standalone-merge parse of
    the same graph and require identical positions on every tensor both
    programs name.  (The unfused program threads extra intermediates —
    e.g. pre-pool concat outputs the fused merge absorbed — which have
    no fused counterpart and are exempt by construction.)"""
    fused = any(li.merge is not None or li.concat_fused
                for li in parsed.layers)
    if not fused:
        return []
    unfused = P.parse(parsed.graph, fuse_skip=False, fuse_concat=False)
    m_f, d_f = thread_scales_checked(parsed, specs)
    m_u, d_u = thread_scales_checked(unfused, specs)
    if any(d.severity == ERROR for d in d_f + d_u):
        return []  # positions are not trustworthy; QV203/QV205 already fired
    out: List[Diagnostic] = []
    for t in sorted(set(m_f) & set(m_u)):
        if m_f[t] != m_u[t]:
            out.append(Diagnostic(
                "QV204", ERROR, tensor=t,
                detail=f"fused program threads m={m_f[t]} but the "
                       f"standalone-merge program threads m={m_u[t]} — "
                       "fusion must not move any shared tensor's scale"))
    return out


# ------------------------------------------------- overflow analysis

def _staged_lane_stats(ql) -> Tuple[np.ndarray, np.ndarray]:
    """(Σ|w_q| per Cout lane, |b_q| per lane) from a staged
    :class:`~repro.core.pipeline.QuantizedLayer` — conv weights are
    HWIO (Cout last), FC weights (K, N); both reduce over every axis
    but the last."""
    w = np.abs(np.asarray(ql.w_q, np.int64))
    sums = w.sum(axis=tuple(range(w.ndim - 1)))
    if ql.b_q is not None:
        bias = np.abs(np.asarray(ql.b_q, np.int64)).reshape(-1)
    else:
        bias = np.zeros_like(sums)
    return sums, bias


def _raw_lane_stats(parsed: P.ParsedModel, li: P.LayerInfo,
                    spec: QuantSpec) -> Tuple[np.ndarray, np.ndarray]:
    """Same lane statistics computed from the graph initializers (the
    CLI path, where no staged program exists): quantize exactly as
    ``build_quantized`` would and reduce onto the Cout axis (OIHW conv:
    axis 0; (K, N) FC: last axis)."""
    w = parsed.graph.initializers[li.weight]
    b = parsed.graph.initializers[li.bias] if li.bias else None
    w_q, b_q = quantize_weights(w, b, spec)
    w_q = np.abs(np.asarray(w_q, np.int64))
    if w_q.ndim == 4:  # OIHW
        sums = w_q.sum(axis=(1, 2, 3))
    else:  # (K, N)
        sums = w_q.sum(axis=tuple(range(w_q.ndim - 1)))
    if b_q is not None:
        bias = np.abs(np.asarray(b_q, np.int64)).reshape(-1)
    else:
        bias = np.zeros_like(sums)
    return sums, bias


def check_accumulators(parsed: P.ParsedModel, specs: Dict[str, QuantSpec],
                       quantized_layers: Optional[Sequence] = None
                       ) -> List[Diagnostic]:
    """QV101: per weighted stage, the worst-case int32 accumulator
    magnitude ``INT8_MAG * Σ_taps|w_q[c]| + |b_q[c]| + (1 << (s_c - 1))``
    per Cout lane ``c`` (the input operand bound is |INT8_MIN| = 128;
    the rounding half rides on the accumulator before the shift) must
    stay within int32.  ``quantized_layers`` reuses the staged arrays
    from :func:`pipeline.build_quantized`; without them the weights are
    re-quantized from the graph initializers (pure numpy)."""
    out: List[Diagnostic] = []
    staged = {ql.info.name: ql for ql in (quantized_layers or ())
              if ql.w_q is not None}
    for li in parsed.layers:
        if li.kind not in (P.CONV, P.FC) or not li.weight:
            continue
        spec = specs.get(li.name)
        if spec is None:
            continue  # QV205 already fired
        if spec.per_channel and len(spec.m_w) != li.c_out:
            continue  # QV206 already fired; lane math would misalign
        try:
            if li.name in staged:
                sums, bias = _staged_lane_stats(staged[li.name])
            else:
                sums, bias = _raw_lane_stats(parsed, li, spec)
        except (KeyError, ValueError):
            continue  # malformed weights: the graph layer reports it
        lanes = np.asarray(shift_lanes(spec), np.int64)
        if lanes.shape[0] not in (1, sums.shape[0]):
            continue
        halves = np.where(lanes > 0,
                          np.left_shift(1, np.maximum(lanes - 1, 0)), 0)
        bound = INT8_MAG * sums + bias + halves
        worst = int(np.argmax(bound))
        if int(bound[worst]) > INT32_MAX:
            taps = li.kernel_shape[0] * li.kernel_shape[1]\
                * (li.c_in // li.group) if li.kind == P.CONV else li.c_in
            out.append(Diagnostic(
                "QV101", ERROR, stage=li.name, tensor=li.output,
                detail=f"worst-case accumulator {int(bound[worst])} "
                       f"(lane {worst}, {taps} taps) exceeds int32 max "
                       f"{INT32_MAX} — the int32 datapath would wrap"))
    return out


def _merge_overflow(kind: str, shifts: Sequence[int],
                    out_shift: int) -> int:
    """Worst-case int32 magnitude of a shift-aligned merge: an Add sums
    every aligned operand; a Concat's slices are independent, so only
    the widest operand counts.  The output requant's rounding half
    rides on top."""
    half = (1 << (out_shift - 1)) if out_shift > 0 else 0
    aligned = [INT8_MAG << s for s in shifts if s >= 0]
    if not aligned:
        return 0
    acc = sum(aligned) if kind == P.ADD else max(aligned)
    return acc + half


def check_merge_alignment(parsed: P.ParsedModel,
                          specs: Dict[str, QuantSpec],
                          tensor_m: Dict[str, int],
                          max_shift: int = MAX_SHIFT) -> List[Diagnostic]:
    """QV202/QV102/QV103 on every merge — standalone Add/Concat stages
    and residual adds folded into a conv epilogue: each operand's
    alignment shift (its position minus the common scale) proved in
    ``[0, max_shift]``, and the aligned int32 sum proved within int32.
    """
    out: List[Diagnostic] = []

    def _check(stage: str, merge_name: str, kind: str,
               operands: Sequence[str], m_ops: Sequence[int],
               spec: Optional[QuantSpec]) -> None:
        if spec is None:
            m_common = min(m_ops)
            spec = QuantSpec(m_w=0, m_x=m_common, m_y=m_common)
        if spec.per_channel:
            return  # QV206 already fired
        shifts = [m - spec.m_x for m in m_ops]
        for t, s in zip(operands, shifts):
            if s < 0:
                out.append(Diagnostic(
                    "QV202", ERROR, stage=stage, tensor=t,
                    detail=f"merge {merge_name!r}: operand position "
                           f"m={spec.m_x + s} below the common scale "
                           f"m={spec.m_x} — shift-only alignment "
                           "cannot scale up"))
            elif s > max_shift:
                out.append(Diagnostic(
                    "QV102", ERROR, stage=stage, tensor=t,
                    detail=f"merge {merge_name!r}: alignment shift {s} "
                           f"exceeds MAX_SHIFT={max_shift}"))
        out_shift = spec.m_w + spec.m_x - spec.m_y
        bound = _merge_overflow(kind, shifts, max(out_shift, 0))
        if bound > INT32_MAX:
            out.append(Diagnostic(
                "QV103", ERROR, stage=stage,
                detail=f"merge {merge_name!r}: aligned int32 bound "
                       f"{bound} exceeds int32 max {INT32_MAX}"))

    for li in parsed.layers:
        if li.kind in (P.ADD, P.CONCAT):
            if not all(t in tensor_m for t in li.inputs):
                continue  # QV205 already fired
            _check(li.name, li.name, li.kind, li.inputs,
                   [tensor_m[t] for t in li.inputs], specs.get(li.name))
        elif li.kind == P.CONV and li.merge is not None:
            operands = (li.merge_intermediate, li.skip_input)
            if not all(t in tensor_m for t in operands):
                continue
            _check(li.name, li.merge.name, P.ADD, operands,
                   [tensor_m[t] for t in operands],
                   specs.get(li.merge.name))
    return out


# --------------------------------------------- alias & liveness rules

def check_concat_partition(parsed: P.ParsedModel) -> List[Diagnostic]:
    """QV301: for every fused concat, the producers' channel slices
    ``[offset, offset + c_out)`` must exactly partition the merge
    buffer's Cout in operand order — no overlap (a non-idempotent
    double write), no gap (uninitialized lanes), no producer-less
    operand (the slice would never be written)."""
    out: List[Diagnostic] = []
    producers: Dict[str, List[P.LayerInfo]] = {}
    for li in parsed.layers:
        if li.concat is not None:
            producers.setdefault(li.concat.name, []).append(li)
    for cc in parsed.layers:
        if cc.kind != P.CONCAT or not cc.concat_fused:
            continue
        group = producers.get(cc.name, [])
        by_out = {li.output: li for li in group}
        missing = [t for t in cc.inputs if t not in by_out]
        for t in missing:
            out.append(Diagnostic(
                "QV301", ERROR, stage=cc.name, tensor=t,
                detail="fused concat operand has no in-place producer "
                       "— its channel slice would never be written"))
        extra = sorted(set(by_out) - set(cc.inputs))
        for t in extra:
            out.append(Diagnostic(
                "QV301", ERROR, stage=cc.name, tensor=t,
                detail=f"stage {by_out[t].name!r} writes the merge "
                       "buffer but its output is not a concat operand"))
        if missing or extra:
            continue
        # operand order fixes the expected offsets
        offset = 0
        intervals = []
        for t in cc.inputs:
            li = by_out[t]
            if li.concat_offset != offset:
                out.append(Diagnostic(
                    "QV301", ERROR, stage=li.name, tensor=t,
                    detail=f"slice offset {li.concat_offset} does not "
                           f"match the operand-order offset {offset} in "
                           f"merge {cc.name!r}"))
            intervals.append((li.concat_offset,
                              li.concat_offset + li.c_out, li.name))
            offset += li.c_out
        intervals.sort()
        end = 0
        for lo, hi, name in intervals:
            if lo < end:
                out.append(Diagnostic(
                    "QV301", ERROR, stage=name,
                    detail=f"slice [{lo}, {hi}) overlaps the previous "
                           f"slice ending at {end} in merge {cc.name!r} "
                           "— overlapping epilogue writes are not "
                           "idempotent"))
            end = max(end, hi)
        if end != cc.c_out or (intervals and intervals[0][0] != 0):
            out.append(Diagnostic(
                "QV301", ERROR, stage=cc.name, tensor=cc.output,
                detail=f"slices cover [{intervals[0][0]}, {end}) of the "
                       f"merge buffer's Cout={cc.c_out} — every lane "
                       "must be written exactly once"))
    return out


def release_schedule(parsed: P.ParsedModel) -> Dict[str, int]:
    """The executor's liveness plan: tensor -> index of the stage after
    which its buffer is dropped from the environment (the graph output
    is pinned past the last stage — the egress reads it).  This is the
    exact rule :func:`pipeline.make_executor` uses to pop buffers."""
    last: Dict[str, int] = {}
    for idx, li in enumerate(parsed.layers):
        for t in li.inputs:
            last[t] = idx
    last[parsed.output_name] = len(parsed.layers)
    return last


def check_liveness(parsed: P.ParsedModel,
                   release_at: Optional[Dict[str, int]] = None
                   ) -> List[Diagnostic]:
    """QV302/QV303: interpret the schedule against an abstract tensor
    environment with the executor's exact liveness-release rule.  Every
    stage input must be live when read (produced earlier, not yet
    released); fused-concat producer outputs exist only as slices of
    the shared merge buffer, so any consumer other than their own
    Concat stage reads a tensor the environment never holds.

    ``release_at`` overrides the release plan (tensor -> drop index);
    by default it is re-derived from the schedule itself via
    :func:`release_schedule`.  Passing a journaled plan lets callers
    prove a *modified* schedule (a spliced stage, a recovery replay)
    against the buffer lifetimes the original build committed to."""
    out: List[Diagnostic] = []
    layers = parsed.layers
    last_use = release_at if release_at is not None\
        else release_schedule(parsed)

    live = {parsed.input_name}
    defined = {parsed.input_name}
    slices: Dict[str, str] = {}  # fused producer output -> its merge
    for idx, li in enumerate(layers):
        fused_cc = li.kind == P.CONCAT and li.concat_fused
        for t in dict.fromkeys(li.inputs):
            if t in slices:
                if not (fused_cc and slices[t] == li.name):
                    out.append(Diagnostic(
                        "QV303", ERROR, stage=li.name, tensor=t,
                        detail="reads a fused-concat producer slice "
                               "that only exists inside merge "
                               f"{slices[t]!r}'s shared buffer"))
                continue
            if t in live:
                continue
            if t in defined:
                out.append(Diagnostic(
                    "QV302", ERROR, stage=li.name, tensor=t,
                    detail="read after its liveness release (the last "
                           "consumer already ran and the environment "
                           "dropped the buffer)"))
            else:
                out.append(Diagnostic(
                    "QV302", ERROR, stage=li.name, tensor=t,
                    detail="read before any scheduled stage produces it"))
        for t in [t for t in live if last_use.get(t, len(layers)) == idx]:
            live.discard(t)
        if li.output in defined:
            out.append(Diagnostic(
                "QV302", ERROR, stage=li.name, tensor=li.output,
                detail="produced twice — a second write would clobber "
                       "the first product's consumers"))
        defined.add(li.output)
        if li.concat is not None:
            slices[li.output] = li.concat.name
        else:
            live.add(li.output)
    if parsed.output_name in slices:
        out.append(Diagnostic(
            "QV303", ERROR, tensor=parsed.output_name,
            detail="the graph output is a fused-concat producer slice "
                   "— it never exists as a named tensor"))
    elif parsed.output_name not in defined:
        out.append(Diagnostic(
            "QV302", ERROR, tensor=parsed.output_name,
            detail="the graph output is never produced by any "
                   "scheduled stage"))
    return out


def check_checkpoint_boundaries(parsed: P.ParsedModel,
                                boundaries: Iterable[int]
                                ) -> List[Diagnostic]:
    """QV304: every snapshot boundary must be a real stage boundary —
    inside the schedule, and not inside a fused-concat group where the
    half-built shared merge buffer is live but is not a named graph
    tensor.  (:func:`pipeline.make_executor` enforces exactly this set
    by raising :class:`VerificationError` on these diagnostics.)"""
    out: List[Diagnostic] = []
    n = len(parsed.layers)
    spans = concat_group_spans(parsed)
    for c in sorted({int(c) for c in boundaries}):
        if not 0 <= c < n:
            out.append(Diagnostic(
                "QV304", ERROR,
                detail=f"checkpoint boundary {c} outside the schedule "
                       f"[0, {n})"))
            continue
        for start, end, name in spans:
            if start <= c < end:
                out.append(Diagnostic(
                    "QV304", ERROR, stage=parsed.layers[c].name,
                    detail=f"checkpoint boundary {c} lies inside "
                           f"fused-concat group {name!r} (stages "
                           f"{start}..{end}); pick a boundary where "
                           "only named tensors are live"))
    return out


# ------------------------------------------------- resource budgets

def check_resources(parsed: P.ParsedModel, *, n_i: int = 16,
                    n_l: int = 32, block_h: Optional[int] = None,
                    per_channel: bool = False,
                    vmem_budget: Optional[int] = None,
                    checkpoints: Iterable[int] = ()
                    ) -> List[Diagnostic]:
    """QV401/QV402 against a *declared* budget (``vmem_budget=None``
    checks nothing — budgets are deployment decisions, not program
    properties): each stage's row-band working set must fit, and the
    retained checkpoint snapshots must fit alongside the peak band
    (they coexist on chip, so the charges add — same rule the DSE's
    ``CNNDesignSpace`` scores)."""
    if vmem_budget is None:
        return []
    out: List[Diagnostic] = []
    peak = 0
    for li in parsed.layers:
        ws = conv_band_working_set([li], n_l, block_h, n_i=n_i,
                                   per_channel=per_channel)
        peak = max(peak, ws)
        if ws > vmem_budget:
            out.append(Diagnostic(
                "QV401", ERROR, stage=li.name, tensor=li.output,
                detail=f"row-band working set {ws} B exceeds the "
                       f"declared budget {vmem_budget} B at (n_i={n_i}, "
                       f"n_l={n_l}, block_h={block_h})"))
    bounds = [c for c in {int(c) for c in checkpoints}
              if 0 <= c < len(parsed.layers)]
    if bounds:
        ckpt_b = checkpoint_bytes(parsed, bounds)
        if peak + ckpt_b > vmem_budget:
            out.append(Diagnostic(
                "QV402", ERROR,
                detail=f"retained checkpoint snapshots ({ckpt_b} B at "
                       f"boundaries {sorted(bounds)}) on top of the "
                       f"peak band ({peak} B) exceed the declared "
                       f"budget {vmem_budget} B"))
    return out


# ------------------------------------------------- jaxpr structural probes

def _walk_eqns(jaxpr):
    """Yield every eqn reachable from ``jaxpr`` without descending into
    ``pallas_call`` kernels (their body is the kernel's own program —
    the probes reason about what reaches XLA *between* kernels)."""
    import jax

    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            if isinstance(v, jax.core.ClosedJaxpr):
                yield from _walk_eqns(v.jaxpr)
            elif isinstance(v, jax.core.Jaxpr):
                yield from _walk_eqns(v)


def int_add_eqns(jaxpr) -> int:
    """Integer tensor ``add`` eqns reaching XLA outside ``pallas_call``
    — a standalone merge stage shows up here (its int32 operand add);
    a fully skip-fused program must have none."""
    n = 0
    for eqn in _walk_eqns(jaxpr):
        if eqn.primitive.name != "add":
            continue
        avals = [v.aval for v in eqn.invars
                 if hasattr(v, "aval") and hasattr(v.aval, "dtype")]
        if avals and all(np.issubdtype(a.dtype, np.integer)
                         and getattr(a, "ndim", 0) >= 4 for a in avals):
            n += 1
    return n


def concat_eqns(jaxpr) -> int:
    """``concatenate`` eqns reaching XLA outside ``pallas_call`` — a
    standalone Concat stage shows up here; a fully concat-fused program
    must have none."""
    return sum(1 for eqn in _walk_eqns(jaxpr)
               if eqn.primitive.name == "concatenate")


def pallas_call_arities(jaxpr) -> List[int]:
    """Operand count of every ``pallas_call`` in trace order — the
    per-channel program stages exactly one extra operand (the per-lane
    shift row) on every weighted kernel call."""
    return [len(eqn.invars) for eqn in _walk_eqns(jaxpr)
            if eqn.primitive.name == "pallas_call"]


def executor_jaxpr(qm, n_i: int = 16, n_l: int = 32,
                   block_h: Optional[int] = None, batch: int = 1,
                   as_text: bool = False, **hooks):
    """Trace the interpret-mode executor of a built program and return
    its jaxpr (``as_text=True``: the string form, the byte-identity
    probe's comparand).  ``hooks`` forward to ``make_executor`` —
    tracing with hooks off must yield the exact same program as the
    plain executor."""
    import jax
    import jax.numpy as jnp

    from . import pipeline as pipe

    ex = pipe.make_executor(qm, n_i, n_l, block_h=block_h,
                            interpret=True, **hooks)
    x = jnp.zeros((batch,) + tuple(qm.parsed.input_shape[1:]),
                  jnp.float32)
    jaxpr = jax.make_jaxpr(lambda v: ex(v))(x)
    return str(jaxpr) if as_text else jaxpr


def structural_probes(qm, n_i: int = 16, n_l: int = 32,
                      block_h: Optional[int] = None,
                      batch: int = 1) -> List[Diagnostic]:
    """QV501/QV502: trace the executor once and prove the fusion
    annotations hold in the emitted program — no standalone integer add
    when every residual merge is folded, no ``concatenate`` when every
    concat is epilogue-fused.  Opt-in (tracing is not free): the CLI's
    ``--jaxpr-probes``, not the build-time pass."""
    out: List[Diagnostic] = []
    layers = qm.parsed.layers
    jaxpr = executor_jaxpr(qm, n_i, n_l, block_h=block_h, batch=batch)
    has_unfused_add = any(li.kind == P.ADD for li in layers)
    if not has_unfused_add and any(li.merge is not None for li in layers):
        n = int_add_eqns(jaxpr)
        if n:
            out.append(Diagnostic(
                "QV501", ERROR,
                detail=f"{n} standalone integer add eqn(s) reach XLA in "
                       "a program whose residual merges are all "
                       "epilogue-fused"))
    ccs = [li for li in layers if li.kind == P.CONCAT]
    if ccs and all(cc.concat_fused for cc in ccs):
        n = concat_eqns(jaxpr)
        if n:
            out.append(Diagnostic(
                "QV502", ERROR,
                detail=f"{n} concatenate eqn(s) reach XLA in a program "
                       "whose channel merges are all epilogue-fused"))
    return out


# --------------------------------------------------------- entry points

def _widen_specs(parsed: P.ParsedModel, specs: Dict[str, QuantSpec],
                 per_channel: Optional[bool]
                 ) -> Tuple[Dict[str, QuantSpec], List[Diagnostic]]:
    """The same mode coercion :func:`pipeline.build_quantized` applies,
    as a diagnostic pass: strict per-tensor mode rejects vector specs
    (QV206); ``per_channel=True`` widens scalar weighted-layer specs to
    uniform per-Cout vectors (bit-identical numerics)."""
    if per_channel is None:
        return dict(specs), []
    out: List[Diagnostic] = []
    coerced: Dict[str, QuantSpec] = {}
    for name, spec in specs.items():
        li = next((l for l in parsed.layers if l.name == name
                   or (l.merge is not None and l.merge.name == name)),
                  None)
        weighted = (li is not None and li.name == name
                    and li.kind in (P.CONV, P.FC))
        if not per_channel and spec.per_channel:
            out.append(Diagnostic(
                "QV206", ERROR, stage=name,
                detail=f"spec for {name!r} is per-channel but "
                       "per_channel=False was requested"))
        if per_channel and weighted and not spec.per_channel:
            coerced[name] = dataclasses.replace(
                spec, m_w=(spec.m_w,) * li.c_out)
    return dict(specs, **coerced), out


def verify_program(parsed: P.ParsedModel, specs: Dict[str, QuantSpec],
                   *, per_channel: Optional[bool] = None,
                   quantized_layers: Optional[Sequence] = None,
                   n_i: int = 16, n_l: int = 32,
                   block_h: Optional[int] = None,
                   vmem_budget: Optional[int] = None,
                   checkpoints: Iterable[int] = (),
                   check_identity: bool = True,
                   max_shift: int = MAX_SHIFT) -> VerificationReport:
    """Run the full static rule catalog over (stage program, specs) and
    return the :class:`VerificationReport`.  Pure analysis: nothing is
    traced, staged, or mutated — callers that want the old raise-on-bad
    behavior chain ``.raise_if_errors()``."""
    specs, diags = _widen_specs(parsed, specs, per_channel)
    diags += check_spec_shapes(parsed, specs)
    diags += check_requant_shifts(parsed, specs, max_shift=max_shift)
    tensor_m, d_thread = thread_scales_checked(parsed, specs)
    diags += d_thread
    diags += check_merge_alignment(parsed, specs, tensor_m,
                                   max_shift=max_shift)
    diags += check_accumulators(parsed, specs,
                                quantized_layers=quantized_layers)
    diags += check_concat_partition(parsed)
    diags += check_liveness(parsed)
    diags += check_checkpoint_boundaries(parsed, checkpoints)
    diags += check_resources(parsed, n_i=n_i, n_l=n_l, block_h=block_h,
                             per_channel=any(s.per_channel
                                             for s in specs.values()),
                             vmem_budget=vmem_budget,
                             checkpoints=checkpoints)
    if check_identity:
        diags += check_threading_identity(parsed, specs)
    return VerificationReport(diags)


def verify_quantized(qm, **kw) -> VerificationReport:
    """Verify a *built* program: reconstruct the effective spec set
    from the staged layers (including the default merge specs
    ``build_quantized`` materialized) and reuse the staged int8 arrays
    for the overflow bounds instead of re-quantizing."""
    specs: Dict[str, QuantSpec] = {}
    for ql in qm.layers:
        if ql.spec is not None:
            specs[ql.info.name] = ql.spec
        if ql.info.merge is not None and ql.merge_spec is not None:
            specs[ql.info.merge.name] = ql.merge_spec
    kw.setdefault("quantized_layers", qm.layers)
    return verify_program(qm.parsed, specs, **kw)
