"""Vectorized SER (soft-error-rate) campaigns + selective hardening
(DESIGN.md §11).

The fault bench used to re-deploy one guarded executor per trial
(``GuardedExecutor.with_program``): one fresh jitted program per
sampled fault, a handful of trials per flip count.  This module turns
the statistical study into ONE compiled program: ``make_executor``'s
``weight_args``/``fault_args`` hooks make the staged weights and the
activation-fault payload *call-time arguments*, so a whole batch of
sampled :class:`~repro.core.faults.FaultPlan` trials — weight-bit
flips, dropped tiles, in-flight activation flips — vmaps through the
same closure (``in_axes=(None, 0, 0)``; a zero XOR mask is the no-op
padding slot).  Hundreds of trials cost one trace plus a batched run.

Per trial the campaign classifies the upset against the golden run on
the same input (the audit envelope is the golden run's own stats, the
guard's zero-slack configuration):

  * ``detected`` — at least one audited stage left its envelope;
  * ``masked``   — undetected and the output is bit-identical to
                   golden (the flip died inside the datapath);
  * ``silent``   — undetected and the output differs: the outcome a
                   mission-critical deployment must drive to zero.

Detected trials are then pushed through the *vectorized* recovery
path: localize (earliest flagged stage), group by nearest upstream
checkpoint, and replay each group through the golden program's
``replay_from`` closure in one vmapped call.  A replay whose stats
re-flag (snapshot poisoned by an un-audited upstream upset) counts as
``escalated`` — the ladder's full golden reexecution recovers it, at
full-depth cost.

Rates carry Wilson score confidence intervals — at the campaign sizes
CI bounds matter more than point estimates (3/3 detected says almost
nothing; 100/100 pins the rate above 0.96).

**Selective hardening** (:func:`derive_guard_policy`): the per-stage
audit is the guard's runtime cost (the measured ~1.4x overhead of a
full audit), but most stages' upsets are either masked or visible
downstream.  From the campaign's trial records the minimal audit set
is a set-cover problem — choose the fewest stages whose flagged sets
cover every output-reaching trial — solved greedily (ln-approximation,
exact at these sizes), and emitted as a ready-to-deploy
:class:`~repro.core.guard.GuardPolicy` with ``audit_stages`` pinned.
The derivation refuses to harden a configuration with observed silent
corruptions: no audit subset can cover what no audit saw.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import faults as F
from . import pipeline as pipe
from . import resources as R

#: Fault kinds a vectorized campaign can batch: kinds that only move
#: int8 payload (weights or activation XOR masks) through an unchanged
#: jaxpr.  Spec-mutating kinds (scale/shift-lane) change the traced
#: requant constants and cannot share a compiled program.
CAMPAIGN_KINDS = (F.WEIGHT_BIT, F.DROPPED_TILE, F.ACTIVATION_BIT)

SCHEMA_VERSION = 1


def wilson(k: int, n: int, z: float = 1.96) -> Tuple[float, float]:
    """Wilson score interval for a binomial rate ``k/n`` (95% default).
    Well-behaved at the boundaries (k=0, k=n) where the normal
    approximation collapses — exactly where SER campaigns live."""
    if n <= 0:
        return (0.0, 1.0)
    p = k / n
    z2 = z * z
    denom = 1.0 + z2 / n
    center = (p + z2 / (2 * n)) / denom
    half = z * math.sqrt(p * (1 - p) / n + z2 / (4 * n * n)) / denom
    return (max(0.0, center - half), min(1.0, center + half))


def _rate(k: int, n: int) -> Dict[str, float]:
    lo, hi = wilson(k, n)
    return {"count": k, "p": (k / n if n else 0.0), "lo": lo, "hi": hi}


@dataclasses.dataclass
class TrialRecord:
    """One sampled fault plan pushed through the campaign executor."""

    plan: F.FaultPlan
    stages: Tuple[str, ...]      # stages the plan faulted
    flagged: Tuple[str, ...]     # audited stages out of envelope
    outcome: str                 # detected | masked | silent
    output_differs: bool
    recovered: bool = False
    escalated: bool = False      # checkpoint replay unavailable/re-flagged
    replayed: int = 0            # stages re-run by the recovery path


@dataclasses.dataclass
class Campaign:
    """One campaign's trial records + aggregation helpers."""

    model: str
    flips: int
    kinds: Tuple[str, ...]
    seed: int
    boundaries: Tuple[int, ...]
    boundary_names: Tuple[str, ...]
    n_stages: int
    records: List[TrialRecord]

    @property
    def trials(self) -> int:
        return len(self.records)

    def counts(self) -> Dict[str, int]:
        c = {"detected": 0, "masked": 0, "silent": 0, "recovered": 0,
             "recovered_by_replay": 0, "escalated": 0}
        for r in self.records:
            c[r.outcome] += 1
            c["recovered"] += int(r.recovered)
            c["recovered_by_replay"] += int(r.recovered and not r.escalated
                                            and r.outcome == "detected")
            c["escalated"] += int(r.escalated)
        return c

    def stage_rates(self) -> Dict[str, Dict]:
        """Per-stage architectural-vulnerability table: of the trials
        that faulted a stage, how many were detected / masked / silent,
        and how many *reached the output* (the AVF estimate selective
        hardening keys on).  Multi-fault trials count under every stage
        they touched."""
        per: Dict[str, Dict[str, int]] = {}
        for r in self.records:
            for s in set(r.stages):
                d = per.setdefault(s, {"trials": 0, "detected": 0,
                                       "masked": 0, "silent": 0,
                                       "reached_output": 0})
                d["trials"] += 1
                d[r.outcome] += 1
                d["reached_output"] += int(r.output_differs)
        out: Dict[str, Dict] = {}
        for s, d in sorted(per.items()):
            n = d["trials"]
            out[s] = {
                "trials": n,
                "detected": _rate(d["detected"], n),
                "masked": _rate(d["masked"], n),
                "silent": _rate(d["silent"], n),
                "avf": _rate(d["reached_output"], n),
            }
        return out

    def summary(self) -> Dict:
        n = self.trials
        c = self.counts()
        replayed = [r.replayed for r in self.records
                    if r.outcome == "detected" and not r.escalated]
        return {
            "version": SCHEMA_VERSION,
            "model": self.model,
            "flips": self.flips,
            "trials": n,
            "kinds": list(self.kinds),
            "seed": self.seed,
            "checkpoints": {"boundaries": list(self.boundaries),
                            "stages": list(self.boundary_names)},
            "counts": c,
            "rates": {k: _rate(c[k], n)
                      for k in ("detected", "masked", "silent",
                                "recovered")},
            "mean_replayed_stages": (float(np.mean(replayed))
                                     if replayed else 0.0),
            "n_stages": self.n_stages,
            "per_stage": self.stage_rates(),
        }


# ---------------------------------------------------------- the driver

def _trial_weights(qm: pipe.QuantizedModel, plan: F.FaultPlan,
                   wnames: Sequence[str]) -> Dict[str, np.ndarray]:
    """The per-trial weight images for the executor's ``weight_args``:
    golden weights with the plan's program faults applied (reusing the
    canonical :func:`faults.inject` so the two paths can never drift)."""
    inj = F.inject(qm, plan)
    by = {ql.info.name: ql.w_q for ql in inj.layers}
    return {n: np.asarray(by[n]) for n in wnames}


def _trial_payload(plan: F.FaultPlan, tensors: Sequence[str],
                   slots: int) -> Dict[str, Tuple[np.ndarray, np.ndarray]]:
    """Fixed-shape ``(idx, mask)`` XOR payload per fault-arg tensor.
    Unused slots keep ``mask == 0`` — the scatter XORs zero, a no-op —
    so every trial in a batch has identical payload shapes."""
    per: Dict[str, List[Tuple[int, int]]] = {t: [] for t in tensors}
    for f in plan.faults:
        if f.kind != F.ACTIVATION_BIT:
            continue
        mask = int(np.array(1 << (f.bit % 8), np.uint8).astype(np.int8))
        per[f.tensor].append((f.index, mask))
    out = {}
    for t in tensors:
        idx = np.zeros(slots, np.int32)
        msk = np.zeros(slots, np.int8)
        merged: Dict[int, int] = {}
        for i, m in per[t]:  # two flips on one element XOR-combine
            merged[i] = merged.get(i, 0) ^ m
        for s, (i, m) in enumerate(list(merged.items())[:slots]):
            idx[s], msk[s] = i, m
        out[t] = (idx, msk)
    return out


def _flag_matrix(stats: Dict[str, np.ndarray],
                 golden: Dict[str, np.ndarray],
                 order: Sequence[str],
                 margin: float, sat_tol: float) -> np.ndarray:
    """(trials, stages) bool: audited stat rows outside the golden
    envelope, the guard's rules vectorized.  The dequant scale ``2^-m``
    multiplies both sides of the max/mean comparisons and the
    saturation fraction is scale-free, so the raw int8 stats compare
    directly."""
    cols = []
    for t in order:
        g = np.asarray(golden[t], np.float64)          # (3,)
        s = np.asarray(stats[t], np.float64)           # (T, 3)
        sat = s[:, 0] > g[0] + sat_tol
        mx = s[:, 1] > g[1] * (1.0 + margin)
        mean = (s[:, 2] > g[2] * (1.0 + margin)) | \
               (s[:, 2] * (1.0 + margin) < g[2])
        cols.append(sat | mx | mean)
    return np.stack(cols, axis=1)


def run_campaign(gate, x, *, trials: int = 100, flips: int = 1,
                 kinds: Sequence[str] = (F.WEIGHT_BIT,), seed: int = 0,
                 margin: float = 0.0, sat_tol: float = 0.0,
                 checkpoints: int = 2, chunk: int = 32,
                 n_i: int = 16, n_l: int = 32,
                 block_h: Optional[int] = None,
                 interpret: Optional[bool] = True) -> Campaign:
    """Run one vectorized SER campaign: ``trials`` sampled
    ``flips``-fault plans through a single compiled executor.

    ``gate`` is a calibrated :class:`~repro.core.synthesis.CNN2Gate`;
    ``x`` the (float, NCHW) input the golden reference and every trial
    run share.  ``checkpoints`` arms the recovery path with the
    equal-cumulative-MAC plan (0 = every detected trial escalates to
    full reexecution).  ``chunk`` bounds the vmapped batch (memory,
    not correctness).
    """
    for k in kinds:
        if k not in CAMPAIGN_KINDS:
            raise ValueError(
                f"kind {k!r} cannot be vectorized (campaign kinds: "
                f"{CAMPAIGN_KINDS}); spec-mutating kinds retrace the "
                "program — use GuardedExecutor.with_program for those")
    qm = gate.quantized
    parsed = gate.parsed
    stages = qm.layers
    stage_names = [ql.info.name for ql in stages]
    stage_idx = {n: i for i, n in enumerate(stage_names)}

    # sample every trial up front: the union of touched stages/tensors
    # fixes the executor's argument signature for the whole campaign
    plans = [F.FaultPlan.sample(qm, flips, kinds=kinds,
                                seed=seed + 17 * t)
             for t in range(trials)]
    w_touched = sorted({f.stage for p in plans for f in p.program_faults})
    a_touched = sorted({f.tensor for p in plans for f in p.faults
                        if f.kind == F.ACTIVATION_BIT})
    slots = max([sum(1 for f in p.faults if f.kind == F.ACTIVATION_BIT)
                 for p in plans] + [1])

    boundaries = R.plan_checkpoints(parsed, checkpoints)
    bnames = tuple(stage_names[b] for b in boundaries)

    ex = pipe.make_executor(qm, n_i=n_i, n_l=n_l, block_h=block_h,
                            interpret=interpret, audit=True,
                            checkpoints=boundaries or None,
                            weight_args=tuple(w_touched),
                            fault_args=tuple(a_touched))

    def _call(xv, w, p):
        extra = []
        if w_touched:
            extra.append(w)
        if a_touched:
            extra.append(p)
        return ex(xv, *extra)

    # golden reference: golden weights + all-zero payload through the
    # SAME closure (also validates the no-op path end to end)
    gold_w = {n: np.asarray(next(ql.w_q for ql in stages
                                 if ql.info.name == n))
              for n in w_touched}
    nop = {t: (np.zeros(slots, np.int32), np.zeros(slots, np.int8))
           for t in a_touched}
    res0 = _call(jnp.asarray(x), gold_w, nop)
    y0, stats0 = np.asarray(res0[0]), {t: np.asarray(s)
                                       for t, s in res0[1].items()}
    audited = list(stats0)  # schedule order (executor preserves it)

    # weights/payload dicts are always passed (possibly empty — _call
    # drops what the executor was not built to take), so in_axes is
    # structurally fixed regardless of the sampled kinds
    vex = jax.jit(jax.vmap(_call, in_axes=(None, 0, 0)))

    records: List[TrialRecord] = []
    replay_ex: Dict[int, Callable] = {}
    for lo in range(0, trials, chunk):
        batch = plans[lo:lo + chunk]
        bw = {n: np.stack([_trial_weights(qm, p, [n])[n] for p in batch])
              for n in w_touched}
        pays = [_trial_payload(p, a_touched, slots) for p in batch]
        bp = {t: (np.stack([pp[t][0] for pp in pays]),
                  np.stack([pp[t][1] for pp in pays]))
              for t in a_touched}
        res = vex(jnp.asarray(x), bw, bp)
        ys = np.asarray(res[0])
        sts = {t: np.asarray(s) for t, s in res[1].items()}
        ckpts = ({bn: {t: np.asarray(a) for t, a in env.items()}
                  for bn, env in res[2].items()} if boundaries else {})

        flags = _flag_matrix(sts, stats0, audited, margin, sat_tol)
        diff = np.array([not np.array_equal(ys[i], y0)
                         for i in range(len(batch))])
        # audit keys are tensors; records carry stage names
        t2s = {ql.info.output: ql.info.name for ql in stages}
        chunk_recs: List[TrialRecord] = []
        for i, p in enumerate(batch):
            flagged = tuple(t2s[t] for t, hit in zip(audited, flags[i])
                            if hit and t in t2s)
            outcome = ("detected" if flagged
                       else ("masked" if not diff[i] else "silent"))
            chunk_recs.append(TrialRecord(
                plan=p,
                stages=tuple(dict.fromkeys(f.stage for f in p.faults)),
                flagged=flagged, outcome=outcome,
                output_differs=bool(diff[i])))

        # ---- vectorized recovery for the detected trials ------------
        by_boundary: Dict[Optional[int], List[int]] = {}
        for i, r in enumerate(chunk_recs):
            if r.outcome != "detected":
                continue
            first = min(stage_idx[s] for s in r.flagged)
            cands = [b for b in boundaries if b < first]
            by_boundary.setdefault(max(cands) if cands else None,
                                   []).append(i)
        for b, idxs in by_boundary.items():
            if b is None:  # no upstream snapshot: full golden reexec
                for i in idxs:
                    chunk_recs[i].recovered = True
                    chunk_recs[i].escalated = True
                    chunk_recs[i].replayed = len(stages)
                continue
            if b not in replay_ex:
                rex = pipe.make_executor(
                    qm, n_i=n_i, n_l=n_l, block_h=block_h,
                    interpret=interpret, audit=True, replay_from=b)
                replay_ex[b] = jax.jit(jax.vmap(rex))
            env = {t: a[np.asarray(idxs)]
                   for t, a in ckpts[stage_names[b]].items()}
            yr, str_ = replay_ex[b](env)
            yr = np.asarray(yr)
            str_ = {t: np.asarray(s) for t, s in str_.items()}
            rf = _flag_matrix(str_, stats0, list(str_), margin, sat_tol)
            for j, i in enumerate(idxs):
                clean = (not rf[j].any()) and np.array_equal(yr[j], y0)
                chunk_recs[i].recovered = True  # escalation recovers too
                chunk_recs[i].escalated = not clean
                chunk_recs[i].replayed = (len(stages) if not clean
                                          else len(stages) - (b + 1))
        records.extend(chunk_recs)

    return Campaign(model=parsed.name, flips=flips, kinds=tuple(kinds),
                    seed=seed, boundaries=boundaries,
                    boundary_names=bnames, n_stages=len(stages),
                    records=records)


# ------------------------------------------------- selective hardening

def derive_guard_policy(campaigns: Sequence[Campaign], parsed,
                        margin: float = 0.0, sat_tol: float = 0.0,
                        checkpoint_replay: bool = True):
    """Derive a selectively-hardened :class:`GuardPolicy` from campaign
    evidence: the minimal audit-stage set (greedy set cover) whose
    flagged sets cover every trial whose upset reached the output.

    The output stage is always audited (the guard certifies final
    outputs against its envelope).  Raises if any campaign observed a
    silent corruption — an audit subset derived from evidence that
    already misses upsets would launder the miss into policy."""
    from .guard import GuardPolicy

    silent = sum(c.counts()["silent"] for c in campaigns)
    if silent:
        raise ValueError(
            f"{silent} silent corruption(s) observed: no audit subset "
            "covers an upset no audit saw — fix detection first")
    out_stage = parsed.layers[-1].name
    need = [set(r.flagged) for c in campaigns for r in c.records
            if r.output_differs]
    chosen = {out_stage}
    uncovered = [s for s in need if not (s & chosen)]
    order = {li.name: i for i, li in enumerate(parsed.layers)}
    while uncovered:
        gain: Dict[str, int] = {}
        for s in uncovered:
            for st in s:
                gain[st] = gain.get(st, 0) + 1
        best = max(gain, key=lambda st: (gain[st], -order[st]))
        chosen.add(best)
        uncovered = [s for s in uncovered if best not in s]
    sel = tuple(sorted(chosen, key=lambda st: order[st]))
    return GuardPolicy(margin=margin, sat_tol=sat_tol,
                       checkpoint_replay=checkpoint_replay,
                       audit_stages=sel)


# --------------------------------------------------------------- CLI

_MODELS = ("resnet_tiny", "googlenet_tiny", "tiny_cnn", "tiny_cnn_gap",
           "mobilenet_tiny", "squeezenet_tiny")


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser(
        description="Vectorized SEU soft-error-rate campaign "
                    "(DESIGN.md §11)")
    ap.add_argument("--model", default="resnet_tiny", choices=_MODELS)
    ap.add_argument("--trials", type=int, default=100)
    ap.add_argument("--flips", default="1",
                    help="comma-separated fault counts per trial")
    ap.add_argument("--kinds", default=F.WEIGHT_BIT,
                    help=f"comma-separated subset of {CAMPAIGN_KINDS}")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoints", type=int, default=2)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--out", default=None, help="write campaign JSON")
    ap.add_argument("--derive-policy", action="store_true",
                    help="emit the selective-hardening audit set")
    ap.add_argument("--assert-silent", action="store_true",
                    help="exit non-zero if any trial was silent "
                         "(undetected AND output-corrupting) — the CI "
                         "gate")
    args = ap.parse_args(argv)

    from repro.core.synthesis import CNN2Gate
    from repro.models import cnn

    graph = getattr(cnn, args.model)(batch=1)
    gate = CNN2Gate.from_graph(graph)
    rng = np.random.default_rng(args.seed)
    shape = gate.parsed.input_shape
    x = (rng.standard_normal(shape) * 0.5).astype(np.float32)
    gate.calibrate_quantization(x)

    kinds = tuple(k.strip() for k in args.kinds.split(",") if k.strip())
    campaigns = []
    for flips in (int(f) for f in args.flips.split(",")):
        c = run_campaign(gate, x, trials=args.trials, flips=flips,
                         kinds=kinds, seed=args.seed,
                         checkpoints=args.checkpoints, chunk=args.chunk)
        s = c.summary()
        cnt = s["counts"]
        print(f"[ser] {args.model} flips={flips} trials={c.trials}: "
              f"detected {cnt['detected']} masked {cnt['masked']} "
              f"silent {cnt['silent']} "
              f"(replay avg {s['mean_replayed_stages']:.1f}/"
              f"{s['n_stages']} stages)")
        campaigns.append(c)

    doc: Dict = {"version": SCHEMA_VERSION, "model": args.model,
                 "trials": args.trials, "seed": args.seed,
                 "kinds": list(kinds),
                 "campaigns": [c.summary() for c in campaigns]}
    if args.derive_policy:
        pol = derive_guard_policy(campaigns, gate.parsed)
        doc["derived_policy"] = {
            "audit_stages": list(pol.audit_stages),
            "n_audited": len(pol.audit_stages),
            "n_stages": len(gate.parsed.layers),
        }
        print(f"[ser] selective audit: {len(pol.audit_stages)}/"
              f"{len(gate.parsed.layers)} stages: "
              f"{list(pol.audit_stages)}")
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
        print(f"[ser] wrote {args.out}")
    if args.assert_silent:
        n_silent = sum(c.counts()["silent"] for c in campaigns)
        if n_silent:
            raise SystemExit(f"[ser] FAIL: {n_silent} silent "
                             "corruption(s) escaped the audit")
        print("[ser] silent == 0 across all campaigns")
    return doc


if __name__ == "__main__":
    main()
