"""Per-stage telemetry: a metrics registry + span tracing (DESIGN.md §12).

CNN2Gate's DSE only works because the tool can *see* where time and
memory go per layer (the paper's Table-1 breakdowns drive the RL
agent).  This module is the observability substrate that turns our
modeled numbers into audited ones:

  * :class:`MetricsRegistry` — thread-safe **counters**, **gauges** and
    fixed-bucket **histograms** with a JSON-ready :meth:`snapshot`.
    Every consumer (guard rungs, DSE evaluations, serve requests)
    counts through one registry, so a single snapshot answers "what
    happened in this process" without log scraping.
  * :class:`Tracer` — **span tracing** exporting Chrome-trace /
    Perfetto-loadable JSON (``trace.json``): complete events
    (``ph="X"``) with ``ts``/``dur`` in microseconds, ``pid``/``tid``,
    a category and free-form ``args``.  Spans nest naturally per
    thread (Perfetto infers nesting from containment on one track).
    Spans measured elsewhere (the stage-timed executor's
    ``block_until_ready`` wall times) are injected via
    :meth:`Tracer.add_span`.

Dependency-free on purpose: the stdlib (``threading``, ``time``,
``json``) is the whole footprint, so the int8 runtime, the DSE sweeps
and the serving loop can all afford always-on telemetry.

Module-level defaults (:func:`get_registry` / :func:`get_tracer`) give
the instrumented consumers a shared sink without threading a handle
through every constructor; tests and CLIs that need isolation pass
their own instances or call :func:`reset`.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Tracer",
    "get_registry", "get_tracer", "reset",
    "DEFAULT_LATENCY_BUCKETS_S",
]

#: Default histogram bucket upper bounds for request/stage latencies in
#: seconds — log-spaced from 100 µs to 100 s (everything above the last
#: edge lands in the +Inf overflow bucket).
DEFAULT_LATENCY_BUCKETS_S: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0)


class Counter:
    """Monotonic counter.  ``inc`` is atomic under the registry lock."""

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters only go up (inc({n}))")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Point-in-time value (queue depth, active slots, ...)."""

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket histogram with quantile estimation.

    ``buckets`` are the finite upper bounds, strictly increasing; an
    implicit +Inf bucket catches the overflow.  A value lands in the
    first bucket whose bound is ``>= value`` (inclusive upper edges,
    the Prometheus ``le`` convention).  :meth:`percentile` linearly
    interpolates within the winning bucket, clamped to the observed
    ``[min, max]`` so tiny samples don't report a bucket edge nobody
    measured.
    """

    def __init__(self, lock: threading.Lock,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S):
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b2 <= b1 for b1, b2
                             in zip(bounds, bounds[1:])):
            raise ValueError("histogram buckets must be a non-empty "
                             "strictly increasing sequence")
        self._lock = lock
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # +Inf overflow bucket
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def record(self, v: float) -> None:
        v = float(v)
        with self._lock:
            i = 0
            while i < len(self.bounds) and v > self.bounds[i]:
                i += 1
            self.counts[i] += 1
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)

    def percentile(self, q: float) -> Optional[float]:
        """Estimated q-th percentile (q in [0, 100]); ``None`` when
        empty.  Overflow-bucket hits report the observed max (the only
        honest number for an unbounded bucket)."""
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile out of range: {q}")
        with self._lock:
            if self.count == 0:
                return None
            target = q / 100.0 * self.count
            acc = 0
            for i, c in enumerate(self.counts):
                acc += c
                if acc >= target and c:
                    if i >= len(self.bounds):      # +Inf bucket
                        return self.max
                    lo = self.bounds[i - 1] if i else (self.min or 0.0)
                    hi = self.bounds[i]
                    frac = (target - (acc - c)) / c
                    est = lo + (hi - lo) * frac
                    return min(max(est, self.min), self.max)
            return self.max

    @property
    def mean(self) -> Optional[float]:
        with self._lock:
            return self.sum / self.count if self.count else None


class MetricsRegistry:
    """Named metric namespace.  ``counter``/``gauge``/``histogram`` are
    get-or-create (idempotent, so instrumentation sites never race on
    registration); registering one name as two different kinds raises.
    ``snapshot()`` returns a plain JSON-serializable dict — the process
    observability payload the profile report and serve stats embed."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, kind, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                # per-metric lock: hot-path inc/record never contends
                # with unrelated metrics or with registration
                m = self._metrics[name] = kind(threading.Lock(), *args)
            elif type(m) is not kind:
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {kind.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S
                  ) -> Histogram:
        return self._get(name, Histogram, buckets)

    def snapshot(self) -> Dict[str, Dict]:
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, Dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        for name, m in sorted(items):
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                assert isinstance(m, Histogram)
                out["histograms"][name] = {
                    "count": m.count, "sum": m.sum,
                    "min": m.min, "max": m.max, "mean": m.mean,
                    "p50": m.percentile(50), "p95": m.percentile(95),
                    "p99": m.percentile(99),
                    "buckets": list(m.bounds),
                    "bucket_counts": list(m.counts),
                }
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


class Tracer:
    """Span recorder exporting the Chrome trace-event format.

    Spans are **complete events** (``ph="X"``): one record with a start
    timestamp and a duration, both in microseconds relative to the
    tracer's epoch.  Perfetto and chrome://tracing load the exported
    file directly; nesting is inferred per ``tid`` from containment,
    which live :meth:`span` blocks guarantee by construction (a nested
    ``with`` closes before its parent).

    ``max_events`` bounds memory: past it the tracer drops new events
    and counts them in ``dropped`` (an always-on serving loop must
    never grow a trace without limit).
    """

    def __init__(self, max_events: int = 200_000):
        self._lock = threading.Lock()
        self._events: List[Dict] = []
        self._epoch = time.perf_counter()
        self.max_events = max_events
        self.dropped = 0

    def now_us(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e6

    def add_span(self, name: str, ts_us: float, dur_us: float,
                 cat: str = "", args: Optional[Dict] = None,
                 tid: Optional[int] = None) -> None:
        """Record an externally-timed span (e.g. a stage wall time the
        stage-timed executor measured around ``block_until_ready``)."""
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": float(ts_us), "dur": float(dur_us),
              "pid": os.getpid(),
              "tid": int(tid) if tid is not None
              else threading.get_ident()}
        if args:
            ev["args"] = dict(args)
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append(ev)

    @contextmanager
    def span(self, name: str, cat: str = "",
             args: Optional[Dict] = None):
        """Time a block and record it as one complete event.  The span
        is recorded even when the block raises (with ``error`` in its
        args) — a failed DSE evaluation still shows up in the trace."""
        t0 = self.now_us()
        err: Optional[str] = None
        try:
            yield self
        except BaseException as e:
            err = f"{type(e).__name__}: {e}"
            raise
        finally:
            a = dict(args) if args else {}
            if err is not None:
                a["error"] = err
            self.add_span(name, t0, self.now_us() - t0, cat=cat,
                          args=a or None)

    def events(self) -> List[Dict]:
        with self._lock:
            return [dict(e) for e in self._events]

    def to_chrome_trace(self) -> Dict:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write ``trace.json`` (load it in Perfetto / chrome://tracing).
        Returns the path."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)
        return path

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0
            self._epoch = time.perf_counter()


# ------------------------------------------------- module-level defaults

_registry = MetricsRegistry()
_tracer = Tracer()


def get_registry() -> MetricsRegistry:
    """The process-default registry (what instrumented consumers use
    when not handed an explicit one)."""
    return _registry


def get_tracer() -> Tracer:
    """The process-default tracer."""
    return _tracer


def reset() -> None:
    """Clear the default registry and tracer (test isolation)."""
    _registry.reset()
    _tracer.reset()
