"""Guarded execution: per-stage audits + a declarative degradation
policy over the int8 runtime (DESIGN.md §9).

The fused executor is a single jitted closure; the guard rides on it
without breaking that property.  ``make_executor(audit=True)`` makes
the *same* closure additionally return per-stage int8 statistics
(saturation fraction, max |value|, mean |value| — computed on-device,
three scalars per stage, negligible next to the conv bands).  The
guard then performs a **host-side dequant audit**: each stage's stats
are scaled by the tensor's fixed-point position (``2^-m`` from
:func:`pipeline.thread_scales`) and compared against calibration-time
envelopes recorded from the *golden* program.  A stage outside its
envelope — saturating more than calibration ever saw, or with a mean
magnitude drifted past the margin — is flagged as a suspected upset.

Degradation ladder (in order; each rung audits its own output):

  0. ``checkpoint_replay``  — when the executor was built with
     stage-boundary checkpoints, localize the fault (the earliest
     flagged stage), take the nearest snapshot strictly upstream of it
     and replay only the downstream stages on the *golden* program.
     Bit-exact against full golden reexecution, at a cost bounded by
     the stages downstream of the fault instead of the network depth
     (DESIGN.md §11).  A snapshot poisoned by an unflagged upstream
     upset re-flags on the replay's own audit and escalates.
  1. ``reexecute``          — run the same program again.  Recovers
     transient in-flight upsets (an SEU in a line buffer does not
     repeat); a persistent fault (corrupted staged weight) re-flags
     and escalates.
  2. ``fallback:unfused``   — rebuild from the golden graph + specs
     with ``fuse_skip=False, fuse_concat=False`` (the bit-exact
     standalone-merge program that always exists) and re-run.  This is the FPGA
     reconfigure-from-flash move: the corrupted staged image is
     abandoned for a freshly staged one on the fallback datapath.
  3. ``fallback:per_tensor`` — additionally degrade per-channel weight
     scales to per-tensor (``m_w := min(m_w)`` per layer, the max-abs
     rule's scalar answer).  Numerically coarser but structurally
     simpler — the last rung before giving up.  Skipped when the
     program is already per-tensor.

With guards *off* the builder returns the plain
``pipeline.make_executor`` closure — byte-identical program, probed by
jaxpr identity in the tests.  Fallback programs and their envelopes
are built lazily on first escalation and cached, so a healthy guarded
deployment pays only the three-scalar audit.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import parser as P
from . import pipeline as pipe
from . import telemetry as tele
from .quantize import QuantSpec


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    """Declarative degradation policy + audit tolerances.

    ``margin`` is the relative slack on the dequantized max/mean
    statistics (0.25 = 25% drift allowed); ``sat_tol`` is absolute
    slack on the saturation fraction.  Tight values (0.0) make the
    audit flag *any* deviation from the calibration run — what the
    deterministic fault-injection tests use."""

    margin: float = 0.25
    sat_tol: float = 0.02
    checkpoint_replay: bool = True
    retry: bool = True
    fallback_unfused: bool = True
    fallback_per_tensor: bool = True
    #: selective hardening (DESIGN.md §11): audit only these stages
    #: (by stage name; ``None`` audits every stage).  Derived from a SER
    #: campaign by :func:`repro.core.ser.derive_guard_policy` — the
    #: minimal stage set whose audits cover every observed
    #: output-reaching upset, closing most of the full-audit overhead.
    audit_stages: Optional[Tuple[str, ...]] = None


@dataclasses.dataclass(frozen=True)
class GuardEnvelope:
    """Calibration-time expected ranges, float (dequantized) domain:
    ``tensor -> (sat_frac, max_abs, mean_abs)``."""

    stats: Dict[str, Tuple[float, float, float]]


@dataclasses.dataclass
class StageAudit:
    """One stage's audited statistics vs. its envelope."""

    stage: str
    tensor: str
    sat: float
    max_abs: float
    mean_abs: float
    flagged: bool
    reasons: Tuple[str, ...] = ()


@dataclasses.dataclass
class ActionResult:
    """One degradation-ladder rung: which stages were still flagged
    after applying it (empty = the rung recovered the run).  The
    checkpoint-replay rung additionally records how many stages it
    re-ran (``replayed``) and from which snapshot (``boundary``)."""

    action: str
    flagged: List[str]
    replayed: Optional[int] = None
    boundary: Optional[str] = None


@dataclasses.dataclass
class GuardReport:
    """Structured outcome of one guarded inference."""

    flagged: List[str]          # stages flagged on the primary run
    audits: List[StageAudit]    # primary-run audit detail
    actions: List[ActionResult]
    recovered_by: Optional[str]
    degraded: bool              # served from a fallback program
    ok: bool                    # final output passed its audit

    @property
    def detected(self) -> bool:
        return bool(self.flagged)

    @property
    def outcome(self) -> str:
        """One-word outcome for deployment counters (launch/serve.py):
        ``clean`` (no flags), ``checkpoint_replayed`` / ``reexecuted``
        / ``fell_back`` (which ladder rung recovered), ``unrecovered``
        (every rung exhausted still out of envelope).  Upsets the audit
        never sees are *masked* — invisible here by definition; their
        rate is what the offline SER campaign (core/ser.py) measures."""
        if not self.detected:
            return "clean"
        if not self.ok:
            return "unrecovered"
        if self.recovered_by == "checkpoint_replay":
            return "checkpoint_replayed"
        if self.recovered_by == "reexecute":
            return "reexecuted"
        return "fell_back"


@dataclasses.dataclass
class _Level:
    """One executable program level: the quantized program, its audited
    one-jitted closure, per-tensor fixed-point positions and the
    calibration envelope recorded from it."""

    qm: pipe.QuantizedModel
    ex: Callable
    tensor_m: Dict[str, int]
    envelope: GuardEnvelope


def _scalar_specs(specs: Dict[str, QuantSpec]) -> Dict[str, QuantSpec]:
    """Degrade per-channel specs to per-tensor: every lane quantizes at
    the minimum lane exponent (the scalar max-abs answer — the lane
    with the largest weights already pinned it)."""
    return {name: (dataclasses.replace(s, m_w=s.m_w_min)
                   if s.per_channel else s)
            for name, s in specs.items()}


class GuardedExecutor:
    """Audited executor + degradation ladder over a built program.

    ``gate`` is the golden source of truth (a
    :class:`~repro.core.synthesis.CNN2Gate` with quantization applied):
    fallback programs are rebuilt from its graph and specs, exactly as
    an FPGA would reconfigure from the golden image in flash.  ``qm``
    is the *deployed* program — pass a fault-injected model (and/or
    ``faults`` for in-flight activation faults) to exercise the guard;
    it defaults to the golden program itself.

    ``checkpoints`` arms the stage-boundary recovery rung: an int K asks
    :func:`resources.plan_checkpoints` for the equal-cumulative-MAC
    placement, a sequence pins explicit boundary indices, and
    ``None``/0 disables the rung (the primary program then snapshots
    nothing and the jitted closure is unchanged).

    Calling the executor returns ``(logits, GuardReport)``.
    """

    def __init__(self, gate, x_cal, policy: Optional[GuardPolicy] = None,
                 qm: Optional[pipe.QuantizedModel] = None,
                 n_i: int = 16, n_l: int = 32,
                 block_h: Optional[int] = None,
                 interpret: Optional[bool] = True,
                 faults: Optional[Dict] = None,
                 checkpoints=None,
                 registry: Optional[tele.MetricsRegistry] = None,
                 tracer: Optional[tele.Tracer] = None):
        if gate.quantized is None or gate.specs is None:
            raise RuntimeError("apply_quantization() or "
                               "calibrate_quantization() first")
        self.gate = gate
        self.policy = policy or GuardPolicy()
        # telemetry (DESIGN.md §12): rung spans + outcome counters go
        # to the process-default sinks unless the deployment passes its
        # own (e.g. the serve loop sharing one registry per replica)
        self._registry = registry if registry is not None\
            else tele.get_registry()
        self._tracer = tracer if tracer is not None else tele.get_tracer()
        self._kw = dict(n_i=n_i, n_l=n_l, block_h=block_h,
                        interpret=interpret)
        golden = gate.quantized
        self._stage_idx = {ql.info.name: i
                           for i, ql in enumerate(golden.layers)}
        if checkpoints is None:
            self._boundaries: Tuple[int, ...] = ()
        elif isinstance(checkpoints, int):
            from . import resources as R
            self._boundaries = R.plan_checkpoints(gate.parsed, checkpoints)
        else:
            self._boundaries = tuple(sorted({int(c) for c in checkpoints}))
        # prove the boundaries before any executor is built: deploying a
        # guard whose recovery snapshots sit at illegal boundaries would
        # only surface at the first escalation, mid-incident
        from . import verify as verify_mod
        bad = verify_mod.check_checkpoint_boundaries(gate.parsed,
                                                     self._boundaries)
        if bad:
            raise verify_mod.VerificationError(bad)
        # selective hardening: audit only the policy's stage subset
        # (translated to output-tensor names, the executor's audit key)
        if self.policy.audit_stages is None:
            self._audit = True
        else:
            sel = set(self.policy.audit_stages)
            unknown = sel - set(self._stage_idx)
            if unknown:
                raise ValueError("audit_stages name unknown stages: "
                                 f"{sorted(unknown)}")
            self._audit = tuple(ql.info.output for ql in golden.layers
                                if ql.info.name in sel)
        self.x_cal = jnp.asarray(x_cal)
        self._gold = self._make_level(golden, gate.specs)
        qm = golden if qm is None else qm
        if qm is golden and not faults and not self._boundaries:
            primary_ex = self._gold.ex
        else:
            primary_ex = pipe.make_executor(
                qm, audit=self._audit, faults=faults,
                checkpoints=self._boundaries or None, **self._kw)
        self._primary = (qm, primary_ex)
        self._fallbacks: Dict[str, Optional[_Level]] = {}
        #: boundary index -> jitted golden replay closure, built lazily
        #: on first escalation and cached (like the fallback levels)
        self._replays: Dict[int, Callable] = {}

    def with_program(self, qm: pipe.QuantizedModel,
                     faults: Optional[Dict] = None) -> "GuardedExecutor":
        """Cheap re-deployment: a new guarded executor over a different
        (e.g. freshly fault-injected) program that SHARES this one's
        golden envelope and already-built fallback levels — what the
        fault-injection bench sweeps trial programs through."""
        other = object.__new__(GuardedExecutor)
        other.__dict__ = dict(self.__dict__)
        other._primary = (qm, pipe.make_executor(
            qm, audit=self._audit, faults=faults,
            checkpoints=self._boundaries or None, **self._kw))
        return other

    # ------------------------------------------------ level construction
    def _make_level(self, qm: pipe.QuantizedModel,
                    specs: Dict[str, QuantSpec]) -> _Level:
        ex = pipe.make_executor(qm, audit=self._audit, **self._kw)
        tensor_m = pipe.thread_scales(qm.parsed, specs)
        _, stats = ex(self.x_cal)
        env = {t: self._dequant(t, np.asarray(s), tensor_m)
               for t, s in stats.items()}
        return _Level(qm, ex, tensor_m, GuardEnvelope(env))

    def _replay_ex(self, boundary: int) -> Callable:
        """The golden program's replay closure from one boundary: runs
        only stages ``boundary+1 ..`` off a snapshot environment."""
        if boundary not in self._replays:
            self._replays[boundary] = pipe.make_executor(
                self.gate.quantized, audit=self._audit,
                replay_from=boundary, **self._kw)
        return self._replays[boundary]

    @staticmethod
    def _dequant(tensor: str, s: np.ndarray,
                 tensor_m: Dict[str, int]) -> Tuple[float, float, float]:
        scale = 2.0 ** -tensor_m.get(tensor, 0)
        return (float(s[0]), float(s[1]) * scale, float(s[2]) * scale)

    def _fallback(self, name: str) -> Optional[_Level]:
        if name not in self._fallbacks:
            parsed_u = P.parse(self.gate.parsed.graph, fuse_skip=False,
                               fuse_concat=False)
            if name == "unfused":
                specs = dict(self.gate.specs)
            else:  # per_tensor (implies unfused: the simplest datapath)
                if not any(s.per_channel for s in self.gate.specs.values()):
                    self._fallbacks[name] = None
                    return None
                specs = _scalar_specs(self.gate.specs)
            qm = pipe.build_quantized(parsed_u, specs)
            self._fallbacks[name] = self._make_level(qm, specs)
        return self._fallbacks[name]

    # ------------------------------------------------------------- audit
    def _check(self, qm: pipe.QuantizedModel, stats: Dict,
               level: _Level) -> List[StageAudit]:
        """Host-side dequant audit of one run against a level's
        calibration envelope, in schedule order.  Tensors without an
        envelope entry (extra intermediates of a fallback program) are
        skipped."""
        pol = self.policy
        audits: List[StageAudit] = []
        for ql in qm.layers:
            t = ql.info.output
            if t not in stats or t not in level.envelope.stats:
                continue
            sat, mx, mean = self._dequant(t, np.asarray(stats[t]),
                                          level.tensor_m)
            e_sat, e_max, e_mean = level.envelope.stats[t]
            reasons = []
            if sat > e_sat + pol.sat_tol:
                reasons.append(f"saturation {sat:.4f} > {e_sat:.4f}")
            if mx > e_max * (1.0 + pol.margin):
                reasons.append(f"max_abs {mx:.4g} > {e_max:.4g}")
            if mean > e_mean * (1.0 + pol.margin) or\
                    mean * (1.0 + pol.margin) < e_mean:
                reasons.append(f"mean_abs {mean:.4g} vs {e_mean:.4g}")
            audits.append(StageAudit(ql.info.name, t, sat, mx, mean,
                                     bool(reasons), tuple(reasons)))
        return audits

    # --------------------------------------------------------- inference
    def __call__(self, x) -> Tuple[jnp.ndarray, GuardReport]:
        """Guarded inference: the primary run, the ladder, and the
        telemetry trail — one ``guard.infer`` span nesting a span per
        rung, plus ``guard.outcome.*`` / ``guard.rung.*`` registry
        counters (DESIGN.md §12)."""
        with self._tracer.span("guard.infer", cat="guard",
                               args={"model": self.gate.parsed.name}):
            y, report = self._infer(x)
        self._registry.counter(f"guard.outcome.{report.outcome}").inc()
        for act in report.actions:
            self._registry.counter(f"guard.rung.{act.action}").inc()
        return y, report

    def _infer(self, x) -> Tuple[jnp.ndarray, GuardReport]:
        x = jnp.asarray(x)
        qm, ex = self._primary
        with self._tracer.span("guard.primary", cat="guard"):
            if self._boundaries:
                y, stats, ckpts = ex(x)
            else:
                (y, stats), ckpts = ex(x), {}
        audits = self._check(qm, stats, self._gold)
        flagged = [a.stage for a in audits if a.flagged]
        if not flagged:
            return y, GuardReport(flagged, audits, [], None, False, True)
        actions: List[ActionResult] = []
        if self._boundaries and self.policy.checkpoint_replay:
            # localize: the earliest flagged stage upper-bounds where
            # the upset entered (audits run in schedule order); replay
            # the GOLDEN program from the nearest snapshot before it —
            # bit-exact vs full golden reexecution by construction,
            # cost bounded by the downstream stage count.  A snapshot
            # poisoned by an unflagged upstream upset re-flags on the
            # replay's own audit below and the ladder escalates.
            first = min(self._stage_idx[s] for s in flagged)
            cands = [b for b in self._boundaries if b < first]
            if cands:
                b = max(cands)
                bname = self.gate.quantized.layers[b].info.name
                n_replayed = len(self.gate.quantized.layers) - (b + 1)
                with self._tracer.span("guard.rung.checkpoint_replay",
                                       cat="guard",
                                       args={"boundary": bname,
                                             "replayed": n_replayed}):
                    yr, statsr = self._replay_ex(b)(ckpts[bname])
                fr = [a.stage
                      for a in self._check(self._gold.qm, statsr,
                                           self._gold) if a.flagged]
                actions.append(ActionResult("checkpoint_replay", fr,
                                            replayed=n_replayed,
                                            boundary=bname))
                if not fr:
                    return yr, GuardReport(flagged, audits, actions,
                                           "checkpoint_replay", False,
                                           True)
        if self.policy.retry:
            with self._tracer.span("guard.rung.reexecute", cat="guard"):
                if self._boundaries:
                    y2, stats2, _ = ex(x)
                else:
                    y2, stats2 = ex(x)
            f2 = [a.stage for a in self._check(qm, stats2, self._gold)
                  if a.flagged]
            actions.append(ActionResult("reexecute", f2))
            if not f2:  # transient upset: same program now in envelope
                return y2, GuardReport(flagged, audits, actions,
                                       "reexecute", False, True)
        for name, enabled in (("unfused", self.policy.fallback_unfused),
                              ("per_tensor",
                               self.policy.fallback_per_tensor)):
            if not enabled:
                continue
            lvl = self._fallback(name)
            if lvl is None:
                continue
            with self._tracer.span(f"guard.rung.fallback:{name}",
                                   cat="guard"):
                yl, statsl = lvl.ex(x)
            fl = [a.stage for a in self._check(lvl.qm, statsl, lvl)
                  if a.flagged]
            actions.append(ActionResult(f"fallback:{name}", fl))
            y = yl
            if not fl:
                return y, GuardReport(flagged, audits, actions, name,
                                      True, True)
        return y, GuardReport(flagged, audits, actions, None, True, False)
