"""Front-end parser: ONNX-lite graph -> linked pipeline of LayerInfo.

This is §4.1's parser: it traverses graph nodes in topological order,
extracts per-layer synthesis information (kernel shape, strides, pads,
dilations, weights, biases), detects the Relu/Softmax activations that
follow compute nodes, and fuses Conv→Relu→MaxPool chains into single
pipeline stages — the paper's "combination of memory read/write,
convolution and pooling kernels" (Fig. 6 caption).  The result is a
linked structure preserving order, which the synthesis tool consumes to
configure hardware pipelines, plus the feasible (N_i, N_l) option sets
derived from the divisibility constraints of §4.2.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import Graph, Node, _norm2, _norm4

# Pipeline stage kinds (the paper's five kernel roles; memory read/write
# kernels bracket every stage implicitly).
CONV = "conv"
POOL = "pool"
FC = "fc"  # Gemm — executed on the conv kernel with pool as pass-through


@dataclasses.dataclass
class LayerInfo:
    """One pipelined stage: conv/fc (+fused relu) (+fused pool)."""

    kind: str
    name: str
    # tensor names
    input: str
    output: str
    weight: Optional[str] = None
    bias: Optional[str] = None
    # shapes (NCHW for conv/pool; (M,K)x(K,N) for fc)
    in_shape: Tuple[int, ...] = ()
    out_shape: Tuple[int, ...] = ()
    # conv/pool attrs
    kernel_shape: Tuple[int, int] = (1, 1)
    strides: Tuple[int, int] = (1, 1)
    pads: Tuple[int, int, int, int] = (0, 0, 0, 0)
    dilations: Tuple[int, int] = (1, 1)
    group: int = 1
    # fused ops
    relu: bool = False
    softmax: bool = False
    pool: Optional["LayerInfo"] = None  # fused pooling stage
    pool_type: str = "max"              # max | avg (standalone pools)
    # linked structure (paper: "saves layers in a linked structure")
    prev: Optional["LayerInfo"] = dataclasses.field(default=None, repr=False)
    next: Optional["LayerInfo"] = dataclasses.field(default=None, repr=False)

    # -- derived quantities used by synthesis & DSE ---------------------
    @property
    def c_in(self) -> int:
        if self.kind == FC:
            return int(self.in_shape[-1])
        return int(self.in_shape[1])

    @property
    def c_out(self) -> int:
        if self.kind == FC:
            return int(self.out_shape[-1])
        return int(self.out_shape[1])

    @property
    def conv_out_shape(self) -> Tuple[int, ...]:
        """Output of the compute stage itself (pre-pool when fused)."""
        return self.pool.in_shape if self.pool is not None else self.out_shape

    @property
    def macs(self) -> int:
        """Multiply-accumulate count of the compute stage."""
        if self.kind == FC:
            m, k = self.in_shape[-2], self.in_shape[-1]
            n = self.out_shape[-1]
            return int(m * k * n)
        n, c_out, h, w = self.conv_out_shape
        kh, kw = self.kernel_shape
        return int(n * c_out * h * w * kh * kw * (self.c_in // self.group))

    @property
    def ops(self) -> int:
        """GOp convention of the paper's Tables 3/4: 2 ops per MAC."""
        return 2 * self.macs

    def weight_count(self) -> int:
        if self.weight is None:
            return 0
        if self.kind == FC:
            return int(self.c_in * self.c_out)
        kh, kw = self.kernel_shape
        return int(self.c_out * (self.c_in // self.group) * kh * kw)


@dataclasses.dataclass
class ParsedModel:
    """Linked pipeline + option sets; what the synthesizer consumes."""

    name: str
    layers: List[LayerInfo]
    graph: Graph
    input_name: str
    input_shape: Tuple[int, ...]
    output_name: str

    @property
    def head(self) -> LayerInfo:
        return self.layers[0]

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def total_ops(self) -> int:
        return sum(l.ops for l in self.layers)

    @property
    def total_weights(self) -> int:
        return sum(l.weight_count() for l in self.layers)

    # -- §4.2 divisibility constraints ----------------------------------
    def feasible_ni(self, cap: int = 64) -> List[int]:
        """N_i must divide the input-channel (vector) width of every
        compute layer to avoid padding.  The first conv layer's 3-channel
        RGB input is zero-padded to the vector width by the memory-read
        kernel (as PipeCNN does), so it is exempt."""
        cands = []
        widths = [l.c_in for l in self.layers[1:] if l.kind in (CONV, FC)]
        for ni in range(1, cap + 1):
            if _pow2(ni) and all(w % ni == 0 for w in widths):
                cands.append(ni)
        return cands

    def feasible_nl(self, cap: int = 64) -> List[int]:
        """N_l must divide the number of output features of every layer
        to avoid idle lanes.  The final classifier layer is exempt: its
        odd-sized output (e.g. 1000 classes) is zero-padded up to a lane
        multiple by the memory-write kernel, as PipeCNN does — without
        this the paper's own (16, 32) Arria-10 choice would be
        infeasible for AlexNet/VGG."""
        cands = []
        feats = [l.c_out for l in self.layers[:-1] if l.kind in (CONV, FC)]
        for nl in range(1, cap + 1):
            if _pow2(nl) and all(f % nl == 0 for f in feats):
                cands.append(nl)
        return cands

    def hardware_options(self, cap: int = 64) -> List[Tuple[int, int]]:
        """All feasible (N_i, N_l) pairs — the DSE search space."""
        return [(ni, nl) for ni in self.feasible_ni(cap) for nl in self.feasible_nl(cap)]


def _pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def parse(graph: Graph) -> ParsedModel:
    """Traverse the graph and emit the linked pipeline structure."""
    layers: List[LayerInfo] = []
    consumed: set = set()

    node_list = graph.nodes
    i = 0
    while i < len(node_list):
        node = node_list[i]
        i += 1
        if node.name in consumed:
            continue
        if node.op_type in ("Flatten", "Reshape", "Dropout", "Identity"):
            continue  # pure data-movement; handled by memory-read schedule
        if node.op_type == "Conv":
            li = _conv_layer(graph, node)
        elif node.op_type in ("Gemm", "MatMul"):
            li = _fc_layer(graph, node)
        elif node.op_type in ("MaxPool", "AveragePool", "GlobalAveragePool"):
            # standalone pool (not fused behind a conv)
            li = _pool_layer(graph, node)
        elif node.op_type in ("Relu", "Softmax", "Add"):
            raise_if_unfused(graph, node, layers)
            continue
        else:
            continue
        # fuse activation + pool chains greedily
        _fuse_chain(graph, li, consumed)
        layers.append(li)

    if not layers:
        raise ValueError(f"graph {graph.name!r} contains no compute layers")

    # link the list (the paper's order-preserving structure)
    for a, b in zip(layers, layers[1:]):
        a.next, b.prev = b, a

    inp = graph.inputs[0]
    return ParsedModel(
        name=graph.name,
        layers=layers,
        graph=graph,
        input_name=inp.name,
        input_shape=tuple(inp.shape),
        output_name=layers[-1].output,
    )


def raise_if_unfused(graph: Graph, node: Node, layers: List[LayerInfo]) -> None:
    """Activations should have been fused into the producing layer; a
    dangling one (e.g. Relu straight on the graph input) is unsupported
    by the pipelined kernel library."""
    for li in layers:
        if li.output == node.inputs[0] or (li.pool and li.pool.output == node.inputs[0]):
            return
        if node.outputs[0] in (li.output,):
            return
    # Softmax on the classifier output is recognised as fused elsewhere.
    raise ValueError(
        f"standalone {node.op_type} node {node.name!r} cannot be mapped to "
        "the pipelined kernel library"
    )


def _conv_layer(graph: Graph, node: Node) -> LayerInfo:
    w_name = node.inputs[1]
    b_name = node.inputs[2] if len(node.inputs) > 2 else None
    w_shape = graph.shape(w_name)
    return LayerInfo(
        kind=CONV,
        name=node.name,
        input=node.inputs[0],
        output=node.outputs[0],
        weight=w_name,
        bias=b_name,
        in_shape=graph.shape(node.inputs[0]),
        out_shape=graph.shape(node.outputs[0]),
        kernel_shape=_norm2(node.attr("kernel_shape", (w_shape[2], w_shape[3]))),
        strides=_norm2(node.attr("strides", 1)),
        pads=_norm4(node.attr("pads")),
        dilations=_norm2(node.attr("dilations", 1)),
        group=int(node.attr("group", 1)),
    )


def _fc_layer(graph: Graph, node: Node) -> LayerInfo:
    w_name = node.inputs[1]
    b_name = node.inputs[2] if len(node.inputs) > 2 else None
    return LayerInfo(
        kind=FC,
        name=node.name,
        input=node.inputs[0],
        output=node.outputs[0],
        weight=w_name,
        bias=b_name,
        in_shape=graph.shape(node.inputs[0]),
        out_shape=graph.shape(node.outputs[0]),
    )


def _pool_layer(graph: Graph, node: Node) -> LayerInfo:
    if node.op_type == "GlobalAveragePool":
        in_shape = graph.shape(node.inputs[0])
        ks: Tuple[int, int] = (in_shape[2], in_shape[3])
        st: Tuple[int, int] = (1, 1)
    else:
        ks = _norm2(node.attr("kernel_shape"))
        st = _norm2(node.attr("strides", ks[0]))
    return LayerInfo(
        kind=POOL,
        name=node.name,
        input=node.inputs[0],
        output=node.outputs[0],
        in_shape=graph.shape(node.inputs[0]),
        out_shape=graph.shape(node.outputs[0]),
        kernel_shape=ks,
        strides=st,
        pads=_norm4(node.attr("pads")),
        pool_type="max" if node.op_type == "MaxPool" else "avg",
    )


def _fuse_chain(graph: Graph, li: LayerInfo, consumed: set) -> None:
    """Fuse Relu / MaxPool / Softmax that immediately follow ``li``.

    Mirrors the paper's hardware view: the conv kernel has a fused ReLU
    stage, the pool kernel sits behind it on the pipe, and fully-connected
    layers run on the conv kernel with pooling configured pass-through.
    """
    cur_out = li.output
    while True:
        consumers = [
            n for n in graph.consumers_of(cur_out) if n.name not in consumed
        ]
        # only fuse when the tensor has exactly one consumer (pipe semantics)
        if len(consumers) != 1:
            break
        n = consumers[0]
        if n.op_type == "Relu":
            li.relu = True
            consumed.add(n.name)
            cur_out = n.outputs[0]
            li.output = cur_out
        elif n.op_type == "Softmax":
            li.softmax = True
            consumed.add(n.name)
            cur_out = n.outputs[0]
            li.output = cur_out
        elif n.op_type == "MaxPool" and li.kind == CONV and li.pool is None:
            # only max-pool fuses into the conv kernel (its pooling
            # stage computes max); average pools run standalone
            pool = _pool_layer(graph, n)
            li.pool = pool
            consumed.add(n.name)
            cur_out = n.outputs[0]
            li.output = cur_out
            li.out_shape = pool.out_shape
        elif n.op_type in ("Flatten", "Reshape", "Dropout", "Identity"):
            consumed.add(n.name)
            cur_out = n.outputs[0]
            li.output = cur_out
        else:
            break


def memory_schedule(model: ParsedModel, n_i: int, n_l: int) -> List[Dict[str, Any]]:
    """The host-program memory access schedule of §4.2: for each pipeline
    stage, how many (N_i)-wide vectors the memory-read kernel fetches and
    how many lanes are active.  Consumed by the pipelined executor and the
    FPGA latency model."""
    sched = []
    for li in model.layers:
        if li.kind == FC:
            vec_per_row = -(-li.c_in // n_i)  # ceil
            rows = int(np.prod(li.in_shape[:-1]))
            sched.append(
                dict(
                    layer=li.name,
                    kind=li.kind,
                    read_vectors=rows * vec_per_row,
                    weight_vectors=li.c_out * vec_per_row,
                    lanes=min(n_l, li.c_out),
                    write_elems=int(np.prod(li.out_shape)),
                )
            )
        else:
            n, c_out, h, w = li.out_shape if li.pool is None else li.pool.in_shape
            kh, kw = li.kernel_shape
            vec_per_patch = -(-(li.c_in * kh * kw) // n_i)
            sched.append(
                dict(
                    layer=li.name,
                    kind=li.kind,
                    read_vectors=n * h * w * vec_per_patch,
                    weight_vectors=c_out * vec_per_patch,
                    lanes=min(n_l, c_out),
                    write_elems=int(np.prod(li.out_shape)),
                )
            )
    return sched
