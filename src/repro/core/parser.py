"""Front-end parser: ONNX-lite graph -> DAG stage program of LayerInfo.

This is §4.1's parser: it traverses graph nodes in topological order,
extracts per-layer synthesis information (kernel shape, strides, pads,
dilations, weights, biases), detects the Relu/Softmax activations that
follow compute nodes, and fuses Conv→Relu→MaxPool chains into single
pipeline stages — the paper's "combination of memory read/write,
convolution and pooling kernels" (Fig. 6 caption).

The result is a **topologically-scheduled stage program** over named
tensors (the paper's "extensible acyclic graph"): each stage reads one
or more named input tensors and produces one output tensor, tensors may
have multiple consumers (fan-out), ``Add`` is a first-class
residual-merge stage and ``Concat`` a channel-merge stage — so
ResNet-class skip connections and Inception-style merges schedule
exactly like the linear Conv→Pool→FC chains of the paper's Fig. 6.
Pure data-movement ops (Flatten/Reshape/Dropout/Identity) that are not
fused into a stage are resolved through an alias map, so stage inputs
always name tensors some scheduled stage (or the graph input) produces.
The linked prev/next structure of the paper is preserved over the
schedule order, and the feasible (N_i, N_l) option sets extend the §4.2
divisibility constraints to branch and depthwise layers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .graph import Graph, GraphValidationError, Node, _norm2, _norm4

# Pipeline stage kinds (the paper's five kernel roles; memory read/write
# kernels bracket every stage implicitly).
CONV = "conv"
POOL = "pool"
FC = "fc"  # Gemm — executed on the conv kernel with pool as pass-through
ADD = "add"        # residual merge: elementwise int8 add + requantize
CONCAT = "concat"  # channel merge: int8 concat at a common scale

#: Pure data-movement ops: elided from the stage program (the memory
#: read/write kernels absorb them); unfused occurrences become aliases.
ELIDED_OPS = ("Flatten", "Reshape", "Dropout", "Identity")


@dataclasses.dataclass
class LayerInfo:
    """One pipelined stage: conv/fc (+fused relu) (+fused pool), or a
    residual/channel merge (add/concat) over two or more named tensors."""

    kind: str
    name: str
    # named tensors: every entry of ``inputs`` is produced by an earlier
    # stage in the schedule (or is the graph input); ``output`` is the
    # stage's single product (post-fusion name)
    inputs: List[str]
    output: str
    weight: Optional[str] = None
    bias: Optional[str] = None
    # shapes (NCHW for conv/pool; (M,K)x(K,N) for fc)
    in_shape: Tuple[int, ...] = ()
    out_shape: Tuple[int, ...] = ()
    # conv/pool attrs
    kernel_shape: Tuple[int, int] = (1, 1)
    strides: Tuple[int, int] = (1, 1)
    pads: Tuple[int, int, int, int] = (0, 0, 0, 0)
    dilations: Tuple[int, int] = (1, 1)
    group: int = 1
    axis: int = 1                       # concat axis (NCHW convention)
    # fused ops
    relu: bool = False
    softmax: bool = False
    pool: Optional["LayerInfo"] = None  # fused pooling stage
    pool_type: str = "max"              # max | avg (standalone pools)
    # residual-add epilogue fusion (conv stages only): ``merge`` is the
    # folded Add stage (keeps its name for QuantSpec lookup, its relu
    # flag and its original operand tensors); ``skip_input`` names the
    # second operand — the residual the kernel adds in its epilogue.
    # The conv's own output tensor survives inside ``merge.inputs`` as
    # the *intermediate* the fixed-point threading still scales.
    merge: Optional["LayerInfo"] = dataclasses.field(default=None,
                                                     repr=False)
    skip_input: Optional[str] = None
    # concat-epilogue fusion: a conv whose ``concat`` field references a
    # channel-merge stage writes its output directly into channels
    # ``[concat_offset, concat_offset + c_out)`` of the merge's shared
    # buffer (the concat becomes an output BlockSpec, not a copy).  The
    # Concat stage itself STAYS in the schedule, annotated
    # ``concat_fused`` — it keeps its name, operand tensors, relu flag
    # and (possibly absorbed) pool for quantization threading, and the
    # executor turns it into a buffer hand-off instead of a concatenate.
    concat: Optional["LayerInfo"] = dataclasses.field(default=None,
                                                      repr=False)
    concat_offset: int = 0
    concat_fused: bool = False
    # linked structure (paper: "saves layers in a linked structure")
    prev: Optional["LayerInfo"] = dataclasses.field(default=None, repr=False)
    next: Optional["LayerInfo"] = dataclasses.field(default=None, repr=False)

    # -- derived quantities used by synthesis & DSE ---------------------
    @property
    def input(self) -> str:
        """First (primary) input tensor — the only one for conv/pool/fc."""
        return self.inputs[0]

    @property
    def merge_intermediate(self) -> str:
        """For a conv with a folded residual add: the merge operand the
        conv itself produces (the tensor the unfused program would have
        written to memory between the two stages)."""
        a, b = self.merge.inputs
        return b if a == self.skip_input else a

    @property
    def is_depthwise(self) -> bool:
        return self.kind == CONV and self.group > 1 and \
            self.group == self.c_in and self.c_out == self.c_in

    @property
    def is_dw_kernel(self) -> bool:
        """Runs on the depthwise band kernel: group == Cin with an
        integer channel multiplier (Cout = m·Cin, one filter column per
        group).  Multiplier 1 is classic depthwise."""
        return self.kind == CONV and self.group > 1 and \
            self.group == self.c_in and self.c_out % self.c_in == 0

    @property
    def c_in(self) -> int:
        if self.kind == FC:
            return int(self.in_shape[-1])
        return int(self.in_shape[1])

    @property
    def c_out(self) -> int:
        if self.kind == FC:
            return int(self.out_shape[-1])
        return int(self.out_shape[1])

    @property
    def conv_out_shape(self) -> Tuple[int, ...]:
        """Output of the compute stage itself (pre-pool when fused)."""
        return self.pool.in_shape if self.pool is not None else self.out_shape

    @property
    def macs(self) -> int:
        """Multiply-accumulate count of the compute stage."""
        if self.kind in (ADD, CONCAT):
            return 0  # merge stages: pure adders / data movement, no MACs
        if self.kind == FC:
            m, k = self.in_shape[-2], self.in_shape[-1]
            n = self.out_shape[-1]
            return int(m * k * n)
        n, c_out, h, w = self.conv_out_shape
        kh, kw = self.kernel_shape
        return int(n * c_out * h * w * kh * kw * (self.c_in // self.group))

    @property
    def ops(self) -> int:
        """GOp convention of the paper's Tables 3/4: 2 ops per MAC."""
        return 2 * self.macs

    def weight_count(self) -> int:
        if self.weight is None:
            return 0
        if self.kind == FC:
            return int(self.c_in * self.c_out)
        kh, kw = self.kernel_shape
        return int(self.c_out * (self.c_in // self.group) * kh * kw)


@dataclasses.dataclass
class ParsedModel:
    """Topologically-scheduled stage program + option sets; what the
    synthesizer consumes.  ``layers`` is the schedule: every stage's
    input tensors are produced by an earlier stage or are the graph
    input, so an interpreter can execute the list front to back."""

    name: str
    layers: List[LayerInfo]
    graph: Graph
    input_name: str
    input_shape: Tuple[int, ...]
    output_name: str

    def __post_init__(self) -> None:
        self._producer_stage: Dict[str, LayerInfo] = {
            li.output: li for li in self.layers}

    def stage_producing(self, tensor: str) -> Optional[LayerInfo]:
        """The scheduled stage whose (post-fusion) output is ``tensor``."""
        return self._producer_stage.get(tensor)

    def consumer_stages(self, tensor: str) -> List[LayerInfo]:
        return [li for li in self.layers if tensor in li.inputs]

    @property
    def head(self) -> LayerInfo:
        return self.layers[0]

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def total_ops(self) -> int:
        return sum(l.ops for l in self.layers)

    @property
    def total_weights(self) -> int:
        return sum(l.weight_count() for l in self.layers)

    # -- §4.2 divisibility constraints ----------------------------------
    def feasible_ni(self, cap: int = 64) -> List[int]:
        """N_i must divide the input-channel (vector) width of every
        compute layer to avoid padding.  The first conv layer's 3-channel
        RGB input is zero-padded to the vector width by the memory-read
        kernel (as PipeCNN does), so it is exempt.  Depthwise/grouped
        convs stream channel-major vectors (each lane owns a channel, the
        per-group contraction is only ``kh*kw*c_in/g`` deep), so the
        constraint stays on the channel count.  Merge stages (add/concat)
        carry no weights and impose no N_i constraint."""
        cands = []
        widths = [l.c_in for l in self.layers[1:] if l.kind in (CONV, FC)]
        for ni in range(1, cap + 1):
            if _pow2(ni) and all(w % ni == 0 for w in widths):
                cands.append(ni)
        return cands

    def feasible_nl(self, cap: int = 64) -> List[int]:
        """N_l must divide the number of output features of every layer
        to avoid idle lanes.  The final classifier layer is exempt: its
        odd-sized output (e.g. 1000 classes) is zero-padded up to a lane
        multiple by the memory-write kernel, as PipeCNN does — without
        this the paper's own (16, 32) Arria-10 choice would be
        infeasible for AlexNet/VGG.  Add/concat merge stages run on the
        memory/adder path, not the compute lanes, so only conv/fc output
        widths constrain N_l."""
        cands = []
        feats = [l.c_out for l in self.layers[:-1] if l.kind in (CONV, FC)]
        for nl in range(1, cap + 1):
            if _pow2(nl) and all(f % nl == 0 for f in feats):
                cands.append(nl)
        return cands

    def hardware_options(self, cap: int = 64) -> List[Tuple[int, int]]:
        """All feasible (N_i, N_l) pairs — the DSE search space."""
        return [(ni, nl) for ni in self.feasible_ni(cap) for nl in self.feasible_nl(cap)]


def _pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def parse(graph: Graph, fuse_skip: bool = True,
          fuse_concat: bool = True) -> ParsedModel:
    """Traverse the graph (already topologically ordered) and emit the
    scheduled DAG stage program.

    Fusion (relu/softmax/max-pool/data-movement behind a stage) only
    happens across single-consumer tensors, so any tensor fused away has
    no other reader — every multi-consumer tensor (residual fan-out)
    survives as a named stage output.  Unfused data-movement nodes
    become aliases; stage inputs are canonicalised through them so the
    executor's tensor environment only ever holds stage outputs.
    Because canonicalisation runs on *every* stage's inputs, a merge
    whose operand arrives through elided Flatten/Identity/Dropout nodes
    sees the real producer tensor — fusion eligibility is judged on the
    resolved name, not the alias.

    With ``fuse_skip`` (default) a post-pass folds every eligible
    residual ``Add`` into the conv stage producing one of its operands
    (see :func:`_fold_skip_adds`) — the paper's keep-it-on-chip rule
    applied to skip connections.  ``fuse_skip=False`` keeps every merge
    a standalone stage (the bit-exact two-stage fallback program).
    ``fuse_concat`` (default) likewise annotates every eligible channel
    ``Concat`` for producer-epilogue fusion (see :func:`_fold_concats`);
    ``fuse_concat=False`` keeps every concat a standalone copy."""
    validate_ingress(graph)
    layers: List[LayerInfo] = []
    consumed: set = set()
    alias: Dict[str, str] = {}

    def canon(t: str) -> str:
        while t in alias:
            t = alias[t]
        return t

    for node in graph.nodes:
        if node.name in consumed:
            continue
        if node.op_type in ELIDED_OPS:
            # pure data-movement; the memory-read schedule absorbs it
            alias[node.outputs[0]] = node.inputs[0]
            continue
        if node.op_type == "Conv":
            li = _conv_layer(graph, node)
        elif node.op_type in ("Gemm", "MatMul"):
            li = _fc_layer(graph, node)
        elif node.op_type in ("MaxPool", "AveragePool", "GlobalAveragePool"):
            # standalone pool (not fused behind a conv)
            li = _pool_layer(graph, node)
        elif node.op_type == "Add":
            li = _merge_layer(graph, node, ADD)
        elif node.op_type == "Concat":
            li = _merge_layer(graph, node, CONCAT)
        elif node.op_type in ("Relu", "Softmax"):
            raise_if_unfused(graph, node, layers)
            continue
        else:
            continue
        # fuse activation + pool chains greedily (single-consumer only)
        _fuse_chain(graph, li, consumed)
        li.inputs = [canon(t) for t in li.inputs]
        layers.append(li)

    if not layers:
        raise GraphValidationError(
            f"graph {graph.name!r} contains no compute layers",
            node=graph.name)

    if fuse_skip:
        layers = _fold_skip_adds(layers, canon(graph.outputs[0]))
    if fuse_concat:
        layers = _fold_concats(layers, canon(graph.outputs[0]))

    # link the list in schedule order (the paper's order-preserving
    # structure; with branches this is the topological schedule)
    for a, b in zip(layers, layers[1:]):
        a.next, b.prev = b, a

    produced = {li.output for li in layers}
    inp = graph.inputs[0]
    for li in layers:
        for t in li.inputs:
            if t not in produced and t != inp.name:
                raise GraphValidationError(
                    "dangling stage input: no scheduled stage produces it",
                    node=li.name, tensor=t)

    return ParsedModel(
        name=graph.name,
        layers=layers,
        graph=graph,
        input_name=inp.name,
        input_shape=tuple(inp.shape),
        output_name=canon(graph.outputs[0]),
    )


def validate_ingress(graph: Graph) -> None:
    """Reject models the synthesis flow must not stage (DESIGN.md §9).

    Checked before any scheduling work: every float initializer must be
    finite (a NaN/Inf weight poisons max-abs calibration and every
    downstream quantized value), and every Conv/Gemm weight operand must
    actually be an initializer — a weight coming in as a dynamic tensor
    cannot be staged into on-chip memory."""
    for name, arr in graph.initializers.items():
        arr = np.asarray(arr)
        if arr.dtype.kind == "f" and not np.isfinite(arr).all():
            bad = int(np.size(arr) - np.isfinite(arr).sum())
            raise GraphValidationError(
                "non-finite initializer", tensor=name,
                detail=f"{bad} NaN/Inf of {arr.size} values")
    for node in graph.nodes:
        if node.op_type in ("Conv", "Gemm") and len(node.inputs) > 1:
            w = node.inputs[1]
            if w not in graph.initializers:
                raise GraphValidationError(
                    "weight operand is not an initializer",
                    node=node.name, tensor=w)


def raise_if_unfused(graph: Graph, node: Node, layers: List[LayerInfo]) -> None:
    """Activations should have been fused into the producing layer; a
    dangling one (e.g. Relu straight on the graph input) is unsupported
    by the pipelined kernel library."""
    for li in layers:
        if li.output == node.inputs[0] or (li.pool and li.pool.output == node.inputs[0]):
            return
        if node.outputs[0] in (li.output,):
            return
    # Softmax on the classifier output is recognised as fused elsewhere.
    raise GraphValidationError(
        f"standalone {node.op_type} node cannot be mapped to the "
        "pipelined kernel library", node=node.name)


def _conv_layer(graph: Graph, node: Node) -> LayerInfo:
    w_name = node.inputs[1]
    b_name = node.inputs[2] if len(node.inputs) > 2 else None
    w_shape = graph.shape(w_name)
    return LayerInfo(
        kind=CONV,
        name=node.name,
        inputs=[node.inputs[0]],
        output=node.outputs[0],
        weight=w_name,
        bias=b_name,
        in_shape=graph.shape(node.inputs[0]),
        out_shape=graph.shape(node.outputs[0]),
        kernel_shape=_norm2(node.attr("kernel_shape", (w_shape[2], w_shape[3]))),
        strides=_norm2(node.attr("strides", 1)),
        pads=_norm4(node.attr("pads")),
        dilations=_norm2(node.attr("dilations", 1)),
        group=int(node.attr("group", 1)),
    )


def _fc_layer(graph: Graph, node: Node) -> LayerInfo:
    w_name = node.inputs[1]
    b_name = node.inputs[2] if len(node.inputs) > 2 else None
    return LayerInfo(
        kind=FC,
        name=node.name,
        inputs=[node.inputs[0]],
        output=node.outputs[0],
        weight=w_name,
        bias=b_name,
        in_shape=graph.shape(node.inputs[0]),
        out_shape=graph.shape(node.outputs[0]),
    )


def _pool_layer(graph: Graph, node: Node) -> LayerInfo:
    if node.op_type == "GlobalAveragePool":
        in_shape = graph.shape(node.inputs[0])
        ks: Tuple[int, int] = (in_shape[2], in_shape[3])
        st: Tuple[int, int] = (1, 1)
    else:
        ks = _norm2(node.attr("kernel_shape"))
        st = _norm2(node.attr("strides", ks[0]))
    return LayerInfo(
        kind=POOL,
        name=node.name,
        inputs=[node.inputs[0]],
        output=node.outputs[0],
        in_shape=graph.shape(node.inputs[0]),
        out_shape=graph.shape(node.outputs[0]),
        kernel_shape=ks,
        strides=st,
        pads=_norm4(node.attr("pads")),
        pool_type="max" if node.op_type == "MaxPool" else "avg",
    )


def _merge_layer(graph: Graph, node: Node, kind: str) -> LayerInfo:
    """Residual (Add) or channel (Concat) merge as a first-class stage:
    all operands are named tensors; the executor aligns their fixed-point
    positions before merging (see pipeline/quantize)."""
    return LayerInfo(
        kind=kind,
        name=node.name,
        inputs=list(node.inputs),
        output=node.outputs[0],
        in_shape=graph.shape(node.inputs[0]),
        out_shape=graph.shape(node.outputs[0]),
        axis=int(node.attr("axis", 1)) if kind == CONCAT else 1,
    )


def _fuse_chain(graph: Graph, li: LayerInfo, consumed: set) -> None:
    """Fuse Relu / MaxPool / Softmax that immediately follow ``li``.

    Mirrors the paper's hardware view: the conv kernel has a fused ReLU
    stage, the pool kernel sits behind it on the pipe, and fully-connected
    layers run on the conv kernel with pooling configured pass-through.
    """
    cur_out = li.output
    while True:
        consumers = [
            n for n in graph.consumers_of(cur_out) if n.name not in consumed
        ]
        # only fuse when the tensor has exactly one consumer (pipe semantics)
        if len(consumers) != 1:
            break
        n = consumers[0]
        if n.op_type == "Relu":
            li.relu = True
            consumed.add(n.name)
            cur_out = n.outputs[0]
            li.output = cur_out
        elif n.op_type == "Softmax":
            li.softmax = True
            consumed.add(n.name)
            cur_out = n.outputs[0]
            li.output = cur_out
        elif (n.op_type == "MaxPool" and li.kind == CONV
              and li.pool is None and not any(_norm4(n.attr("pads")))):
            # only max-pool fuses into the conv kernel (its pooling
            # stage computes max); average pools and *padded* max-pools
            # run standalone — the fused band kernel has no pool-pad
            # path, and maxpool2d_nhwc handles pads exactly
            pool = _pool_layer(graph, n)
            li.pool = pool
            consumed.add(n.name)
            cur_out = n.outputs[0]
            li.output = cur_out
            li.out_shape = pool.out_shape
        elif n.op_type in ("Flatten", "Reshape", "Dropout", "Identity"):
            consumed.add(n.name)
            cur_out = n.outputs[0]
            li.output = cur_out
        else:
            break


def _fold_skip_adds(layers: List[LayerInfo],
                    graph_output: Optional[str] = None) -> List[LayerInfo]:
    """Residual-add epilogue fusion pass (the ROADMAP's add-into-conv
    item): fold each two-operand ``Add`` into the conv stage producing
    one of its operands, so the merge runs inside the conv kernel's
    epilogue instead of as a standalone stage (one full int8 feature-map
    HBM write + read saved per skip connection).

    Eligibility — everything else falls back to the standalone merge
    stage, whose numerics the fused epilogue replicates bit-for-bit:

      * the host operand's producer is a dense conv (``group == 1``) or
        a depthwise-kernel conv (group == Cin, any integer channel
        multiplier — both band kernels carry the skip epilogue; ragged
        grouped producers run on the group-axis kernel, which does not);
      * that conv's output has the Add as its **only** consumer (pipe
        semantics — a fan-out tensor must stay addressable);
      * the conv has no fused pool yet and matches the Add's geometry;
      * the skip operand is already available when the host runs (its
        producer is scheduled earlier, or it is the graph input).

    When both producers qualify the later-scheduled one hosts (its
    operand is then the freshest tensor — the ResNet projection case).
    After folding, a single-consumer unpadded MaxPool stage straddling
    the old Add output is absorbed as the merged stage's fused pool
    (graph order Conv→Add→ReLU→MaxPool == epilogue order)."""
    result = list(layers)
    progress = True
    while progress:
        progress = False
        pos = {id(li): i for i, li in enumerate(result)}
        producer = {li.output: li for li in result}
        n_consumers: Dict[str, int] = {}
        for li in result:
            for t in li.inputs:
                n_consumers[t] = n_consumers.get(t, 0) + 1
        for add in result:
            if add.kind != ADD or len(add.inputs) != 2:
                continue
            if add.inputs[0] == add.inputs[1]:
                continue  # x + x consumes one tensor twice: keep merged
            if add.softmax:
                continue  # the epilogue has no softmax: keep standalone
            cands = []
            for k, t in enumerate(add.inputs):
                p = producer.get(t)
                if (p is not None and p.kind == CONV
                        and (p.group == 1 or p.is_dw_kernel)
                        and p.pool is None and p.merge is None
                        and not p.softmax
                        and n_consumers.get(t, 0) == 1
                        and t != graph_output  # the egress still reads it
                        and p.out_shape == add.out_shape):
                    cands.append((pos[id(p)], p, add.inputs[1 - k]))
            host = skip_t = None
            for _i, p, other in sorted(cands, key=lambda c: -c[0]):
                op = producer.get(other)
                if op is None or pos[id(op)] < pos[id(p)]:
                    host, skip_t = p, other
                    break
            if host is None:
                continue
            host.merge = add
            host.skip_input = skip_t
            host.inputs = [host.inputs[0], skip_t]
            host.output = add.output
            host.out_shape = add.out_shape
            result.remove(add)
            # absorb a following single-consumer unpadded MaxPool: the
            # epilogue pools after the merge, matching the graph order
            pools = [l for l in result if host.output in l.inputs]
            if (len(pools) == 1 and pools[0].kind == POOL
                    and pools[0].pool_type == "max"
                    and not any(pools[0].pads)
                    and not pools[0].softmax and not pools[0].relu
                    and host.output != graph_output):
                pstage = pools[0]
                host.pool = pstage
                host.output = pstage.output
                host.out_shape = pstage.out_shape
                result.remove(pstage)
            progress = True
            break  # adjacency changed: recompute the maps
    return result


def _fold_concats(layers: List[LayerInfo],
                  graph_output: Optional[str] = None) -> List[LayerInfo]:
    """Concat-epilogue fusion pass (the ROADMAP's inception item): mark
    each channel ``Concat`` whose operands are ALL produced by eligible
    band-kernel convs so that every producer writes its Cout tiles
    directly into a channel-offset slice of the shared merge buffer —
    the concat becomes an output BlockSpec, not a copy (one full merged
    feature-map HBM write + read saved per inception block).

    Unlike ``_fold_skip_adds`` the Concat stage is NOT removed: it stays
    scheduled (annotated ``concat_fused``) as the point where the shared
    buffer becomes the merge tensor, keeping its name, operand tensors
    and relu flag — so ``thread_scales``/``calibrate_quantization``
    treat fused and unfused programs identically and emit byte-identical
    specs.  Producers get ``concat``/``concat_offset`` annotations; the
    offsets accumulate in operand order and exactly partition the merge
    Cout.

    Eligibility — ALL operands must qualify, else the whole concat stays
    a standalone merge (whose numerics the fused epilogue replicates
    bit-for-bit):

      * the merge is a channel concat (axis 1 in NCHW), not the graph
        output's softmax host, with no repeated operand tensors;
      * every operand's producer is a dense conv (``group == 1``) or a
        depthwise-kernel conv (group == Cin, integer channel
        multiplier) with no fused pool, no folded residual merge, no
        prior concat annotation and no softmax;
      * every operand has the concat as its **only** consumer and is not
        the graph output (a fan-out operand must stay addressable);
      * every operand matches the merge's batch and spatial geometry
        (the channel sums are checked to partition the merge Cout).

    After folding, a single-consumer unpadded MaxPool stage straddling
    the concat output is absorbed as the merge's fused pool — each
    producer then runs the pool in its epilogue on its own channel
    slice (disjoint channels, so pooling per-slice == pooling the
    merged tensor) and the shared buffer takes the pooled geometry."""
    result = list(layers)
    producer = {li.output: li for li in result}
    n_consumers: Dict[str, int] = {}
    for li in result:
        for t in li.inputs:
            n_consumers[t] = n_consumers.get(t, 0) + 1
    for cc in [l for l in result if l.kind == CONCAT]:
        if cc.axis != 1 or cc.softmax:
            continue
        if len(set(cc.inputs)) != len(cc.inputs):
            continue  # a repeated operand would need two buffer slices
        prods: List[Tuple[LayerInfo, int]] = []
        off = 0
        ok = True
        for t in cc.inputs:
            p = producer.get(t)
            if (p is None or p.kind != CONV
                    or not (p.group == 1 or p.is_dw_kernel)
                    or p.pool is not None or p.merge is not None
                    or p.concat is not None or p.softmax
                    or n_consumers.get(t, 0) != 1
                    or t == graph_output
                    or p.out_shape[0] != cc.out_shape[0]
                    or p.out_shape[2:] != cc.out_shape[2:]):
                ok = False
                break
            prods.append((p, off))
            off += p.c_out
        if not ok or off != cc.c_out:
            continue
        for p, o in prods:
            p.concat = cc
            p.concat_offset = o
        cc.concat_fused = True
        # absorb a following single-consumer unpadded MaxPool into the
        # merge: producers pool in their epilogues, the shared buffer
        # is allocated in pooled geometry, and the standalone pool
        # stage disappears (graph order Concat→ReLU→MaxPool == epilogue
        # order concat-align→relu→pool)
        pools = [l for l in result if cc.output in l.inputs]
        if (len(pools) == 1 and pools[0].kind == POOL
                and pools[0].pool_type == "max"
                and not any(pools[0].pads)
                and not pools[0].softmax and not pools[0].relu
                and cc.output != graph_output):
            pstage = pools[0]
            cc.pool = pstage
            cc.output = pstage.output
            cc.out_shape = pstage.out_shape
            result.remove(pstage)
    return result


def memory_schedule(model: ParsedModel, n_i: int, n_l: int) -> List[Dict[str, Any]]:
    """The host-program memory access schedule of §4.2: for each pipeline
    stage, how many (N_i)-wide vectors the memory-read kernel fetches and
    how many lanes are active.  Consumed by the pipelined executor and the
    FPGA latency model."""
    sched = []
    for li in model.layers:
        if li.kind == FC:
            vec_per_row = -(-li.c_in // n_i)  # ceil
            rows = int(np.prod(li.in_shape[:-1]))
            sched.append(
                dict(
                    layer=li.name,
                    kind=li.kind,
                    read_vectors=rows * vec_per_row,
                    weight_vectors=li.c_out * vec_per_row,
                    lanes=min(n_l, li.c_out),
                    write_elems=int(np.prod(li.out_shape)),
                )
            )
        elif li.kind in (ADD, CONCAT):
            # merge stages stream every operand once and write the
            # merged tensor — pure memory traffic, no weight vectors.
            # The operand slices of a concat together hold exactly one
            # merged tensor's worth of elements, so the merge buffer is
            # charged ONCE per merge tensor, not once per branch.  A
            # producer-fused concat is a buffer hand-off: the producers
            # already wrote their slices in place, so the stage itself
            # moves nothing.
            if li.concat_fused:
                sched.append(
                    dict(layer=li.name, kind=li.kind, read_vectors=0,
                         weight_vectors=0, lanes=min(n_l, li.c_out),
                         write_elems=0))
                continue
            if li.kind == ADD:
                read_elems = len(li.inputs) * int(np.prod(li.in_shape))
            else:
                read_elems = int(np.prod(li.out_shape))
            sched.append(
                dict(
                    layer=li.name,
                    kind=li.kind,
                    read_vectors=-(-read_elems // n_i),
                    weight_vectors=0,
                    lanes=min(n_l, li.c_out),
                    write_elems=int(np.prod(li.out_shape)),
                )
            )
        else:
            n, c_out, h, w = li.out_shape if li.pool is None else li.pool.in_shape
            kh, kw = li.kernel_shape
            vec_per_patch = -(-(li.c_in * kh * kw) // n_i)
            read_vectors = n * h * w * vec_per_patch
            if li.merge is not None:
                # fused residual merge: the skip operand streams through
                # the same memory-read kernel once (conv-out geometry)
                read_vectors += -(-int(np.prod(li.conv_out_shape)) // n_i)
            write_elems = int(np.prod(li.out_shape))
            if li.concat is not None and li.concat.pool is not None:
                # concat producer running the merge's absorbed pool in
                # its epilogue: it writes its slice in pooled geometry
                cc = li.concat
                write_elems = int(cc.out_shape[0] * li.c_out
                                  * np.prod(cc.out_shape[2:]))
            sched.append(
                dict(
                    layer=li.name,
                    kind=li.kind,
                    read_vectors=read_vectors,
                    weight_vectors=c_out * vec_per_patch,
                    lanes=min(n_l, c_out),
                    write_elems=write_elems,
                )
            )
    return sched
