"""Concrete design spaces for the DSE fitters.

``CNNDesignSpace`` is the paper's own (N_i, N_l) space.
``ShardingSpace`` is the same fitter lifted to the TPU pod: options are
parallelism knobs (remat x microbatch x sequence-parallel x ZeRO-2),
the "vendor compiler" is XLA itself (`lower().compile()` on the
production mesh), and the four Algorithm-1 quotas map to HBM residency,
compute-fraction-of-step, temp pressure and collective pressure
(DESIGN.md §2 table).  Like the paper's first-stage estimation, the
fitter evaluates a depth-reduced model and scales — each evaluation is
a real compile, just a cheap one.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from .dse import DesignSpace
from .parser import ParsedModel
from .resources import (FPGAProfile, ResourceReport, TPU_V5E, NI_CAP,
                        NL_CAP, checkpoint_bytes, conv_band_working_set,
                        estimate_fpga, plan_checkpoints)

#: Default row-band heights offered to the DSE when the caller enables
#: the third axis but does not name candidates.
DEFAULT_BLOCK_H_OPTIONS: List[int] = [4, 8, 16, 32]


class CNNDesignSpace(DesignSpace):
    """The paper's (N_i, N_l) space for a parsed CNN on a given board,
    optionally extended with the conv kernel's ``block_h`` row-band
    height as a third axis (DESIGN.md §4).

    Options obey the §4.2 divisibility constraints (from the parsed
    model) and the framework caps (N_i <= 16 from the 128-bit DDR burst,
    N_l <= 32 from the pipe width — the paper's 'limited options'
    discussion in §5).  ``evaluate`` calls the calibrated analytical
    stand-in for the vendor compiler; in the 3-axis space it adds the
    row-band working set (``conv_band_working_set``) against the
    board's on-chip memory, so options whose band does not fit are
    rejected exactly like any over-quota option in Algorithm 1.  The
    working-set rule covers the whole DAG stage program — dense convs
    (Cin-sliced by the ``8*N_i`` contraction tile, plus the skip band
    when a residual add is fused into the epilogue), depthwise convs at
    any channel multiplier, ragged grouped convs (banded per group, so
    the group count never inflates the per-step set), residual merge
    buffers, and concats (charged once per merge tensor when standalone,
    zero when epilogue-fused: the producers' own bands already hold the
    in-place slices — resources.py) — so branchy models prune the same
    way linear ones do, and both parallelism degrees shape the scored
    band exactly as they shape the executor's kernel tiles.

    ``checkpoint_options`` adds a fourth axis ``ckpt_k``: the number of
    stage-boundary recovery snapshots the deployment retains (DESIGN.md
    §11).  Each candidate K is expanded by ``plan_checkpoints`` (the
    equal-cumulative-MAC placement rule) and the retained snapshots'
    int8 bytes are charged against the same on-chip memory quota as the
    row band — they coexist with it, so the charges *add*, and a K whose
    snapshots push the memory over quota is rejected exactly like an
    oversized band.  K=0 (no checkpoints, no charge) should normally be
    in the candidate list so resilience is paid for only when it fits.

    ``specs`` (optional) arms the static verifier as a DRC gate: the
    (program, specs) pair is checked once at construction, and a space
    whose program fails verification scores every option as infeasible
    (all quotas at ``FAILED_PCT``, ``raw["verifier"]`` naming the
    tripped rules) — the Algorithm-1 move of rejecting a design before
    paying the vendor compiler for it.
    """

    def __init__(self, model: ParsedModel, board: FPGAProfile,
                 ni_cap: int = NI_CAP, nl_cap: int = NL_CAP,
                 block_h_options: Optional[List[int]] = None,
                 per_channel: bool = False,
                 checkpoint_options: Optional[List[int]] = None,
                 specs: Optional[Dict] = None):
        self.model = model
        self.board = board
        #: error rule ids from the one-time static verification of the
        #: (program, specs) pair; empty when clean or unarmed
        self.verifier_errors: Tuple[str, ...] = ()
        if specs is not None:
            from . import verify as verify_mod
            rep = verify_mod.verify_program(model, specs,
                                            check_identity=False)
            self.verifier_errors = tuple(sorted(
                {d.rule_id for d in rep.errors}))
        self._ni = [n for n in model.feasible_ni(ni_cap) if n <= ni_cap]
        self._nl = [n for n in model.feasible_nl(nl_cap) if n <= nl_cap]
        self._bh = sorted(block_h_options) if block_h_options else None
        self._ck = (sorted(set(checkpoint_options))
                    if checkpoint_options else None)
        #: K -> (plan, retained int8 bytes); the plan is a pure function
        #: of the parsed model, so one expansion serves every option
        self._ck_cache: Dict[int, Tuple[Tuple[int, ...], int]] = {}
        #: per-channel quantized program: the working-set rule charges
        #: the per-lane shift row (int32/lane) alongside the bias, and
        #: the weight store grows by one int32 exponent per Cout lane
        self.per_channel = per_channel
        self.weight_bytes = model.total_weights  # int8: 1 byte/weight
        if per_channel:
            self.weight_bytes += 4 * sum(
                li.c_out for li in model.layers
                if li.kind in ("conv", "fc"))

    def options(self) -> List[Tuple]:
        import itertools
        return list(itertools.product(*self.axes()))

    def axes(self) -> List[List[int]]:
        axes = [list(self._ni), list(self._nl)]
        if self._bh is not None:
            axes.append(list(self._bh))
        if self._ck is not None:
            axes.append(list(self._ck))
        return axes

    def axis_names(self) -> List[str]:
        names = ["n_i", "n_l"]
        if self._bh is not None:
            names.append("block_h")
        if self._ck is not None:
            names.append("ckpt_k")
        return names

    def checkpoint_plan(self, k: int) -> Tuple[Tuple[int, ...], int]:
        """(boundary plan, retained int8 bytes) for K snapshots."""
        if k not in self._ck_cache:
            plan = plan_checkpoints(self.model, k)
            self._ck_cache[k] = (plan, checkpoint_bytes(self.model, plan))
        return self._ck_cache[k]

    def evaluate(self, option: Tuple) -> ResourceReport:
        if self.verifier_errors:
            # a program that fails DRC can never fit, at any option:
            # charge it like any over-quota design (Algorithm 1)
            from .dse import FAILED_PCT
            return ResourceReport(
                percents={k: FAILED_PCT
                          for k in ("lut", "dsp", "mem", "reg")},
                raw={"verifier": list(self.verifier_errors)}, fits=False)
        ni, nl = option[0], option[1]
        rep = estimate_fpga(self.board, ni, nl, self.weight_bytes)
        if self._bh is None and self._ck is None:
            return rep
        i = 2
        band_bytes = 0
        if self._bh is not None:
            # the Cin tile (8*N_i) and the Cout tile (8*N_l) both bound
            # the band the same way the executor's kernel tiles do
            band_bytes = conv_band_working_set(
                self.model.layers, nl, option[i], n_i=ni,
                per_channel=self.per_channel)
            i += 1
        ckpt_b = 0
        plan: Tuple[int, ...] = ()
        if self._ck is not None:
            plan, ckpt_b = self.checkpoint_plan(option[i])
        # band and retained snapshots coexist on chip: charges add
        onchip_pct = 100.0 * (8 * (band_bytes + ckpt_b)) / self.board.mem_bits
        percents = dict(rep.percents)
        percents["mem"] = max(percents["mem"], onchip_pct)
        raw = dict(rep.raw, band_ws_bytes=band_bytes,
                   band_ws_pct=100.0 * 8 * band_bytes / self.board.mem_bits,
                   ckpt_bytes=ckpt_b, ckpt_plan=plan,
                   onchip_pct=onchip_pct)
        fits = all(v <= 100.0 for v in percents.values())
        return ResourceReport(percents=percents, raw=raw, fits=fits)

    def tiebreak(self, option: Tuple) -> float:
        # prefer balanced (N_i, N_l) — see DesignSpace.tiebreak
        # docstring; among those, deeper row bands (larger block_h =
        # fewer halo re-reads) break remaining ties, then more
        # checkpoints (cheaper expected recovery) break the rest
        t = float(min(option[0], option[1]))
        i = 2
        if self._bh is not None:
            t += option[i] * 1e-3
            i += 1
        if self._ck is not None:
            t += option[i] * 1e-5
        return t


DEFAULT_POD_AXES: List[Tuple[str, List]] = [
    ("remat", ["none", "dots", "full"]),
    ("n_micro", [1, 4, 8, 16]),
    ("sequence_parallel", [False, True]),
]


class ShardingSpace(DesignSpace):
    """Pod-scale parallelism options scored by the real XLA compiler.

    ``evaluate`` compiles a depth-reduced variant of the cell on the
    production mesh (estimation stage, like the paper's first synthesis
    stage) and scales residency/terms back to full depth.  The reward
    quotas (Algorithm 1 unchanged):

        lut  -> projected HBM residency %      (hard fit criterion)
        dsp  -> compute fraction of the step % (utilization == throughput)
        mem  -> projected temp pressure %
        reg  -> collective/compute pressure %
    """

    def __init__(self, arch: str, shape_name: str,
                 axes: Optional[List[Tuple[str, List]]] = None,
                 eval_depth: int = 4, flash_accounting: bool = True,
                 profile=TPU_V5E):
        self.arch = arch
        self.shape_name = shape_name
        self._axes = axes or DEFAULT_POD_AXES
        self.eval_depth = eval_depth
        self.flash = flash_accounting
        self.profile = profile
        from repro import configs
        self._cfg = configs.get(arch)
        self._scale = max(1, self._cfg.n_layers // max(eval_depth, 1))

    def axes(self) -> List[List]:
        return [vals for _n, vals in self._axes]

    def axis_names(self) -> List[str]:
        return [name for name, _vals in self._axes]

    def options(self) -> List[Tuple]:
        import itertools
        return list(itertools.product(*self.axes()))

    def _policy_kwargs(self, option: Tuple) -> Dict[str, Any]:
        return {name: val for (name, _), val in zip(self._axes, option)}

    def evaluate(self, option: Tuple) -> ResourceReport:
        from repro.launch.dryrun import lower_cell, _depth_cfg
        from repro.sharding import PolicyOptions
        opts = PolicyOptions(**self._policy_kwargs(option))
        cfg1, _ = _depth_cfg(self._cfg, 1)  # family-consistent reduction
        depth_over = {"n_layers": cfg1.n_layers * self.eval_depth}
        if self._cfg.family == "encdec":
            depth_over["encoder_layers"] = depth_over["n_layers"]
        _c, meta = lower_cell(
            self.arch, self.shape_name, options=opts,
            cfg_override=depth_over, extrapolate=False,
            flash_accounting=self.flash)
        # project depth-linear quantities back to full depth
        hbm = self.profile.hbm_bytes
        peak = meta["arg_bytes"] + meta["out_bytes"] \
            + meta["temp_bytes"] * self._scale
        t_c = meta["t_compute"] * self._scale
        t_m = meta["t_memory_fused"] * self._scale
        t_col = meta["t_collective"] * self._scale
        t_step = max(t_c, t_m, t_col)
        percents = {
            "lut": 100.0 * peak / hbm,
            "dsp": 100.0 * t_c / max(t_step, 1e-12),
            "mem": 100.0 * meta["temp_bytes"] * self._scale / hbm,
            "reg": 100.0 * min(t_col / max(t_c, 1e-12), 2.0) / 2.0,
        }
        raw = {"peak": peak, "t_compute": t_c, "t_memory": t_m,
               "t_collective": t_col, "t_step": t_step,
               "option": self._policy_kwargs(option)}
        fits = percents["lut"] <= 100.0
        return ResourceReport(percents=percents, raw=raw, fits=fits)

    def tiebreak(self, option: Tuple) -> float:
        return 0.0
