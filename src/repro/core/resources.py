"""Hardware resource models — the "compiler resource estimation" oracle.

The paper's DSE queries the Intel OpenCL compiler's first synthesis stage
for estimated %LUT/%DSP/%RAM/%register utilization.  Neither that
compiler nor FPGA hardware exist in this container, so this module
provides an **analytical estimator calibrated against the paper's own
published synthesis results** (Tables 1–3):

  anchors: 5CSEMA5 @ (8,8) -> ALM 26K, DSP 72, RAM 397/397, 2 Mbit
           Arria 10 @ (16,32) -> ALM 129K (30 %), DSP 300 (20 %), RAM 40 %
           5CSEMA4 @ (1,1) -> must NOT fit (control logic alone too big)
           VGG-16 uses ~8 % more Arria-10 RAM blocks than AlexNet

  fitted model (documented, not hard-coded decisions):
           ALM        = 11300 + 230 * (N_i*N_l)
           DSP        = 40    + ceil(N_i*N_l / 2)      # dual int8 MAC/DSP
           RAM blocks = 148 + 1.2 * (N_i*N_l) + 2.82 * weight_Mbytes
           regs       = 2.5 * ALM   (of 4 * ALM_avail)

For TPU targets the estimator is **not** analytical: it reads the real
XLA compiled artifact (memory_analysis / cost_analysis) — see
``TPUResourceModel`` and ``repro.roofline``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

# ------------------------------------------------------------------ FPGA

@dataclasses.dataclass(frozen=True)
class FPGAProfile:
    """Published capacities of the paper's three boards (Table 2)."""

    name: str
    alm: int
    dsp: int
    ram_blocks: int
    mem_bits: int
    f_max_mhz: float          # Table 1 achieved kernel clock
    ddr_gbps: float           # calibrated effective DDR bandwidth
    ram_bits_per_block: int = 10_000

    @property
    def reg(self) -> int:
        return 4 * self.alm


CYCLONE_V_5CSEMA4 = FPGAProfile(
    "Cyclone V SoC 5CSEMA4", alm=15_000, dsp=83, ram_blocks=321,
    mem_bits=2_000_000, f_max_mhz=131.0, ddr_gbps=0.78)
CYCLONE_V_5CSEMA5 = FPGAProfile(
    "Cyclone V SoC 5CSEMA5", alm=32_000, dsp=87, ram_blocks=397,
    mem_bits=4_000_000, f_max_mhz=131.0, ddr_gbps=0.78)
ARRIA_10_GX1150 = FPGAProfile(
    "Arria 10 GX 1150", alm=427_000, dsp=1516, ram_blocks=2713,
    mem_bits=55_500_000, f_max_mhz=199.0, ddr_gbps=4.95,
    ram_bits_per_block=20_000)

FPGA_BOARDS: Dict[str, FPGAProfile] = {
    "5CSEMA4": CYCLONE_V_5CSEMA4,
    "5CSEMA5": CYCLONE_V_5CSEMA5,
    "ARRIA10": ARRIA_10_GX1150,
}

# Framework option caps (§5 of the paper: "limited options to increase the
# level of parallelism" — the memory-read kernel's vector width is bounded
# by the 128-bit DDR burst (N_i <= 16) and the pipe width bounds N_l <= 32).
NI_CAP = 16
NL_CAP = 32


@dataclasses.dataclass
class ResourceReport:
    """What the 'compiler' hands back to the DSE agent (§4.4)."""

    percents: Dict[str, float]          # {lut, dsp, mem, reg} in [0, 100+]
    raw: Dict[str, float]
    fits: bool

    @property
    def f_avg(self) -> float:
        """Eq. (5): average usage factor."""
        p = self.percents
        return (p["lut"] + p["dsp"] + p["mem"] + p["reg"]) / 4.0


def estimate_fpga(profile: FPGAProfile, n_i: int, n_l: int,
                  weight_bytes: int) -> ResourceReport:
    """Calibrated analytical stand-in for the vendor compiler estimate."""
    alm = 11_300 + 230.0 * (n_i * n_l)
    dsp = 40 + math.ceil(n_i * n_l / 2)
    ram = 148 + 1.2 * (n_i * n_l) + 2.815 * (weight_bytes / 1e6)
    regs = 2.5 * alm
    mem_bits = ram * profile.ram_bits_per_block * 0.5
    percents = {
        "lut": 100.0 * alm / profile.alm,
        "dsp": 100.0 * dsp / profile.dsp,
        "mem": 100.0 * ram / profile.ram_blocks,
        "reg": 100.0 * regs / profile.reg,
    }
    raw = {"alm": alm, "dsp": dsp, "ram_blocks": ram, "regs": regs,
           "mem_bits": mem_bits}
    fits = all(v <= 100.0 for v in percents.values())
    return ResourceReport(percents=percents, raw=raw, fits=fits)


# --------------------------------------------- row-band working-set model

#: Per-core VMEM the conv kernel's row-band working set must fit in on a
#: real TPU (the Mosaic double-buffering budget; the ~16 MiB/core figure
#: of the Pallas guide).  The FPGA boards use their published on-chip
#: ``mem_bits`` instead.
VMEM_BUDGET_BYTES = 16 * 1024 ** 2


def conv_band_working_set(layers, n_l: int,
                          block_h: Optional[int],
                          n_i: Optional[int] = None,
                          per_channel: bool = False) -> int:
    """Peak per-grid-step VMEM bytes of the row-tiled kernels across the
    model's stage program (the quantity the DSE must keep under the
    on-chip budget — the paper's line-buffer/block-RAM sizing, §3.2.2).

    ``layers`` is the parsed ``LayerInfo`` schedule; ``n_l`` maps to the
    output-channel tile exactly as the executor maps it
    (``block_cout = 8 * N_l``) and ``n_i`` to the dense kernel's Cin
    contraction tile (``block_cin = 8 * N_i``; ``None`` scores the
    whole-Cin contraction); ``block_h=None`` scores the untiled
    whole-plane kernel.  Beyond dense convs the feasibility rule covers:

      * dense convs with a fused residual merge — the conv band plus
        the ``skip_vmem_bytes`` band the epilogue holds alongside it;
      * depthwise convs (any integer channel multiplier) — the
        channel-tiled band of ``dw_vmem_bytes`` (the input band shrinks
        with the channel tile, like the dense kernel's ``block_cin``
        slice, and with the multiplier), plus a fused residual band;
      * ragged grouped convs — the per-group band of
        ``gconv_vmem_bytes`` (the group axis is a grid axis, so the
        per-step set never scales with the group count);
      * residual merges — every operand band plus the int32 alignment
        intermediate and the output band (the skip buffer the paper
        would hold in block RAM while the main branch computes);
      * standalone concat merges — ONE output band plus the int32
        alignment intermediate and the int8 output: the operand slices
        partition the merge band, so charging every operand on top of
        the output would double-count the same bytes per branch;
      * fused concat merges (``concat_fused``) — zero: each producer
        conv writes its channel slice of the merge buffer from its own
        epilogue, so the charge already sits in the producers' bands.

    ``per_channel`` charges the per-lane requant-shift row (one int32
    per Cout lane of the tile, next to the bias row) every per-channel
    quantized grid step holds — the shift-vector bytes of DESIGN.md §8,
    so the DSE stays honest about the per-channel epilogue's working
    set.
    """
    from repro.kernels import qconv  # kernels never import core: no cycle

    block_cout = max(8 * n_l, 8)
    block_cin = max(8 * n_i, 8) if n_i else None
    peak = 0
    for li in layers:
        if li.kind in ("add", "concat"):
            if li.concat_fused:
                continue  # producers write the merge buffer in place
            # concat operand slices partition the output band: charge
            # the merge once, not once per producer branch
            n_ops = 1 if li.kind == "concat" else len(li.inputs)
            if len(li.out_shape) == 4:  # spatial merge: row-banded
                _n, c, h, w = li.out_shape
                bh = min(block_h or h, h)
                band_elems = bh * w * c
            else:  # vector merge (MLP-style skip): whole tensor
                band_elems = int(math.prod(li.out_shape[1:]))
            # operand bands int8 + int32 add intermediate + out band
            peak = max(peak, band_elems * (n_ops + 4 + 1))
            continue
        if li.kind != "conv":
            continue
        _n, cin, h, w = li.in_shape
        pads = li.pads
        hp, wp = h + pads[0] + pads[2], w + pads[1] + pads[3]
        kh, kw = li.kernel_shape
        sh, sw = li.strides
        _n2, cout, oh, ow = li.out_shape
        pool = None
        if li.pool is not None:
            pool = (li.pool.kernel_shape[0], li.pool.strides[0])
        if li.is_dw_kernel:
            bc = min(block_cout, -(-cout // 128) * 128)
            ws = qconv.dw_vmem_bytes(wp, cout, kh, kw, bc, oh, ow,
                                     sh=sh, sw=sw, block_h=block_h,
                                     pool=pool, per_channel=per_channel,
                                     multiplier=cout // cin,
                                     skip=li.merge is not None)
        elif li.group > 1:  # ragged grouped conv: per-group band
            ws = qconv.gconv_vmem_bytes(
                wp, cin // li.group, cout // li.group, kh, kw, oh, ow,
                sh=sh, sw=sw, block_h=block_h, pool=pool,
                per_channel=per_channel)
        else:
            bco = min(block_cout, -(-cout // 128) * 128)
            ws = qconv.vmem_bytes(
                hp, wp, cin, kh, kw, bco, oh, ow,
                sh=sh, sw=sw, block_h=block_h, pool=pool,
                block_cin=block_cin, skip=li.merge is not None,
                per_channel=per_channel)
        peak = max(peak, ws)
    return peak


# ------------------------------------------ checkpoint placement model
#
# Stage-boundary recovery (DESIGN.md §11): the executor can snapshot the
# live int8 tensor environment at chosen stage boundaries so the guard
# replays only the stages downstream of a localized fault.  The snapshot
# is exactly the executor's liveness set — the functions below mirror
# the executor's ``last_use`` release rule byte for byte, so the DSE can
# charge checkpoint storage against the on-chip memory quota without
# building a program.


def _env_liveness(parsed):
    """(produced_at, last_use, int8_bytes) for every tensor that exists
    in the executor's environment, mirroring ``make_executor``:
    the graph input is produced "before stage 0" (index -1), the output
    is read by the egress (index ``len(layers)``), and fused-concat
    *producers* never put their output in the environment (they write a
    channel slice of the merge's shared buffer — only the Concat stage
    publishes the merged tensor)."""
    layers = parsed.layers
    last_use: Dict[str, int] = {}
    for idx, li in enumerate(layers):
        for t in li.inputs:
            last_use[t] = idx
    last_use[parsed.output_name] = len(layers)
    produced = {parsed.input_name: -1}
    nbytes = {parsed.input_name: int(math.prod(parsed.input_shape))}
    for idx, li in enumerate(layers):
        if li.concat is not None:
            continue  # writes the shared merge buffer, not the env
        produced[li.output] = idx
        nbytes[li.output] = int(math.prod(li.out_shape))
    return produced, last_use, nbytes


def checkpoint_live_bytes(parsed, boundary: int) -> Dict[str, int]:
    """``tensor -> int8 bytes`` of the snapshot taken after stage
    ``boundary`` completes: every tensor produced at or before the
    boundary whose last consumer lies strictly after it.  By the
    executor's own liveness rule this set is both sufficient and minimal
    for replaying stages ``boundary+1 ..``."""
    produced, last_use, nbytes = _env_liveness(parsed)
    return {t: nbytes[t] for t, p in produced.items()
            if p <= boundary < last_use.get(t, -1)}


def concat_group_spans(parsed) -> Tuple[Tuple[int, int, str], ...]:
    """``(start, end, merge_name)`` spans of stage indices where a
    fused-concat merge buffer is under construction: from each group's
    first producer up to (excluding) its Concat stage.  Boundaries in a
    span are invalid snapshot points — the half-built shared buffer is
    live but is not a named graph tensor.  Shared by
    :func:`eligible_checkpoints` and ``verify.check_checkpoint_boundaries``
    so the planner and the verifier can never disagree."""
    layers = parsed.layers
    name_idx = {li.name: i for i, li in enumerate(layers)}
    first: Dict[str, int] = {}
    for i, li in enumerate(layers):
        if li.concat is not None and li.concat.name in name_idx:
            first.setdefault(li.concat.name, i)
    return tuple(sorted((start, name_idx[name], name)
                        for name, start in first.items()))


def eligible_checkpoints(parsed) -> Tuple[int, ...]:
    """Stage indices that are valid snapshot boundaries: everything
    except the final stage (snapshotting after the output is produced
    recovers nothing) and boundaries inside a fused-concat group, where
    the half-built shared merge buffer is live but is not a named graph
    tensor (the executor rejects those too)."""
    blocked = set()
    for start, end, _name in concat_group_spans(parsed):
        blocked.update(range(start, end))
    return tuple(i for i in range(len(parsed.layers) - 1)
                 if i not in blocked)


def checkpoint_bytes(parsed, boundaries) -> int:
    """Total int8 bytes of all retained snapshots.  Snapshots are held
    for the whole inference (any of them may be the replay source), so
    the DSE charges their *sum*, not their max."""
    return sum(sum(checkpoint_live_bytes(parsed, b).values())
               for b in boundaries)


def plan_checkpoints(parsed, k: int) -> Tuple[int, ...]:
    """Place up to ``k`` checkpoints at equal cumulative-MAC split
    points over the eligible boundaries (DESIGN.md §11).

    The expected replay cost of a fault uniformly distributed over the
    schedule's MACs is minimized when the boundaries split the
    cumulative-MAC curve evenly — the j-th checkpoint targets
    ``total_macs * j / (k+1)``.  Ties (several boundaries equally close
    to a split point, common in merge-heavy graphs where merge stages
    cost 0 MACs) break toward the smaller snapshot, then the earlier
    boundary, so the plan is deterministic."""
    elig = list(eligible_checkpoints(parsed))
    if k <= 0 or not elig:
        return ()
    cum, acc = [], 0
    for li in parsed.layers:
        acc += li.macs
        cum.append(acc)
    total = max(acc, 1)
    sizes = {b: sum(checkpoint_live_bytes(parsed, b).values())
             for b in elig}
    k_eff = min(k, len(elig))
    chosen: set = set()
    for j in range(1, k_eff + 1):
        target = total * j / (k_eff + 1)
        best = min((b for b in elig if b not in chosen),
                   key=lambda b: (abs(cum[b] - target), sizes[b], b))
        chosen.add(best)
    return tuple(sorted(chosen))


# ------------------------------------------------------------------- TPU

@dataclasses.dataclass(frozen=True)
class TPUProfile:
    """TPU v5e-class chip constants used across roofline + DSE."""

    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12       # per chip
    peak_int8_ops: float = 394e12
    hbm_bandwidth: float = 819e9          # bytes/s
    hbm_bytes: int = 16 * 1024 ** 3
    vmem_bytes: int = 128 * 1024 ** 2     # ~128 MiB SRAM class budget
    ici_link_bandwidth: float = 50e9      # bytes/s per link
    ici_links: int = 4                    # 2-D torus: 4 links/chip
    mxu_tile: Tuple[int, int] = (128, 128)


TPU_V5E = TPUProfile()


def tpu_report_from_compiled(compiled, profile: TPUProfile = TPU_V5E,
                             collective_bytes: float = 0.0) -> ResourceReport:
    """Map a real XLA compiled artifact onto the four DSE quotas.

    lut -> HBM residency %, dsp -> arithmetic-intensity balance (time on
    MXU vs peak), mem -> temp (activation/workspace) pressure %,
    reg -> collective pressure relative to compute.  These play the same
    role the four FPGA quotas play in Algorithm 1: exceeding 100 on any
    quota means 'does not fit'.
    """
    ma = compiled.memory_analysis()
    from repro.roofline import cost_analysis_dict
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    resident = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes)
    t_compute = flops / profile.peak_bf16_flops
    t_memory = bytes_acc / profile.hbm_bandwidth
    t_coll = collective_bytes / (profile.ici_links * profile.ici_link_bandwidth)
    denom = max(t_compute, 1e-12)
    percents = {
        "lut": 100.0 * resident / profile.hbm_bytes,
        "dsp": 100.0 * min(t_compute / max(t_compute, t_memory, t_coll), 1.0),
        "mem": 100.0 * ma.temp_size_in_bytes / profile.hbm_bytes,
        "reg": 100.0 * min(t_coll / denom, 2.0) / 2.0,
    }
    raw = {"flops": flops, "bytes": bytes_acc, "resident": resident,
           "t_compute": t_compute, "t_memory": t_memory,
           "t_collective": t_coll, "collective_bytes": collective_bytes}
    fits = percents["lut"] <= 100.0
    return ResourceReport(percents=percents, raw=raw, fits=fits)


# ------------------------------------------- per-stage modeled costs

def modeled_stage_costs(parsed, profile: "FPGAProfile", n_i: int,
                        n_l: int, block_h: Optional[int] = None,
                        per_channel: bool = False) -> Dict[str, Dict]:
    """Per-stage analytical costs in schedule order — the model side of
    the attribution join (``launch/profile.py``, DESIGN.md §12).

    For every scheduled stage: the Table-1 latency split
    (``model_s``/``t_compute_s``/``t_memory_s`` from
    :func:`fpga_layer_time_s`), the modeled DDR traffic
    (``ddr_bytes`` = input + weight + output bytes from
    ``pipeline.layer_bytes`` — fused merges report the bytes the fusion
    actually moves), the stage's row-band working set (``vmem_bytes``
    from :func:`conv_band_working_set` scored on that stage alone;
    zero for stages the band model does not charge) and its ``macs``.
    Keyed by stage name so measured wall times join by name.
    """
    from . import pipeline as pipe  # resources never imports at top: no cycle

    out: Dict[str, Dict] = {}
    for li in parsed.layers:
        in_b, w_b, out_b = pipe.layer_bytes(li)
        t, tc, tm = fpga_layer_time_s(profile, n_i, n_l, li.macs,
                                      in_b, w_b, out_b)
        out[li.name] = {
            "kind": li.kind,
            "model_s": t, "t_compute_s": tc, "t_memory_s": tm,
            "ddr_bytes": in_b + w_b + out_b,
            "vmem_bytes": conv_band_working_set(
                [li], n_l, block_h, n_i=n_i, per_channel=per_channel),
            "macs": li.macs,
        }
    return out


# ------------------------------------------------- FPGA latency model

def fpga_layer_time_s(profile: FPGAProfile, n_i: int, n_l: int,
                      macs: int, in_bytes: int, w_bytes: int,
                      out_bytes: int) -> Tuple[float, float, float]:
    """max(compute, memory) per pipelined stage (batch = 1).

    compute: one MAC per lane-vector element per cycle -> macs/(N_i*N_l*f).
    memory : weights + input + output once over effective DDR bandwidth
             (the deep pipeline means features stream, §3.2.3).
    Returns (time_s, t_compute, t_memory).

    Calibration residuals vs the paper's Table 1 (batch = 1) are
    reported by benchmarks/table1_latency.py: AlexNet/Arria and
    AlexNet/Cyclone within ~1 %, VGG/Arria -14 %, VGG/Cyclone -53 %.
    The VGG-on-Cyclone underestimate is expected: Table 1 shows that
    board's RAM at 100 % — feature maps spill and the resulting stall
    traffic is not captured by this first-order streaming model (the
    paper makes the same point about buffer limits in §5).
    """
    f = profile.f_max_mhz * 1e6
    t_c = macs / (n_i * n_l * f)
    t_m = (in_bytes + w_bytes + out_bytes) / (profile.ddr_gbps * 1e9)
    return max(t_c, t_m), t_c, t_m
