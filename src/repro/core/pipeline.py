"""Pipelined int8 executor — the "host program" of §4.2.

Takes a parsed model + per-layer (N, m) quantization specs, quantizes
weights/biases once, and runs inference by streaming each pipeline stage
through the fused Pallas kernels (conv+ReLU+pool on the conv kernel, FC
on the same matrix unit with pooling configured pass-through — §5).

The executor is an **interpreter over the DAG stage program**
(DESIGN.md §6): the parser's topologically-scheduled stage list is
executed against a tensor environment of named int8 NHWC activations,
with liveness-based release (a tensor is dropped from the environment
after its last consumer runs, so a residual skip holds exactly as long
as its merge needs it).  Residual ``Add`` stages align their operands'
fixed-point positions with per-operand round-half-up shifts before the
int32 add (see :func:`thread_scales`); grouped/depthwise convs dispatch
to the depthwise band kernel or the exact reference path.

It remains **whole-network fused** (DESIGN.md §3): activations stay
NHWC int8 from ingress to egress — one NCHW->NHWC conversion when the
float input is quantized, one back only if the network ends in a
spatial stage — and every layer's weights are pre-staged into the
kernel-native layout once at :func:`build_quantized` time (conv OIHW ->
HWIO; FC rows permuted so flattening an NHWC activation hits the same
features the NCHW-trained weights expect).  :func:`make_executor`
closes the whole stage program over one ``jax.jit``, so steady-state
calls re-enter a single compiled executable instead of re-dispatching
the Python stage loop — the TPU analogue of the paper's host program
enqueueing one fused command queue.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from . import parser as P
from . import verify as V
from .quantize import INT8_MAX, INT8_MIN, QuantSpec, quantize_weights


@dataclasses.dataclass
class QuantizedLayer:
    """One stage with weights staged in the kernel-native layout:
    conv -> HWIO int8, FC -> (K, N) int8 in NHWC-flatten row order.
    Merge stages carry per-operand alignment shifts instead of weights."""

    info: P.LayerInfo
    spec: Optional[QuantSpec]
    w_q: Optional[jnp.ndarray]
    b_q: Optional[jnp.ndarray]
    operand_shifts: Tuple[int, ...] = ()
    # conv stages with a folded residual add: the merge's own spec
    # (requant shift from the common operand position to m_y); the
    # operand_shifts then align (conv intermediate, skip) in that order
    merge_spec: Optional[QuantSpec] = None


@dataclasses.dataclass
class QuantizedModel:
    """int8-ready pipeline (weights quantized with the *given* specs)."""

    name: str
    layers: List[QuantizedLayer]
    input_m: int          # fixed-point exponent of the network input
    output_m: int
    parsed: P.ParsedModel
    _executors: Dict[Tuple, Callable] = dataclasses.field(
        default_factory=dict, repr=False)

    @property
    def hardware_options(self):
        return self.parsed.hardware_options


def thread_scales(model: P.ParsedModel,
                  specs: Dict[str, QuantSpec]) -> Dict[str, int]:
    """Per-tensor fixed-point exponents implied by the per-layer specs —
    a graph pass over the DAG (the linear scan of the old executor only
    worked because every tensor had exactly one consumer).

    Rules: a weighted stage pins its input tensor at ``m_x`` and its
    output at ``m_y``; pools pass the scale through unchanged (both
    directions, so a pool feeding the first conv resolves too); merge
    stages output at their spec's ``m_y``, or at the minimum operand
    position when no spec was given.  A conv with a folded residual add
    pins its *intermediate* tensor (the unfused conv output) at its own
    ``m_y`` and its stage output at the merge spec's ``m_y`` — the same
    two rules the unfused Conv + Add pair would apply.  Iterated to
    fixpoint; raises if the graph input or output never resolves
    (under-specified specs).

    Per-channel specs change nothing here: tensor positions are
    *activation* scales, which stay per-tensor in every mode (a vector
    ``m_w`` only widens the weighted stage's own requant shift), so
    merge-alignment groups keep aligning on scalar positions.
    """
    tensor_m: Dict[str, int] = {}
    for _ in range(len(model.layers) + 2):
        changed = False

        def _set(t: str, m: int) -> None:
            nonlocal changed
            if t not in tensor_m:
                tensor_m[t] = m
                changed = True

        for li in model.layers:
            spec = specs.get(li.name)
            if li.kind in (P.CONV, P.FC):
                if spec is None:
                    raise KeyError(f"no QuantSpec for layer {li.name!r}")
                _set(li.inputs[0], spec.m_x)
                if li.kind == P.CONV and li.merge is not None:
                    _set(li.merge_intermediate, spec.m_y)
                    mspec = specs.get(li.merge.name)
                    if mspec is not None:
                        _set(li.output, mspec.m_y)
                    elif li.skip_input in tensor_m:
                        _set(li.output,
                             min(spec.m_y, tensor_m[li.skip_input]))
                else:
                    _set(li.output, spec.m_y)
            elif li.kind == P.POOL:
                if li.inputs[0] in tensor_m:
                    _set(li.output, tensor_m[li.inputs[0]])
                elif li.output in tensor_m:
                    _set(li.inputs[0], tensor_m[li.output])
            else:  # add / concat
                if spec is not None:
                    _set(li.output, spec.m_y)
                elif all(t in tensor_m for t in li.inputs):
                    _set(li.output, min(tensor_m[t] for t in li.inputs))
        if not changed:
            break
    for t in (model.input_name, model.output_name):
        if t not in tensor_m:
            raise ValueError("could not resolve fixed-point position of "
                             f"tensor {t!r} from the given specs")
    return tensor_m


def _stage_weights(li: P.LayerInfo, prev: Optional[P.LayerInfo],
                   w_q: np.ndarray) -> np.ndarray:
    """One-time layout staging (ingress-side, never per inference):
    conv OIHW -> HWIO; FC weight rows reordered from the exporter's
    NCHW-flatten order (c, h, w) to the executor's NHWC-flatten order
    (h, w, c) when the FC consumes a flattened spatial tensor.  ``prev``
    is the stage *producing* the FC's input tensor (DAG producer, not
    list predecessor)."""
    if li.kind == P.CONV:
        return np.transpose(w_q, (2, 3, 1, 0))
    if li.kind == P.FC and prev is not None and len(prev.out_shape) == 4:
        _n, c, h, w = prev.out_shape
        k, n_out = w_q.shape
        if k == c * h * w:
            return (w_q.reshape(c, h, w, n_out)
                    .transpose(1, 2, 0, 3)
                    .reshape(k, n_out))
    return w_q


def _check_group(li: P.LayerInfo) -> None:
    """Every grouped conv must be executable *as a grouped conv* —
    an invalid group can never fall through to the dense kernel and
    produce silently wrong numerics."""
    g = li.group
    if g < 1 or li.c_in % g or li.c_out % g:
        raise NotImplementedError(
            f"conv {li.name!r}: group={g} does not divide "
            f"C_in={li.c_in}/C_out={li.c_out}; the executor cannot map "
            "this onto the grouped kernel library")


def build_quantized(model: P.ParsedModel,
                    specs: Dict[str, QuantSpec],
                    per_channel: Optional[bool] = None,
                    verify: bool = True) -> QuantizedModel:
    """Apply the user-given (N, m) pairs (the paper: CNN2Gate does not
    *perform* quantization, it *applies* provided values) and stage all
    weights into the kernel-native layouts.  Merge stages (add/concat)
    get per-operand alignment shifts derived from :func:`thread_scales`;
    a spec for them is optional (default: merge at the minimum operand
    position, no output requant).

    ``per_channel`` selects the weight-scale mode:
      * ``None`` (default) — honour each spec as given: specs with a
        tuple ``m_w`` run the per-lane shift-vector epilogue, scalar
        specs run the unchanged per-tensor path;
      * ``True``  — every weighted layer must run per-channel: scalar
        ``m_w`` specs are widened to uniform per-Cout vectors (bit-
        identical numerics, shift-vector datapath);
      * ``False`` — strict per-tensor: a tuple ``m_w`` raises.
    Activations are per-tensor in every mode, so merge alignment and
    fused-skip epilogues are untouched beyond the conv requant.

    ``verify`` (default on) runs the static design-rule checks of
    :mod:`repro.core.verify` over the program — the cheap structural
    rules before staging, the overflow bounds on the staged int8 arrays
    after — and raises :class:`~repro.core.verify.VerificationError`
    (a ``ValueError``) on any error-severity diagnostic.  Verification
    is pure analysis: the staged program and the executor jaxpr are
    byte-identical with it on or off."""
    if per_channel is not None:
        coerced = {}
        for name, spec in specs.items():
            li = next((l for l in model.layers if l.name == name
                       or (l.merge is not None and l.merge.name == name)),
                      None)
            weighted = (li is not None and li.name == name
                        and li.kind in (P.CONV, P.FC))
            if not per_channel and spec.per_channel:
                raise V.VerificationError([V.Diagnostic(
                    "QV206", V.ERROR, stage=name,
                    detail=f"spec for {name!r} is per-channel but "
                           "per_channel=False was requested")])
            if per_channel and weighted and not spec.per_channel:
                coerced[name] = dataclasses.replace(
                    spec, m_w=(spec.m_w,) * li.c_out)
        specs = dict(specs, **coerced)
    if verify:
        # cheap structural rules first — spec shapes, shift ranges,
        # threading conflicts, merge alignment — so an infeasible spec
        # set fails with structured diagnostics before any staging work
        pre = V.check_spec_shapes(model, specs)
        pre += V.check_requant_shifts(model, specs)
        tm_chk, d_thr = V.thread_scales_checked(model, specs)
        pre += d_thr
        pre += V.check_merge_alignment(model, specs, tm_chk)
        V.VerificationReport(pre).raise_if_errors()
    tensor_m = thread_scales(model, specs)
    layers: List[QuantizedLayer] = []
    for li in model.layers:
        # pool stages carry no weights: int8 passes through at the
        # incoming fixed-point scale (no spec, no requant)
        spec = specs.get(li.name) if li.kind in (P.POOL, P.ADD, P.CONCAT)\
            else specs[li.name]
        w = model.graph.initializers[li.weight] if li.weight else None
        b = model.graph.initializers[li.bias] if li.bias else None
        w_q, b_q = (None, None)
        operand_shifts: Tuple[int, ...] = ()
        merge_spec: Optional[QuantSpec] = None
        if li.kind == P.CONV:
            _check_group(li)
        if li.kind == P.CONV and li.merge is not None:
            # folded residual add: same shift-only alignment rules as a
            # standalone merge, operands = (conv intermediate, skip)
            m_ops = (tensor_m[li.merge_intermediate],
                     tensor_m[li.skip_input])
            merge_spec = specs.get(li.merge.name)
            if merge_spec is None:
                m_common = min(m_ops)
                merge_spec = QuantSpec(m_w=0, m_x=m_common, m_y=m_common)
            operand_shifts = tuple(m - merge_spec.m_x for m in m_ops)
            if any(s < 0 for s in operand_shifts):
                raise V.VerificationError([V.Diagnostic(
                    "QV202", V.ERROR, stage=li.name,
                    tensor=li.output,
                    detail=f"fused merge {li.merge.name!r}: operand "
                           "position below the common scale "
                           f"m={merge_spec.m_x} (shifts {operand_shifts})"
                           " — shift-only alignment cannot scale up")])
        if li.kind in (P.ADD, P.CONCAT):
            m_ops = [tensor_m[t] for t in li.inputs]
            if spec is None:
                m_common = min(m_ops)
                spec = QuantSpec(m_w=0, m_x=m_common, m_y=m_common)
            operand_shifts = tuple(m - spec.m_x for m in m_ops)
            if any(s < 0 for s in operand_shifts):
                raise V.VerificationError([V.Diagnostic(
                    "QV202", V.ERROR, stage=li.name, tensor=li.output,
                    detail=f"merge {li.name!r}: operand position below "
                           f"the common scale m={spec.m_x} (shifts "
                           f"{operand_shifts}) — shift-only alignment "
                           "cannot scale up")])
        if w is not None:
            w_q, b_q = quantize_weights(w, b, spec)
            prev_info = model.stage_producing(li.inputs[0])
            w_q = jnp.asarray(_stage_weights(li, prev_info, w_q))
            b_q = jnp.asarray(b_q) if b_q is not None else None
        layers.append(QuantizedLayer(li, spec, w_q, b_q, operand_shifts,
                                     merge_spec))
    if verify:
        # the deep rules run on the staged program: overflow bounds on
        # the actual int8 arrays (no re-quantization), alias/liveness of
        # the schedule, fused/unfused threading identity
        post = V.check_accumulators(model, specs, quantized_layers=layers)
        post += V.check_concat_partition(model)
        post += V.check_liveness(model)
        post += V.check_threading_identity(model, specs)
        V.VerificationReport(post).raise_if_errors()
    return QuantizedModel(
        name=model.name,
        layers=layers,
        input_m=tensor_m[model.input_name],
        output_m=tensor_m[model.output_name],
        parsed=model,
    )


def _concat_axis(axis: int, ndim: int) -> int:
    """Map an NCHW concat axis onto the executor's NHWC layout."""
    if ndim == 4:
        return {0: 0, 1: 3, 2: 1, 3: 2}[axis % 4]
    return axis


def _apply_tensor_faults(h: jnp.ndarray, f: Dict) -> jnp.ndarray:
    """Apply in-flight activation faults (core/faults.py) to one named
    tensor inside the jitted program: XOR bit masks at flat indices
    (SEU bit flips) and zeroed flat ranges (dropped bursts)."""
    flat = h.reshape(-1)
    idx = f.get("xor_idx")
    if idx is not None and len(idx):
        ji = jnp.asarray(idx)
        mask = jnp.asarray(f["xor_mask"]).astype(h.dtype)
        flat = flat.at[ji].set(jax.lax.bitwise_xor(flat[ji], mask))
    z = f.get("zero_idx")
    if z is not None and len(z):
        flat = flat.at[jnp.asarray(z)].set(0)
    return flat.reshape(h.shape)


def _stage_stats(h: jnp.ndarray) -> jnp.ndarray:
    """int8-domain audit statistics of one stage output, computed
    inside the jitted closure: ``[saturation fraction, max |value|,
    mean |value|]``.  The guard (core/guard.py) dequantizes these
    host-side with the tensor's fixed-point position and compares them
    against calibration-time envelopes."""
    sat = jnp.mean(((h == INT8_MAX) | (h == INT8_MIN))
                   .astype(jnp.float32))
    a = jnp.abs(h.astype(jnp.int32)).astype(jnp.float32)
    return jnp.stack([sat, jnp.max(a), jnp.mean(a)])


def _apply_arg_faults(h: jnp.ndarray, entry) -> jnp.ndarray:
    """Apply a *call-time* activation-fault payload ``(idx, mask)`` to
    one tensor: XOR ``mask[k]`` into flat element ``idx[k]``.  Unlike
    the static ``faults=`` payload this one is a closure argument, so a
    whole batch of sampled fault trials can be vmapped through ONE
    compiled program (core/ser.py).  A zero mask is the identity, which
    is how padded/no-op trial slots ride along for free."""
    idx, mask = entry
    flat = h.reshape(-1)
    flat = flat.at[jnp.asarray(idx)].set(
        jax.lax.bitwise_xor(flat[jnp.asarray(idx)],
                            jnp.asarray(mask).astype(h.dtype)))
    return flat.reshape(h.shape)


def make_executor(qm: QuantizedModel, n_i: int = 16, n_l: int = 32,
                  block_h: Optional[int] = None,
                  interpret: Optional[bool] = None,
                  *,
                  audit=False,
                  faults: Optional[Dict[str, Dict]] = None,
                  checkpoints=None,
                  weight_args=(),
                  fault_args=(),
                  replay_from: Optional[int] = None,
                  stage_timed: bool = False,
                  tracer=None
                  ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Build the whole-network fused executor: ONE jitted closure that
    interprets the DAG stage program over a tensor environment.
    ``x_float`` is the NCHW float input; the result is float logits
    (dequantized with the output tensor's m).

    (N_i, N_l, block_h) select kernel tile shapes: N_l lanes ->
    output-channel tile (x8: eight 8-bit MACs per lane-vector element
    feed one MXU row), N_i -> ``block_cin = 8*N_i`` input-channel
    contraction tile (the conv kernel's innermost grid axis and the FC
    kernel's K tile — a real blocking knob, not just an analytical
    report), block_h -> the conv kernel's row-band height (the
    line-buffer depth of DESIGN.md §2).  Functionally the result is
    identical for every option — options trade resources for speed,
    exactly as in the paper.

    Conv stages with a folded residual add (``li.merge``) feed the skip
    operand straight into the kernel epilogue — no standalone add stage
    exists in the jitted program, so the merged feature map never
    round-trips through HBM between conv and add.

    Conv stages annotated for concat fusion (``li.concat``) write their
    output into a channel-offset slice of the merge's shared buffer: the
    buffer is allocated once at the first producer (tracked in the
    environment under a reserved ``"\\x00cbuf:"`` key so it can never
    collide with a graph tensor name), each producer's kernel call
    aliases it in and out with its own ``out_off``/``concat_shift``/
    ``concat_relu`` (and the merge's absorbed pool, when present), and
    the annotated Concat stage itself just *unwraps* the finished buffer
    as the merge tensor — no ``concatenate`` appears anywhere in the
    jitted program.  Liveness is exact: the buffer key is released at
    the Concat stage, which by construction runs after the last
    contributor.

    Buffer release is liveness-based: the stage index of each tensor's
    last consumer is precomputed, and the environment drops a tensor as
    soon as the schedule passes it — the program's peak live set (what
    the FPGA would hold in DDR-visible buffers) is what the DSE's branch
    rules score, not one threaded activation.

    ``audit=True`` makes the closure additionally return per-stage
    int8 audit statistics (``{tensor: [sat_frac, max_abs, mean_abs]}``)
    for the guarded-execution layer; a *collection* of tensor names
    audits only those stages (selective hardening, DESIGN.md §11 —
    the stats cost scales with the audited set).  ``faults`` injects
    in-flight activation faults (see core/faults.py).  All hooks
    default off, and when off NOTHING extra is traced — the emitted
    jaxpr is byte-identical to the unguarded executor (probed in
    tests).

    Resilience hooks (all trace-time-only; DESIGN.md §11):

      * ``checkpoints`` — stage indices at which the closure snapshots
        the live int8 tensor environment (exactly what a replay needs:
        the liveness pass guarantees the snapshot is sufficient and
        minimal).  The closure then also returns ``{stage_name:
        {tensor: int8 array}}``.  Boundaries inside a fused-concat
        group (shared merge buffer under construction) are rejected.
      * ``replay_from`` — build a *replay* closure instead: it takes a
        checkpoint environment (as returned above) and runs only the
        stages AFTER the given boundary index.  Recovery cost is
        bounded by the stages downstream of the boundary, not the
        network depth.
      * ``weight_args`` — stage names whose staged weights become a
        call-time argument (``ex(x, {stage: w_q})``): a batch of
        fault-injected weight images vmaps through one compiled
        program instead of rebuilding an executor per trial.
      * ``fault_args`` — tensor names whose activation-fault payload
        ``(idx, mask)`` becomes a call-time argument
        (``ex(x, ..., {tensor: (idx, mask)})``); a zero mask is a
        no-op slot, so fixed-shape trial batches vmap cleanly.

    ``stage_timed=True`` builds the **stage-timed executor** instead
    (DESIGN.md §12): every DAG stage (plus the ingress quantize and the
    egress dequant) is compiled as its OWN jitted sub-closure over the
    live tensor environment, and the returned callable runs them in
    schedule order with ``jax.block_until_ready`` between stages —
    measured per-stage wall time, the attribution input
    ``launch/profile.py`` joins against the analytical cost models.
    Returns ``(logits, timings)`` where ``timings`` is a schedule-order
    list of ``{"stage", "kind", "wall_us"}`` rows; an optional
    ``tracer`` (:class:`repro.core.telemetry.Tracer`) additionally
    records each stage as a trace span.  Numerics are identical to the
    fused closure (same stage program, same kernels); only the jit
    boundary moves, so per-stage times include each sub-closure's
    dispatch and device sync — honest about what stage-at-a-time
    execution costs, which is exactly the quantity the fused/stagewise
    benchmarks compare.  Exclusive with every other hook, and
    trace-time-only: ``stage_timed=False`` (the default) traces the
    byte-identical whole-network program.

    Return value composition (fixed order): ``logits``, then ``stats``
    when auditing, then ``ckpts`` when checkpointing.
    """
    block_cout = max(8 * n_l, 8)
    block_cin = max(8 * n_i, 8)
    stages = qm.layers
    out_name = qm.parsed.output_name
    in_name = qm.parsed.input_name
    out_stage = qm.parsed.stage_producing(out_name)

    last_use: Dict[str, int] = {}
    for idx, ql in enumerate(stages):
        for t in ql.info.inputs:
            last_use[t] = idx
    last_use[out_name] = len(stages)  # the egress reads it

    # ---- resilience-hook configuration (all static / trace-time) ----
    audit_sel = None if isinstance(audit, bool) else frozenset(audit)
    want_stats = audit is not False
    if stage_timed and (want_stats or faults or checkpoints
                        or weight_args or fault_args
                        or replay_from is not None):
        raise ValueError(
            "stage_timed is exclusive with the audit/faults/checkpoints/"
            "weight_args/fault_args/replay_from hooks: the stage-timed "
            "executor measures the plain program")

    def _audited(t: str) -> bool:
        return audit is True or (audit_sel is not None and t in audit_sel)

    weight_arg_set = frozenset(weight_args or ())
    weighted_names = {ql.info.name for ql in stages if ql.w_q is not None}
    unknown_w = weight_arg_set - weighted_names
    if unknown_w:
        raise ValueError("weight_args name stages without staged "
                         f"weights: {sorted(unknown_w)}")
    fault_arg_set = frozenset(fault_args or ())
    known_tensors = {ql.info.output for ql in stages} | {in_name}
    unknown_f = fault_arg_set - known_tensors
    if unknown_f:
        raise ValueError("fault_args name unknown tensors: "
                         f"{sorted(unknown_f)}")

    ckpt_idx = tuple(sorted({int(c) for c in (checkpoints or ())}))
    if ckpt_idx and replay_from is not None:
        raise ValueError("checkpoints and replay_from are exclusive: a "
                         "replay closure never snapshots")
    # boundary legality (range + never inside a fused-concat group) is
    # the verifier's QV304 rule — one shared implementation with the
    # checkpoint planner, so executor and planner can never disagree
    bad = V.check_checkpoint_boundaries(qm.parsed, ckpt_idx)
    if bad:
        raise V.VerificationError(bad)
    if replay_from is not None and not -1 <= replay_from < len(stages):
        raise ValueError(f"replay_from={replay_from} outside [-1, "
                         f"{len(stages)})")
    ckpt_set = frozenset(ckpt_idx)
    has_w_arg = bool(weight_arg_set)
    has_f_arg = bool(fault_arg_set)

    # concat fusion: producers need their merge's alignment shifts and
    # relu flag, which live on the (still-scheduled) Concat stage
    concat_ql = {ql.info.name: ql for ql in stages
                 if ql.info.kind == P.CONCAT}

    def _cbuf_key(cc: P.LayerInfo) -> str:
        return "\x00cbuf:" + cc.name

    def _extra(extra):
        """Split the optional positional tail into (weights, payload)."""
        i = 0
        weights = None
        payload = None
        if has_w_arg:
            weights = extra[i]
            i += 1
        if has_f_arg:
            payload = extra[i]
            i += 1
        if i != len(extra):
            raise TypeError(f"executor expected {i} extra argument(s) "
                            f"(weights={has_w_arg}, faults={has_f_arg}), "
                            f"got {len(extra)}")
        return weights, payload

    def _pack(logits, stats, ckpts):
        out = (logits,)
        if want_stats:
            out += (stats,)
        if ckpt_set:
            out += (ckpts,)
        return out if len(out) > 1 else logits

    def _exec_stages(env: Dict[str, jnp.ndarray], weights, payload,
                     start: int, stop: int, stats, ckpts) -> None:
        """Interpret stages ``[start, stop)`` over a live tensor
        environment, mutating ``env``/``stats``/``ckpts`` in place —
        the shared core of the forward, replay and stage-timed paths."""

        def _w(ql):
            if weights is not None and ql.info.name in weight_arg_set:
                return weights[ql.info.name]
            return ql.w_q

        for idx in range(start, stop):
            ql = stages[idx]
            li = ql.info
            if li.kind == P.CONV:
                pool = None
                if li.pool is not None:
                    pool = (li.pool.kernel_shape[0], li.pool.strides[0])
                merge_kw = {}
                if li.merge is not None:  # residual add in the epilogue
                    merge_kw = dict(
                        skip=env[li.skip_input],
                        skip_shifts=ql.operand_shifts,
                        merge_shift=ql.merge_spec.requant_shift,
                        merge_relu=li.merge.relu)
                if li.concat is not None:  # concat merge in the epilogue
                    cc = li.concat
                    cq = concat_ql[cc.name]
                    if cc.pool is not None:  # pool absorbed by the merge
                        pool = (cc.pool.kernel_shape[0], cc.pool.strides[0])
                    key = _cbuf_key(cc)
                    buf = env.get(key)
                    if buf is None:  # first contributor allocates
                        _nb, c_, h_, w_ = cc.out_shape
                        # batch comes from the traced activation, not
                        # the parse-time shape: the closure must lower
                        # at any batch (fullflow compiles a sample)
                        nb = env[li.inputs[0]].shape[0]
                        buf = jnp.zeros((nb, h_, w_, c_), jnp.int8)
                    merge_kw.update(
                        out_buf=buf,
                        out_off=li.concat_offset,
                        concat_shift=cq.operand_shifts[
                            cc.inputs.index(li.output)],
                        concat_relu=cc.relu)
                h = ops.qconv2d_nhwc(
                    env[li.inputs[0]], _w(ql), ql.b_q,
                    strides=li.strides, pads=li.pads,
                    shift=ql.spec.requant_shift, relu=li.relu, pool=pool,
                    groups=li.group, block_cout=block_cout, block_h=block_h,
                    block_cin=block_cin, interpret=interpret, **merge_kw)
                if li.concat is not None:
                    # h IS the shared buffer; the producer's own output
                    # tensor exists only as a channel slice of it.
                    # Faults/audit addressing that tensor act on the
                    # slice (written back via a dynamic update), so the
                    # resilience layer sees fused and standalone
                    # programs the same way.
                    has_static = bool(faults) and li.output in faults
                    has_arg = li.output in fault_arg_set
                    if has_static or has_arg or _audited(li.output):
                        off = li.concat_offset
                        sl = jax.lax.slice_in_dim(h, off, off + li.c_out,
                                                  axis=3)
                        if has_static:
                            sl = _apply_tensor_faults(sl, faults[li.output])
                        if has_arg:
                            sl = _apply_arg_faults(sl, payload[li.output])
                        if has_static or has_arg:
                            h = jax.lax.dynamic_update_slice_in_dim(
                                h, sl, off, axis=3)
                        if _audited(li.output):
                            stats[li.output] = _stage_stats(sl)
                    env[_cbuf_key(li.concat)] = h
                    for t in li.inputs:  # liveness still applies
                        if last_use.get(t) == idx:
                            env.pop(t, None)
                    continue
            elif li.kind == P.POOL:
                pool_fn = (ops.avgpool2d_nhwc if li.pool_type == "avg"
                           else ops.maxpool2d_nhwc)
                h = pool_fn(env[li.inputs[0]], li.kernel_shape[0],
                            li.strides[0], li.pads)
            elif li.kind == P.FC:
                h = env[li.inputs[0]]
                if h.ndim > 2:
                    # NHWC flatten: rows were permuted at staging time
                    h = h.reshape(h.shape[0], -1)
                h = ops.qgemm(h, _w(ql), ql.b_q,
                              shift=ql.spec.requant_shift,
                              relu=li.relu,
                              block_n=min(128, block_cout),
                              block_k=min(128, block_cin),
                              interpret=interpret)
            elif li.kind == P.ADD:
                h = ops.qadd_nhwc([env[t] for t in li.inputs],
                                  ql.operand_shifts,
                                  shift=ql.spec.requant_shift,
                                  relu=li.relu)
            elif li.kind == P.CONCAT:
                if li.concat_fused:
                    # the producers already wrote (aligned + relu'd +
                    # pooled) channel slices in place: the shared buffer
                    # IS the merge tensor — just unwrap and release it
                    h = env.pop(_cbuf_key(li))
                else:
                    xs = [env[t] for t in li.inputs]
                    h = ops.qconcat_nhwc(
                        xs, ql.operand_shifts,
                        axis=_concat_axis(li.axis, xs[0].ndim),
                        relu=li.relu)
            else:  # pragma: no cover - parser only emits the five kinds
                raise ValueError(li.kind)
            if faults and li.output in faults:
                h = _apply_tensor_faults(h, faults[li.output])
            if li.output in fault_arg_set:
                h = _apply_arg_faults(h, payload[li.output])
            if _audited(li.output):
                stats[li.output] = _stage_stats(h)
            env[li.output] = h
            for t in li.inputs:     # liveness-based buffer release
                if last_use.get(t) == idx:
                    env.pop(t, None)  # pop: an operand may repeat (x + x)
            if idx in ckpt_set:
                # snapshot AFTER the liveness release: the environment
                # holds exactly the live set — what a replay from this
                # boundary needs, and nothing more
                ckpts[li.name] = dict(env)

    def _egress(env: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        h = env[out_name]
        if h.ndim == 4:
            h = jnp.transpose(h, (0, 3, 1, 2))      # single egress NHWC->NCHW
        logits = h.astype(jnp.float32) * (2.0 ** -qm.output_m)
        if out_stage is not None and out_stage.softmax:
            logits = jax.nn.softmax(logits, axis=-1)
        return logits

    def _run(env: Dict[str, jnp.ndarray], weights, payload, start: int):
        stats: Dict[str, jnp.ndarray] = {}
        ckpts: Dict[str, Dict[str, jnp.ndarray]] = {}
        _exec_stages(env, weights, payload, start, len(stages),
                     stats, ckpts)
        return _egress(env), stats, ckpts

    def _ingress(x_float: jnp.ndarray, payload) -> jnp.ndarray:
        scale = 2.0 ** qm.input_m
        h = jnp.clip(jnp.round(x_float * scale), -128, 127).astype(jnp.int8)
        if h.ndim == 4:
            h = jnp.transpose(h, (0, 2, 3, 1))      # single ingress NCHW->NHWC
        if faults and in_name in faults:
            h = _apply_tensor_faults(h, faults[in_name])
        if in_name in fault_arg_set:
            h = _apply_arg_faults(h, payload[in_name])
        return h

    if stage_timed:
        return _make_stage_timed(qm, stages, in_name, _ingress,
                                 _exec_stages, _egress, tracer)

    if replay_from is not None:
        def replay(env: Dict[str, jnp.ndarray], *extra):
            weights, payload = _extra(extra)
            logits, stats, _ = _run(dict(env), weights, payload,
                                    replay_from + 1)
            return _pack(logits, stats, {})
        return jax.jit(replay)

    def forward(x_float: jnp.ndarray, *extra):
        weights, payload = _extra(extra)
        h = _ingress(x_float, payload)
        env: Dict[str, jnp.ndarray] = {in_name: h}
        logits, stats, ckpts = _run(env, weights, payload, 0)
        return _pack(logits, stats, ckpts)

    return jax.jit(forward)


def _make_stage_timed(qm: QuantizedModel, stages, in_name: str,
                      ingress: Callable, exec_stages: Callable,
                      egress: Callable, tracer) -> Callable:
    """Assemble the stage-timed executor (``make_executor(
    stage_timed=True)``): one jitted sub-closure per DAG stage over the
    live tensor environment, run in schedule order with a device sync
    between stages so each stage's wall time is attributable.  Ingress
    (quantize + layout) and egress (dequant + softmax) are timed as
    their own pseudo-stages — they are real work the fused closure also
    pays, and the attribution report should see 100 % of the wall."""

    def _stage_fn(idx: int) -> Callable:
        def f(env: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
            env = dict(env)
            exec_stages(env, None, None, idx, idx + 1, {}, {})
            return env
        return jax.jit(f)

    stage_fns = [_stage_fn(i) for i in range(len(stages))]
    ingress_fn = jax.jit(lambda x: ingress(x, None))
    egress_fn = jax.jit(egress)

    def timed(x_float: jnp.ndarray):
        timings: List[Dict[str, object]] = []

        def _t0():
            return (time.perf_counter(),
                    tracer.now_us() if tracer is not None else 0.0)

        def _rec(name: str, kind: str, t0, ts_us) -> None:
            dur_us = (time.perf_counter() - t0) * 1e6
            timings.append({"stage": name, "kind": kind,
                            "wall_us": dur_us})
            if tracer is not None:
                tracer.add_span(name, ts_us, dur_us, cat="stage",
                                args={"kind": kind,
                                      "model": qm.name})

        t0, ts = _t0()
        h = jax.block_until_ready(ingress_fn(x_float))
        _rec("ingress", "ingress", t0, ts)
        env: Dict[str, jnp.ndarray] = {in_name: h}
        for idx, fn in enumerate(stage_fns):
            li = stages[idx].info
            t0, ts = _t0()
            env = jax.block_until_ready(fn(env))
            _rec(li.name, li.kind, t0, ts)
        t0, ts = _t0()
        logits = jax.block_until_ready(egress_fn(env))
        _rec("egress", "egress", t0, ts)
        return logits, timings

    return timed


def run_int8(qm: QuantizedModel, x_float: jnp.ndarray,
             n_i: int = 16, n_l: int = 32,
             interpret: Optional[bool] = None,
             block_h: Optional[int] = None) -> jnp.ndarray:
    """Full pipelined inference through the fused executor.  Executors
    are cached per (N_i, N_l, block_h, interpret) on the model, so
    repeated calls hit the same compiled program."""
    key = (n_i, n_l, block_h, interpret)
    ex = qm._executors.get(key)
    if ex is None:
        ex = qm._executors[key] = make_executor(
            qm, n_i, n_l, block_h=block_h, interpret=interpret)
    return ex(x_float)


def layer_bytes(li: P.LayerInfo) -> Tuple[int, int, int]:
    """(input, weight, output) int8 bytes of a stage — feeds the FPGA
    latency model and the memory-schedule report.  Merge stages read
    every operand."""
    if li.kind in (P.ADD, P.CONCAT):
        if li.concat_fused:
            # producer-fused concat: the producers wrote their channel
            # slices straight into the shared buffer, so the merge
            # stage itself moves NOTHING (no operand reads, no merged
            # write) — the whole round trip the fusion saves
            return 0, 0, 0
        if li.kind == P.ADD:
            in_b = len(li.inputs) * int(np.prod(li.in_shape))
        else:
            in_b = int(np.prod(li.out_shape))
        return in_b, 0, int(np.prod(li.out_shape))
    in_b = int(np.prod(li.in_shape))
    if li.kind == P.CONV and li.merge is not None:
        # fused residual merge: the skip operand streams in once; the
        # intermediate conv result never touches memory at all
        in_b += int(np.prod(li.conv_out_shape))
    w_b = li.weight_count()
    out_b = int(np.prod(li.out_shape))
    if li.kind == P.CONV and li.concat is not None\
            and li.concat.pool is not None:
        # concat producer with the merge's absorbed pool: the slice it
        # writes is in pooled geometry
        cc = li.concat
        out_b = int(cc.out_shape[0] * li.c_out * np.prod(cc.out_shape[2:]))
    return in_b, w_b, out_b
