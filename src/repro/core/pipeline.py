"""Pipelined int8 executor — the "host program" of §4.2.

Takes a parsed model + per-layer (N, m) quantization specs, quantizes
weights/biases once, and runs inference by streaming each pipeline stage
through the fused Pallas kernels (conv+ReLU+pool on the conv kernel, FC
on the same matrix unit with pooling configured pass-through — §5).

The executor is **whole-network fused** (DESIGN.md §3): activations
stay NHWC int8 from ingress to egress — one NCHW->NHWC conversion when
the float input is quantized, one back only if the network ends in a
spatial stage — and every layer's weights are pre-staged into the
kernel-native layout once at :func:`build_quantized` time (conv OIHW ->
HWIO; FC rows permuted so flattening an NHWC activation hits the same
features the NCHW-trained weights expect).  :func:`make_executor`
closes the whole layer program over one ``jax.jit``, so steady-state
calls re-enter a single compiled executable instead of re-dispatching
the Python layer loop — the TPU analogue of the paper's host program
enqueueing one fused command queue.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from . import parser as P
from .quantize import QuantSpec, quantize_weights


@dataclasses.dataclass
class QuantizedLayer:
    """One stage with weights staged in the kernel-native layout:
    conv -> HWIO int8, FC -> (K, N) int8 in NHWC-flatten row order."""

    info: P.LayerInfo
    spec: QuantSpec
    w_q: Optional[jnp.ndarray]
    b_q: Optional[jnp.ndarray]


@dataclasses.dataclass
class QuantizedModel:
    """int8-ready pipeline (weights quantized with the *given* specs)."""

    name: str
    layers: List[QuantizedLayer]
    input_m: int          # fixed-point exponent of the network input
    output_m: int
    parsed: P.ParsedModel
    _executors: Dict[Tuple, Callable] = dataclasses.field(
        default_factory=dict, repr=False)

    @property
    def hardware_options(self):
        return self.parsed.hardware_options


def _stage_weights(li: P.LayerInfo, prev: Optional[P.LayerInfo],
                   w_q: np.ndarray) -> np.ndarray:
    """One-time layout staging (ingress-side, never per inference):
    conv OIHW -> HWIO; FC weight rows reordered from the exporter's
    NCHW-flatten order (c, h, w) to the executor's NHWC-flatten order
    (h, w, c) when the FC consumes a flattened spatial tensor."""
    if li.kind == P.CONV:
        return np.transpose(w_q, (2, 3, 1, 0))
    if li.kind == P.FC and prev is not None and len(prev.out_shape) == 4:
        _n, c, h, w = prev.out_shape
        k, n_out = w_q.shape
        if k == c * h * w:
            return (w_q.reshape(c, h, w, n_out)
                    .transpose(1, 2, 0, 3)
                    .reshape(k, n_out))
    return w_q


def build_quantized(model: P.ParsedModel,
                    specs: Dict[str, QuantSpec]) -> QuantizedModel:
    """Apply the user-given (N, m) pairs (the paper: CNN2Gate does not
    *perform* quantization, it *applies* provided values) and stage all
    weights into the kernel-native layouts."""
    layers: List[QuantizedLayer] = []
    prev_info: Optional[P.LayerInfo] = None
    for li in model.layers:
        # pool stages carry no weights: int8 passes through at the
        # incoming fixed-point scale (no spec, no requant)
        spec = specs.get(li.name) if li.kind == P.POOL else specs[li.name]
        w = model.graph.initializers[li.weight] if li.weight else None
        b = model.graph.initializers[li.bias] if li.bias else None
        w_q, b_q = (None, None)
        if w is not None:
            w_q, b_q = quantize_weights(w, b, spec)
            w_q = jnp.asarray(_stage_weights(li, prev_info, w_q))
            b_q = jnp.asarray(b_q) if b_q is not None else None
        layers.append(QuantizedLayer(li, spec, w_q, b_q))
        prev_info = li
    return QuantizedModel(
        name=model.name,
        layers=layers,
        input_m=specs[model.layers[0].name].m_x,
        output_m=specs[model.layers[-1].name].m_y,
        parsed=model,
    )


def make_executor(qm: QuantizedModel, n_i: int = 16, n_l: int = 32,
                  block_h: Optional[int] = None,
                  interpret: Optional[bool] = None
                  ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Build the whole-network fused executor: ONE jitted closure over
    the staged layer list.  ``x_float`` is the NCHW float input; the
    result is float logits (dequantized with the final layer's m_y).

    (N_i, N_l, block_h) select kernel tile shapes: N_l lanes ->
    output-channel tile (x8: eight 8-bit MACs per lane-vector element
    feed one MXU row), N_i -> contraction granularity, block_h -> the
    conv kernel's row-band height (the line-buffer depth of DESIGN.md
    §2).  Functionally the result is identical for every option —
    options trade resources for speed, exactly as in the paper.
    """
    block_cout = max(8 * n_l, 8)
    last = qm.layers[-1].info

    def forward(x_float: jnp.ndarray) -> jnp.ndarray:
        scale = 2.0 ** qm.input_m
        h = jnp.clip(jnp.round(x_float * scale), -128, 127).astype(jnp.int8)
        if h.ndim == 4:
            h = jnp.transpose(h, (0, 2, 3, 1))      # single ingress NCHW->NHWC
        for ql in qm.layers:
            li = ql.info
            if li.kind == P.CONV:
                pool = None
                if li.pool is not None:
                    pool = (li.pool.kernel_shape[0], li.pool.strides[0])
                h = ops.qconv2d_nhwc(
                    h, ql.w_q, ql.b_q,
                    strides=li.strides, pads=li.pads,
                    shift=ql.spec.requant_shift, relu=li.relu, pool=pool,
                    block_cout=block_cout, block_h=block_h,
                    interpret=interpret)
            elif li.kind == P.POOL:
                pool_fn = (ops.avgpool2d_nhwc if li.pool_type == "avg"
                           else ops.maxpool2d_nhwc)
                h = pool_fn(h, li.kernel_shape[0], li.strides[0], li.pads)
            elif li.kind == P.FC:
                if h.ndim > 2:
                    # NHWC flatten: rows were permuted at staging time
                    h = h.reshape(h.shape[0], -1)
                h = ops.qgemm(h, ql.w_q, ql.b_q,
                              shift=ql.spec.requant_shift,
                              relu=li.relu,
                              block_n=min(128, max(8 * n_l, 8)),
                              block_k=128,
                              interpret=interpret)
            else:  # pragma: no cover - parser only emits the three kinds
                raise ValueError(li.kind)
        if h.ndim == 4:
            h = jnp.transpose(h, (0, 3, 1, 2))      # single egress NHWC->NCHW
        logits = h.astype(jnp.float32) * (2.0 ** -qm.output_m)
        if last.softmax:
            logits = jax.nn.softmax(logits, axis=-1)
        return logits

    return jax.jit(forward)


def run_int8(qm: QuantizedModel, x_float: jnp.ndarray,
             n_i: int = 16, n_l: int = 32,
             interpret: Optional[bool] = None,
             block_h: Optional[int] = None) -> jnp.ndarray:
    """Full pipelined inference through the fused executor.  Executors
    are cached per (N_i, N_l, block_h, interpret) on the model, so
    repeated calls hit the same compiled program."""
    key = (n_i, n_l, block_h, interpret)
    ex = qm._executors.get(key)
    if ex is None:
        ex = qm._executors[key] = make_executor(
            qm, n_i, n_l, block_h=block_h, interpret=interpret)
    return ex(x_float)


def layer_bytes(li: P.LayerInfo) -> Tuple[int, int, int]:
    """(input, weight, output) int8 bytes of a stage — feeds the FPGA
    latency model and the memory-schedule report."""
    in_b = int(np.prod(li.in_shape))
    w_b = li.weight_count()
    out_b = int(np.prod(li.out_shape))
    return in_b, w_b, out_b
