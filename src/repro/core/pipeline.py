"""Pipelined int8 executor — the "host program" of §4.2.

Takes a parsed model + per-layer (N, m) quantization specs, quantizes
weights/biases once, and runs inference by streaming each pipeline stage
through the fused Pallas kernels (conv+ReLU+pool on the conv kernel, FC
on the same matrix unit with pooling configured pass-through — §5).
Activation tensors move between stages as int8 at the per-layer
fixed-point scale, mirroring the OpenCL pipes' int8 payload.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from . import parser as P
from .quantize import QuantSpec, quantize_weights


@dataclasses.dataclass
class QuantizedLayer:
    info: P.LayerInfo
    spec: QuantSpec
    w_q: Optional[jnp.ndarray]
    b_q: Optional[jnp.ndarray]


@dataclasses.dataclass
class QuantizedModel:
    """int8-ready pipeline (weights quantized with the *given* specs)."""

    name: str
    layers: List[QuantizedLayer]
    input_m: int          # fixed-point exponent of the network input
    output_m: int
    parsed: P.ParsedModel

    @property
    def hardware_options(self):
        return self.parsed.hardware_options


def build_quantized(model: P.ParsedModel,
                    specs: Dict[str, QuantSpec]) -> QuantizedModel:
    """Apply the user-given (N, m) pairs (the paper: CNN2Gate does not
    *perform* quantization, it *applies* provided values)."""
    layers: List[QuantizedLayer] = []
    for li in model.layers:
        # pool stages carry no weights: int8 passes through at the
        # incoming fixed-point scale (no spec, no requant)
        spec = specs.get(li.name) if li.kind == P.POOL else specs[li.name]
        w = model.graph.initializers[li.weight] if li.weight else None
        b = model.graph.initializers[li.bias] if li.bias else None
        w_q, b_q = (None, None)
        if w is not None:
            w_q, b_q = quantize_weights(w, b, spec)
            w_q = jnp.asarray(w_q)
            b_q = jnp.asarray(b_q) if b_q is not None else None
        layers.append(QuantizedLayer(li, spec, w_q, b_q))
    return QuantizedModel(
        name=model.name,
        layers=layers,
        input_m=specs[model.layers[0].name].m_x,
        output_m=specs[model.layers[-1].name].m_y,
        parsed=model,
    )


def run_int8(qm: QuantizedModel, x_float: jnp.ndarray,
             n_i: int = 16, n_l: int = 32,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    """Full pipelined inference.  ``x_float`` is the NCHW float input;
    returns float logits (dequantized with the final layer's m_y).

    (N_i, N_l) select kernel block shapes: N_l lanes -> output-channel
    tile (x8: eight 8-bit MACs per lane-vector element feed one MXU
    row), N_i -> contraction granularity.  Functionally the result is
    identical for every option — options trade resources for speed,
    exactly as in the paper.
    """
    scale = 2.0 ** qm.input_m
    h = jnp.clip(jnp.round(x_float * scale), -128, 127).astype(jnp.int8)
    block_cout = max(8 * n_l, 8)
    for ql in qm.layers:
        li = ql.info
        if li.kind == P.CONV:
            pool = None
            if li.pool is not None:
                pool = (li.pool.kernel_shape[0], li.pool.strides[0])
            h = ops.qconv2d_nchw(
                h, ql.w_q, ql.b_q,
                strides=li.strides, pads=li.pads,
                shift=ql.spec.requant_shift, relu=li.relu, pool=pool,
                block_cout=block_cout, interpret=interpret)
        elif li.kind == P.POOL:
            pool_fn = (ops.avgpool2d_nchw if li.pool_type == "avg"
                       else ops.maxpool2d_nchw)
            h = pool_fn(h, li.kernel_shape[0], li.strides[0], li.pads)
        elif li.kind == P.FC:
            if h.ndim > 2:
                h = h.reshape(h.shape[0], -1)
            h = ops.qgemm(h, ql.w_q, ql.b_q, shift=ql.spec.requant_shift,
                          relu=li.relu,
                          block_n=min(128, max(8 * n_l, 8)),
                          block_k=128,
                          interpret=interpret)
        else:  # pragma: no cover - parser only emits the three kinds
            raise ValueError(li.kind)
    logits = h.astype(jnp.float32) * (2.0 ** -qm.output_m)
    last = qm.layers[-1].info
    if last.softmax:
        logits = jax.nn.softmax(logits, axis=-1)
    return logits


def layer_bytes(li: P.LayerInfo) -> Tuple[int, int, int]:
    """(input, weight, output) int8 bytes of a stage — feeds the FPGA
    latency model and the memory-schedule report."""
    in_b = int(np.prod(li.in_shape))
    w_b = li.weight_count()
    out_b = int(np.prod(li.out_shape))
    return in_b, w_b, out_b
