"""Deterministic SEU-style fault injection for the int8 runtime.

The paper pitches FPGAs for "industrial and mission-critical scenarios"
(§1); the FPGA-toolflow surveys it builds on treat single-event-upset
behavior as a first-class property of a production toolflow.  This
module lets us *quantify* the int8 pipeline's resilience: a
:class:`FaultPlan` is a seedable, fully deterministic set of
:class:`Fault` records that corrupt a **built** program — the staged
int8 weights, int32 biases, per-lane shift vectors and requant scales
of a :class:`~repro.core.pipeline.QuantizedModel`, or the inter-stage
int8 activations the executor streams between kernels.

Fault classes (DESIGN.md §9):

  * ``weight_bit`` / ``bias_bit``   — one bit of a staged weight (int8)
    or bias (int32) word flips: configuration-RAM / weight-buffer SEU.
  * ``shift_lane``                  — one lane of a per-channel requant
    shift vector moves by ``delta``: a flipped shift-register bit.
  * ``scale``                       — a layer's output scale ``m_y``
    moves by ``delta`` (the whole requant shift is wrong): control-word
    SEU.
  * ``dropped_tile``                — a contiguous Cout slice of a
    staged weight reads back as zeros: a DMA'd tile never arrived.
  * ``activation_bit``              — one bit of a named inter-stage
    int8 activation flips in flight: line-buffer / DDR-word SEU.
  * ``activation_tile``             — a flat range of an inter-stage
    activation reads back as zeros: a lost burst.

Weight-side faults are applied host-side by :func:`inject`, which
returns a **new** corrupted :class:`QuantizedModel` (the pristine model
is never mutated — it is the golden image the guard's degradation
policy rebuilds from).  Activation faults are handed to
``pipeline.make_executor(faults=...)`` and applied inside the one
jitted closure, so the corrupted program still runs as a single
compiled executable.

Everything is derived from ``np.random.default_rng(seed)``: the same
seed over the same model yields the same plan, byte for byte — the
property the fault-injection bench and the determinism tests rely on.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import pipeline as pipe
from .quantize import MAX_SHIFT, QuantSpec

WEIGHT_BIT = "weight_bit"
BIAS_BIT = "bias_bit"
SHIFT_LANE = "shift_lane"
SCALE = "scale"
DROPPED_TILE = "dropped_tile"
ACTIVATION_BIT = "activation_bit"
ACTIVATION_TILE = "activation_tile"

#: Fault classes applied to the staged program (host-side, inject()).
PROGRAM_KINDS = (WEIGHT_BIT, BIAS_BIT, SHIFT_LANE, SCALE, DROPPED_TILE)
#: Fault classes applied to inter-stage tensors (in the jitted closure).
ACTIVATION_KINDS = (ACTIVATION_BIT, ACTIVATION_TILE)
ALL_KINDS = PROGRAM_KINDS + ACTIVATION_KINDS


@dataclasses.dataclass(frozen=True)
class Fault:
    """One SEU event.  ``stage`` names the pipeline stage (LayerInfo
    name); activation faults additionally carry the ``tensor`` they
    corrupt (the stage's output tensor when sampled)."""

    kind: str
    stage: str
    index: int = 0          # flat element index (weight/bias/activation)
    bit: int = 0            # bit position for *_bit kinds
    lane: int = 0           # Cout lane for shift_lane
    delta: int = 1          # exponent perturbation for shift_lane/scale
    tile: Tuple[int, int] = (0, 0)  # [start, stop) for *_tile kinds
    tensor: str = ""        # activation faults: target tensor name


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic set of faults (optionally tagged with the seed
    that sampled it, for reports)."""

    faults: Tuple[Fault, ...]
    seed: Optional[int] = None

    def __len__(self) -> int:
        return len(self.faults)

    @property
    def program_faults(self) -> Tuple[Fault, ...]:
        return tuple(f for f in self.faults if f.kind in PROGRAM_KINDS)

    @classmethod
    def sample(cls, qm: pipe.QuantizedModel, n: int,
               kinds: Sequence[str] = (WEIGHT_BIT,), seed: int = 0,
               bits: Sequence[int] = tuple(range(8))) -> "FaultPlan":
        """Draw ``n`` faults of the given kinds against the built
        program.  Deterministic in ``(qm structure, n, kinds, seed,
        bits)``; the same seed always produces the same plan."""
        for k in kinds:
            if k not in ALL_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        rng = np.random.default_rng(seed)
        weighted = [ql for ql in qm.layers if ql.w_q is not None]
        biased = [ql for ql in weighted if ql.b_q is not None]
        per_chan = [ql for ql in weighted
                    if ql.spec is not None and ql.spec.per_channel]
        faults: List[Fault] = []
        for _ in range(n):
            kind = str(rng.choice(list(kinds)))
            if kind in (WEIGHT_BIT, DROPPED_TILE, SCALE):
                pool = weighted
            elif kind == BIAS_BIT:
                pool = biased
            elif kind == SHIFT_LANE:
                pool = per_chan
            else:  # activation faults target any stage output
                pool = list(qm.layers)
            if not pool:
                raise ValueError(
                    f"no eligible stage for fault kind {kind!r}")
            ql = pool[int(rng.integers(len(pool)))]
            li = ql.info
            if kind == WEIGHT_BIT:
                f = Fault(kind, li.name,
                          index=int(rng.integers(int(ql.w_q.size))),
                          bit=int(rng.choice(list(bits))))
            elif kind == BIAS_BIT:
                f = Fault(kind, li.name,
                          index=int(rng.integers(int(ql.b_q.size))),
                          bit=int(rng.integers(32)))
            elif kind == SHIFT_LANE:
                f = Fault(kind, li.name,
                          lane=int(rng.integers(len(ql.spec.m_w))),
                          delta=int(rng.choice([-2, -1, 1, 2])))
            elif kind == SCALE:
                f = Fault(kind, li.name,
                          delta=int(rng.choice([1, 2])))
            elif kind == DROPPED_TILE:
                cout = int(ql.w_q.shape[-1])
                width = int(rng.integers(1, max(2, cout // 4 + 1)))
                start = int(rng.integers(max(1, cout - width + 1)))
                f = Fault(kind, li.name, tile=(start, start + width))
            else:
                size = int(np.prod(li.out_shape))
                if kind == ACTIVATION_BIT:
                    f = Fault(kind, li.name,
                              index=int(rng.integers(size)),
                              bit=int(rng.choice(list(bits))),
                              tensor=li.output)
                else:
                    width = max(1, size // 64)
                    start = int(rng.integers(max(1, size - width + 1)))
                    f = Fault(kind, li.name, tile=(start, start + width),
                              tensor=li.output)
            faults.append(f)
        return cls(tuple(faults), seed=seed)

    # ------------------------------------------------- executor payload
    def activation_faults(self) -> Dict[str, Dict[str, np.ndarray]]:
        """Per-tensor payload for ``pipeline.make_executor(faults=...)``:
        XOR masks for bit flips and flat index ranges to zero for
        dropped tiles, keyed by the tensor each fault targets."""
        xor: Dict[str, List[Tuple[int, int]]] = defaultdict(list)
        zero: Dict[str, List[int]] = defaultdict(list)
        for f in self.faults:
            if f.kind not in ACTIVATION_KINDS:
                continue
            if not f.tensor:
                raise ValueError(
                    f"activation fault on stage {f.stage!r} names no "
                    "tensor (set Fault.tensor)")
            if f.kind == ACTIVATION_BIT:
                mask = int(np.array(1 << (f.bit % 8), np.uint8)
                           .astype(np.int8))
                xor[f.tensor].append((f.index, mask))
            else:
                zero[f.tensor].extend(range(f.tile[0], f.tile[1]))
        out: Dict[str, Dict[str, np.ndarray]] = {}
        for t in set(xor) | set(zero):
            entry: Dict[str, np.ndarray] = {}
            if xor.get(t):
                entry["xor_idx"] = np.asarray([i for i, _ in xor[t]],
                                              np.int32)
                entry["xor_mask"] = np.asarray([m for _, m in xor[t]],
                                               np.int8)
            if zero.get(t):
                entry["zero_idx"] = np.asarray(sorted(set(zero[t])),
                                               np.int32)
            out[t] = entry
        return out


# ------------------------------------------------------------ injection

def _flip_bit(arr: np.ndarray, index: int, bit: int) -> None:
    """Flip one bit of one element, in place, via an unsigned view
    (XOR on the signed dtype would overflow at the sign bit)."""
    flat = arr.reshape(-1)
    u = flat.view(np.uint8 if arr.dtype == np.int8 else np.uint32)
    u[index % flat.size] ^= np.asarray(
        1 << (bit % (8 * arr.dtype.itemsize)), u.dtype)


def _corrupt_scale(spec: QuantSpec, delta: int) -> QuantSpec:
    """Move the output scale ``m_y`` by ±delta — whichever direction
    keeps the requant shift representable (the fault must build)."""
    for d in (-abs(delta), abs(delta)):
        cand = dataclasses.replace(spec, m_y=spec.m_y + d)
        try:
            cand.requant_shift
        except ValueError:
            continue
        return cand
    return spec  # no representable corruption: leave untouched


def _corrupt_lane(spec: QuantSpec, lane: int, delta: int) -> QuantSpec:
    """Perturb one lane of a per-channel shift vector, clamped so the
    corrupted program still satisfies the datapath's 0..MAX_SHIFT
    range (an unrepresentable shift would refuse to build — the fault
    model is a wrong-but-running configuration)."""
    if not spec.per_channel:
        raise ValueError("shift_lane fault needs a per-channel spec")
    mw = list(spec.m_w)
    lane %= len(mw)
    lo = spec.m_y - spec.m_x                       # shift >= 0
    hi = MAX_SHIFT + spec.m_y - spec.m_x           # shift <= MAX_SHIFT
    for d in (delta, -delta):
        cand = int(np.clip(mw[lane] + d, lo, hi))
        if cand != mw[lane]:
            mw[lane] = cand
            return dataclasses.replace(spec, m_w=tuple(mw))
    return spec


def inject(qm: pipe.QuantizedModel, plan: FaultPlan) -> pipe.QuantizedModel:
    """Apply a plan's program-side faults, returning a **new** corrupted
    :class:`QuantizedModel` (fresh executor cache; the input model and
    its staged arrays are untouched — it stays the golden image).
    Activation faults are not applied here; pass
    ``plan.activation_faults()`` to ``make_executor(faults=...)``."""
    by_stage: Dict[str, List[Fault]] = defaultdict(list)
    for f in plan.program_faults:
        by_stage[f.stage].append(f)
    unknown = set(by_stage) - {ql.info.name for ql in qm.layers}
    if unknown:
        raise KeyError(f"fault plan names unknown stages: {sorted(unknown)}")
    layers: List[pipe.QuantizedLayer] = []
    for ql in qm.layers:
        fs = by_stage.get(ql.info.name)
        if not fs:
            layers.append(ql)
            continue
        w = np.array(ql.w_q) if ql.w_q is not None else None
        b = np.array(ql.b_q) if ql.b_q is not None else None
        spec = ql.spec
        for f in fs:
            if f.kind == WEIGHT_BIT:
                if w is None:
                    raise ValueError(f"stage {f.stage!r} has no weights")
                _flip_bit(w, f.index, f.bit)
            elif f.kind == BIAS_BIT:
                if b is None:
                    raise ValueError(f"stage {f.stage!r} has no bias")
                _flip_bit(b, f.index, f.bit)
            elif f.kind == DROPPED_TILE:
                if w is None:
                    raise ValueError(f"stage {f.stage!r} has no weights")
                cout = w.shape[-1]
                t0 = min(max(f.tile[0], 0), cout)
                t1 = min(max(f.tile[1], t0), cout)
                w[..., t0:t1] = 0
            elif f.kind == SHIFT_LANE:
                spec = _corrupt_lane(spec, f.lane, f.delta)
            elif f.kind == SCALE:
                spec = _corrupt_scale(spec, f.delta)
        layers.append(dataclasses.replace(
            ql,
            w_q=jnp.asarray(w) if w is not None else None,
            b_q=jnp.asarray(b) if b is not None else None,
            spec=spec))
    return pipe.QuantizedModel(
        name=qm.name, layers=layers, input_m=qm.input_m,
        output_m=qm.output_m, parsed=qm.parsed)
