"""Neutral dataflow IR for CNN2Gate-style model analysis.

This is the "extensible acyclic graph" of the paper's §4.1: nodes are
operators with ONNX-compatible ``op_type`` strings, edges are named
tensors.  Shape inference for Conv/MaxPool follows Eq. (3)/(4) of the
paper exactly (floor-division form with pads/dilations/strides).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# ONNX operator names the front-end parser understands (§4.1 of the paper).
SUPPORTED_OPS = (
    "Conv",
    "MaxPool",
    "AveragePool",
    "Relu",
    "Gemm",
    "MatMul",
    "Softmax",
    "Flatten",
    "Reshape",
    "Add",
    "Concat",
    "GlobalAveragePool",
    "Dropout",  # inference no-op; parsed and elided
    "Identity",
)


@dataclasses.dataclass(frozen=True)
class TensorInfo:
    """Shape/dtype metadata for a named edge in the graph."""

    name: str
    shape: Tuple[int, ...]
    dtype: str = "float32"

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclasses.dataclass
class Node:
    """A single operator node, ONNX-flavoured."""

    op_type: str
    name: str
    inputs: List[str]
    outputs: List[str]
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def attr(self, key: str, default: Any = None) -> Any:
        return self.attrs.get(key, default)


class GraphError(ValueError):
    pass


class GraphValidationError(GraphError):
    """Structured ingress-validation failure.

    Raised when an imported model is rejected *before* any staging work:
    non-finite weights, malformed containers, dangling edges.  Carries
    machine-readable fields so callers (CLI, serving admission) can
    report what was wrong without parsing the message.
    """

    def __init__(self, reason: str, *, node: str = "", tensor: str = "",
                 detail: str = ""):
        self.reason = reason
        self.node = node
        self.tensor = tensor
        self.detail = detail
        where = " ".join(p for p in (
            f"node={node}" if node else "",
            f"tensor={tensor}" if tensor else "") if p)
        msg = reason + (f" [{where}]" if where else "")
        if detail:
            msg += f": {detail}"
        super().__init__(msg)


def conv_output_hw(
    in_hw: Sequence[int],
    kernel_shape: Sequence[int],
    strides: Sequence[int],
    pads: Sequence[int],
    dilations: Sequence[int],
) -> Tuple[int, int]:
    """Eq. (3) of the paper: floor((x + 2p - d(ks-1) - 1)/st + 1).

    ``pads`` is ONNX-style (pad_top, pad_left, pad_bottom, pad_right); the
    paper's 2p corresponds to pad_begin + pad_end per spatial dim.
    """
    h_in, w_in = int(in_hw[0]), int(in_hw[1])
    ks, st, d = kernel_shape, strides, dilations
    p_sum = (pads[0] + pads[2], pads[1] + pads[3])
    h_out = math.floor((h_in + p_sum[0] - d[0] * (ks[0] - 1) - 1) / st[0] + 1)
    w_out = math.floor((w_in + p_sum[1] - d[1] * (ks[1] - 1) - 1) / st[1] + 1)
    if h_out <= 0 or w_out <= 0:
        raise GraphError(
            f"Eq.(3) produced non-positive output dims {h_out}x{w_out} for "
            f"input {h_in}x{w_in} ks={ks} st={st} p={pads} d={d}"
        )
    return h_out, w_out


def _norm4(pads: Optional[Sequence[int]]) -> Tuple[int, int, int, int]:
    if pads is None:
        return (0, 0, 0, 0)
    if len(pads) == 2:  # symmetric shorthand
        return (pads[0], pads[1], pads[0], pads[1])
    if len(pads) == 4:
        return tuple(int(p) for p in pads)  # type: ignore[return-value]
    raise GraphError(f"bad pads {pads}")


def _norm2(v: Optional[Sequence[int]], default: int = 1) -> Tuple[int, int]:
    if v is None:
        return (default, default)
    if isinstance(v, int):
        return (v, v)
    if len(v) == 1:
        return (int(v[0]), int(v[0]))
    return (int(v[0]), int(v[1]))


class Graph:
    """Acyclic dataflow graph with topological node order.

    ``initializers`` holds weights/biases (numpy arrays) keyed by tensor
    name — the analogue of the ONNX initializer list the paper's parser
    extracts alongside the dataflow.
    """

    def __init__(
        self,
        name: str,
        nodes: Iterable[Node],
        inputs: Sequence[TensorInfo],
        outputs: Sequence[str],
        initializers: Optional[Dict[str, np.ndarray]] = None,
    ) -> None:
        self.name = name
        self.nodes: List[Node] = list(nodes)
        self.inputs: List[TensorInfo] = list(inputs)
        self.outputs: List[str] = list(outputs)
        self.initializers: Dict[str, np.ndarray] = dict(initializers or {})
        self._validate()
        self.nodes = self._toposort()
        self.tensor_shapes: Dict[str, Tuple[int, ...]] = {}
        self._infer_shapes()
        # Producer/consumer adjacency, built once: the parser queries
        # these inside per-node loops, so the O(nodes) scans the naive
        # producer_of/consumers_of would do turn quadratic on deep nets.
        self._producer: Dict[str, Node] = {}
        self._consumers: Dict[str, List[Node]] = {}
        for n in self.nodes:
            for o in n.outputs:
                self._producer[o] = n
            for i in n.inputs:
                self._consumers.setdefault(i, []).append(n)

    # -- structure ----------------------------------------------------
    def _validate(self) -> None:
        producers: Dict[str, str] = {}
        for t in self.inputs:
            producers[t.name] = "<graph-input>"
        for name in self.initializers:
            producers[name] = "<initializer>"
        for n in self.nodes:
            if n.op_type not in SUPPORTED_OPS:
                raise GraphError(f"unsupported op_type {n.op_type!r} in node {n.name}")
            for o in n.outputs:
                if o in producers:
                    raise GraphError(f"tensor {o!r} produced twice")
                producers[o] = n.name
        for n in self.nodes:
            for i in n.inputs:
                if i not in producers:
                    raise GraphError(f"node {n.name} consumes undefined tensor {i!r}")
        for o in self.outputs:
            if o not in producers:
                raise GraphError(f"graph output {o!r} never produced")

    def _toposort(self) -> List[Node]:
        ready = {t.name for t in self.inputs} | set(self.initializers)
        pending = list(self.nodes)
        ordered: List[Node] = []
        while pending:
            progressed = False
            rest: List[Node] = []
            for n in pending:
                if all(i in ready for i in n.inputs):
                    ordered.append(n)
                    ready.update(n.outputs)
                    progressed = True
                else:
                    rest.append(n)
            pending = rest
            if not progressed:
                raise GraphError(
                    "graph has a cycle or disconnected nodes: "
                    + ", ".join(n.name for n in pending)
                )
        return ordered

    # -- shape inference (Eq. 3/4) -------------------------------------
    def _infer_shapes(self) -> None:
        shapes = self.tensor_shapes
        for t in self.inputs:
            shapes[t.name] = tuple(t.shape)
        for name, arr in self.initializers.items():
            shapes[name] = tuple(arr.shape)
        for n in self.nodes:
            fn = getattr(self, f"_shape_{n.op_type.lower()}", None)
            if fn is None:
                raise GraphError(f"no shape rule for {n.op_type}")
            out_shapes = fn(n, [shapes[i] for i in n.inputs])
            for o, s in zip(n.outputs, out_shapes):
                shapes[o] = tuple(int(x) for x in s)

    # All activation tensors are NCHW (ONNX convention).
    def _shape_conv(self, n: Node, ins):
        x, w = ins[0], ins[1]
        if len(x) != 4 or len(w) != 4:
            raise GraphError(f"Conv {n.name} expects 4-D input/weight, got {x}/{w}")
        group = int(n.attr("group", 1))
        if x[1] != w[1] * group:
            raise GraphError(
                f"Conv {n.name}: C_in mismatch x={x} w={w} group={group}"
            )
        ks = _norm2(n.attr("kernel_shape", (w[2], w[3])))
        st = _norm2(n.attr("strides", 1))
        d = _norm2(n.attr("dilations", 1))
        p = _norm4(n.attr("pads"))
        h, wo = conv_output_hw(x[2:], ks, st, p, d)
        return [(x[0], w[0], h, wo)]

    def _shape_maxpool(self, n: Node, ins):
        (x,) = ins[:1]
        ks = _norm2(n.attr("kernel_shape"))
        st = _norm2(n.attr("strides", ks[0]))
        d = _norm2(n.attr("dilations", 1))
        p = _norm4(n.attr("pads"))
        h, w = conv_output_hw(x[2:], ks, st, p, d)
        # Eq. (4): c_out = c_in for pooling.
        return [(x[0], x[1], h, w)]

    _shape_averagepool = _shape_maxpool

    def _shape_globalaveragepool(self, n: Node, ins):
        (x,) = ins[:1]
        return [(x[0], x[1], 1, 1)]

    def _shape_relu(self, n: Node, ins):
        return [ins[0]]

    _shape_softmax = _shape_relu
    _shape_identity = _shape_relu

    def _shape_dropout(self, n: Node, ins):
        return [ins[0]] * max(1, len(n.outputs))

    def _shape_add(self, n: Node, ins):
        a, b = ins
        if tuple(a) != tuple(b):
            raise GraphError(f"Add {n.name}: shape mismatch {a} vs {b}")
        return [a]

    def _shape_concat(self, n: Node, ins):
        axis = int(n.attr("axis", 1))
        base = list(ins[0])
        axis = axis % len(base)
        for s in ins[1:]:
            if len(s) != len(base) or any(
                    a != b for d, (a, b) in enumerate(zip(s, base))
                    if d != axis):
                raise GraphError(f"Concat {n.name}: incompatible {ins}")
        base[axis] = sum(s[axis] for s in ins)
        return [tuple(base)]

    def _shape_flatten(self, n: Node, ins):
        (x,) = ins[:1]
        axis = int(n.attr("axis", 1))
        lead = int(np.prod(x[:axis])) if axis else 1
        return [(lead, int(np.prod(x[axis:])))]

    def _shape_reshape(self, n: Node, ins):
        x = ins[0]
        target = n.attr("shape")
        if target is None and len(n.inputs) > 1:
            target = self.initializers[n.inputs[1]].tolist()
        target = [int(t) for t in target]
        total = int(np.prod(x))
        if -1 in target:
            idx = target.index(-1)
            known = int(np.prod([t for t in target if t != -1]))
            target[idx] = total // known
        if int(np.prod(target)) != total:
            raise GraphError(f"Reshape {n.name}: {x} -> {target} size mismatch")
        return [tuple(target)]

    def _shape_gemm(self, n: Node, ins):
        a, b = ins[0], ins[1]
        trans_a = int(n.attr("transA", 0))
        trans_b = int(n.attr("transB", 0))
        m, k = (a[1], a[0]) if trans_a else (a[0], a[1])
        kb, nn = (b[1], b[0]) if trans_b else (b[0], b[1])
        if k != kb:
            raise GraphError(f"Gemm {n.name}: K mismatch {a}x{b} tA={trans_a} tB={trans_b}")
        return [(m, nn)]

    def _shape_matmul(self, n: Node, ins):
        a, b = ins
        if a[-1] != b[-2 if len(b) > 1 else 0]:
            raise GraphError(f"MatMul {n.name}: {a} @ {b}")
        return [tuple(a[:-1]) + (b[-1],)]

    # -- convenience ----------------------------------------------------
    def producer_of(self, tensor: str) -> Optional[Node]:
        return self._producer.get(tensor)

    def consumers_of(self, tensor: str) -> List[Node]:
        return list(self._consumers.get(tensor, ()))

    def shape(self, tensor: str) -> Tuple[int, ...]:
        return self.tensor_shapes[tensor]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Graph({self.name!r}, {len(self.nodes)} nodes)"
