"""ONNX-compatible transport layer (framework-neutral model exchange).

The paper uses ONNX protobufs as its "model transfer layer" so the
synthesis tool is decoupled from whatever ML framework produced the model
(§4.1).  The ``onnx`` package is not available offline, so this module
implements the same *contract* with a JSON + npz container:

  model.json  — graph topology: nodes with ONNX ``op_type`` names, attrs
  model.npz   — initializers (weights/biases) keyed by tensor name

``from_model_dict``/``to_model_dict`` are the in-memory equivalents, and
exporters are provided for the builder DSL in ``repro.models.cnn`` so any
front end that can emit this dict (Keras/PyTorch exporters emit ONNX with
the same op names) plugs in unchanged.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import numpy as np

from .graph import Graph, GraphError, GraphValidationError, Node, TensorInfo

FORMAT_VERSION = 1


def validate_initializers(initializers: Optional[Dict[str, np.ndarray]],
                          ) -> None:
    """Reject non-finite imported weights at the door.

    A NaN/Inf in an initializer silently poisons calibration (max-abs
    over NaN is NaN -> every quantized value is garbage), so ingress is
    the only place it can be caught cheaply and attributed to a tensor.
    """
    for name, arr in (initializers or {}).items():
        arr = np.asarray(arr)
        if arr.dtype.kind == "f" and not np.isfinite(arr).all():
            bad = int(np.size(arr) - np.isfinite(arr).sum())
            raise GraphValidationError(
                "non-finite initializer", tensor=name,
                detail=f"{bad} NaN/Inf of {arr.size} values")


def to_model_dict(graph: Graph) -> Dict[str, Any]:
    return {
        "format_version": FORMAT_VERSION,
        "name": graph.name,
        "inputs": [
            {"name": t.name, "shape": list(t.shape), "dtype": t.dtype}
            for t in graph.inputs
        ],
        "outputs": list(graph.outputs),
        "nodes": [
            {
                "op_type": n.op_type,
                "name": n.name,
                "inputs": list(n.inputs),
                "outputs": list(n.outputs),
                "attrs": _jsonify_attrs(n.attrs),
            }
            for n in graph.nodes
        ],
    }


def from_model_dict(
    model: Dict[str, Any], initializers: Optional[Dict[str, np.ndarray]] = None
) -> Graph:
    if model.get("format_version", 1) > FORMAT_VERSION:
        raise ValueError("model produced by a newer exporter")
    for key in ("nodes", "inputs", "outputs"):
        if not isinstance(model.get(key), list):
            raise GraphValidationError("malformed model container",
                                       detail=f"missing/non-list {key!r}")
    try:
        nodes = [
            Node(
                op_type=n["op_type"],
                name=n.get("name", f'{n["op_type"]}_{i}'),
                inputs=list(n["inputs"]),
                outputs=list(n["outputs"]),
                attrs=dict(n.get("attrs", {})),
            )
            for i, n in enumerate(model["nodes"])
        ]
        inputs = [
            TensorInfo(t["name"], tuple(t["shape"]), t.get("dtype", "float32"))
            for t in model["inputs"]
        ]
    except (KeyError, TypeError) as e:
        raise GraphValidationError("malformed model container",
                                   detail=repr(e)) from e
    validate_initializers(initializers)
    try:
        return Graph(
            name=model.get("name", "model"),
            nodes=nodes,
            inputs=inputs,
            outputs=list(model["outputs"]),
            initializers=initializers,
        )
    except GraphValidationError:
        raise
    except GraphError as e:
        # structural problems in an *imported* model are ingress failures
        raise GraphValidationError("invalid graph structure",
                                   detail=str(e)) from e


def save(graph: Graph, path: str) -> None:
    """Write ``<path>.json`` + ``<path>.npz``."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path + ".json", "w") as f:
        json.dump(to_model_dict(graph), f, indent=1)
    np.savez(path + ".npz", **graph.initializers)


def load(path: str) -> Graph:
    with open(path + ".json") as f:
        model = json.load(f)
    inits: Dict[str, np.ndarray] = {}
    npz_path = path + ".npz"
    if os.path.exists(npz_path):
        with np.load(npz_path) as z:
            inits = {k: z[k] for k in z.files}
    return from_model_dict(model, inits)


def _jsonify_attrs(attrs: Dict[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for k, v in attrs.items():
        if isinstance(v, np.ndarray):
            out[k] = v.tolist()
        elif isinstance(v, (np.integer,)):
            out[k] = int(v)
        elif isinstance(v, (np.floating,)):
            out[k] = float(v)
        elif isinstance(v, tuple):
            out[k] = list(v)
        else:
            out[k] = v
    return out
