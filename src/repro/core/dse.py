"""Hardware-aware design-space exploration (§4.3/§4.4 of the paper).

Two fitters over a generic ``DesignSpace``:

  * ``brute_force`` (BF-DSE, §4.3.1) — exhaustively evaluates every
    feasible option, keeps the one maximizing resource utilization
    below the user thresholds (utilization ∝ throughput for the
    pipelined architecture).
  * ``rl_dse`` (RL-DSE, §4.4) — a time-limited tabular Q-learning agent.
    Actions (the paper's): 1) increase N_l, 2) increase N_i,
    3) increase both; a variable that passes its maximum wraps back to
    its minimum.  Reward shaping is Algorithm 1 verbatim: -1 when any
    quota exceeds its threshold, β·F_avg when a new best utilization is
    observed (β = 0.01 scales percent → [0, 1]), else 0.  Discount
    γ = 0.1, episodes are step-limited (time-limited RL [34]).

Both fitters share a memoised ``evaluate`` — in the real system each
evaluation is a multi-second vendor-compiler call, so the number of
*unique* evaluations is the cost that RL-DSE reduces (Table 2: 2.5 min
vs 3.5 min ≈ 25 % faster).  We report wall time and unique-eval counts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .resources import ResourceReport

BETA = 0.01     # reward scale (percent -> [0, 1]), §4.4
GAMMA = 0.1     # discount factor, §4.4

Thresholds = Dict[str, float]
DEFAULT_THRESHOLDS: Thresholds = {"lut": 100.0, "dsp": 100.0,
                                  "mem": 100.0, "reg": 100.0}


class DesignSpace:
    """An enumerable option space + a compiler-feedback oracle.

    Concrete spaces: ``repro.core.spaces.CNNDesignSpace`` ((N_i, N_l)
    pairs under the divisibility constraints of §4.2) and
    ``repro.core.spaces.ShardingSpace`` (pod-scale parallelism options
    scored by the real XLA compiler — the paper's fitter lifted to TPU).
    """

    def options(self) -> List[Tuple]:
        raise NotImplementedError

    def evaluate(self, option: Tuple) -> ResourceReport:
        raise NotImplementedError

    # Axes for the RL agent's increase/wrap actions: list of sorted
    # per-dimension value lists; an option is a tuple indexed alike.
    def axes(self) -> List[List]:
        raise NotImplementedError

    def axis_names(self) -> List[str]:
        """Human-readable names for the option tuple's positions
        (reports, CLI output)."""
        return [f"axis{i}" for i in range(len(self.axes()))]

    def tiebreak(self, option: Tuple) -> float:
        """Secondary score among options with equal F_avg.  The CNN space
        prefers *balanced* (N_i, N_l): the memory-read kernel's delivery
        rate scales with N_i while lane consumption scales with N_l, so
        among equal-resource options the balanced pair minimises pipe
        stalls (this is why the paper's 5CSEMA5 result is (8, 8) rather
        than an equal-product skewed pair)."""
        return 0.0


@dataclasses.dataclass
class DSEResult:
    best: Optional[Tuple]
    best_report: Optional[ResourceReport]
    f_max: float
    evaluations: int           # unique compiler calls
    steps: int                 # agent steps (RL) or options scanned (BF)
    wall_time_s: float
    history: List[Tuple]       # (option, f_avg, fits) per unique eval

    @property
    def found(self) -> bool:
        return self.best is not None


class _Memo:
    """Memoised oracle — models 'one vendor-compiler call per option'."""

    def __init__(self, space: DesignSpace, eval_cost_s: float = 0.0):
        self.space = space
        self.cache: Dict[Tuple, ResourceReport] = {}
        self.eval_cost_s = eval_cost_s
        self.simulated_time = 0.0

    def __call__(self, option: Tuple) -> ResourceReport:
        if option not in self.cache:
            self.cache[option] = self.space.evaluate(option)
            self.simulated_time += self.eval_cost_s
        return self.cache[option]


def _within(report: ResourceReport, th: Thresholds) -> bool:
    return all(report.percents[k] <= th.get(k, 100.0) for k in report.percents)


def brute_force(space: DesignSpace,
                thresholds: Optional[Thresholds] = None,
                eval_cost_s: float = 0.0) -> DSEResult:
    """BF-DSE: scan every option; keep the first strict-max F_avg."""
    th = thresholds or DEFAULT_THRESHOLDS
    memo = _Memo(space, eval_cost_s)
    t0 = time.perf_counter()
    best, best_rep = None, None
    best_key = (-1.0, float("-inf"))
    history: List[Tuple] = []
    opts = space.options()
    for opt in opts:
        rep = memo(opt)
        ok = _within(rep, th)
        history.append((opt, rep.f_avg, ok))
        key = (rep.f_avg, space.tiebreak(opt))
        if ok and key > best_key:
            best_key, best, best_rep = key, opt, rep
    wall = time.perf_counter() - t0 + memo.simulated_time
    return DSEResult(best, best_rep, best_key[0], len(memo.cache), len(opts),
                     wall, history)


def rl_dse(space: DesignSpace,
           thresholds: Optional[Thresholds] = None,
           episodes: int = 12,
           steps_per_episode: int = 24,
           epsilon: float = 0.25,
           alpha: float = 0.5,
           seed: int = 0,
           patience: int = 3,
           eval_cost_s: float = 0.0) -> DSEResult:
    """RL-DSE: Q-learning over (axis-index) states with the paper's
    increase/wrap action set and Algorithm-1 reward shaping.  Episodes
    stop early once ``patience`` consecutive episodes bring no new
    H_best — this is where the paper's ~25 % wall-time saving over
    BF-DSE comes from (fewer unique vendor-compiler calls)."""
    th = thresholds or DEFAULT_THRESHOLDS
    axes = space.axes()
    dims = [len(a) for a in axes]
    n_actions = 3  # ++axis0 | ++axis1 | ++both   (paper's action set)
    if len(axes) != 2:
        # generalised: ++axis_i for each axis, plus ++all (e.g. the CNN
        # space's third block_h row-band axis, DESIGN.md §4)
        n_actions = len(axes) + 1
    q = np.zeros(dims + [n_actions], np.float64)
    rng = np.random.default_rng(seed)
    memo = _Memo(space, eval_cost_s)
    valid = set(space.options())

    t0 = time.perf_counter()
    best_key = (-1.0, float("-inf"))
    best: Optional[Tuple] = None
    best_rep: Optional[ResourceReport] = None
    history: List[Tuple] = []
    steps = 0
    stale_episodes = 0

    def step_state(state: Tuple[int, ...], action: int) -> Tuple[int, ...]:
        s = list(state)
        if action < len(axes):
            targets = [action]
        else:
            targets = list(range(len(axes)))
        for t in targets:
            s[t] += 1
            if s[t] >= dims[t]:
                s[t] = 0  # paper: reset to initial value on overflow
        return tuple(s)

    for _ep in range(episodes):
        state = tuple(0 for _ in axes)  # start from minimum values (§4.4)
        improved = False
        for _t in range(steps_per_episode):  # time-limited episode [34]
            steps += 1
            if rng.random() < epsilon:
                action = int(rng.integers(n_actions))
            else:
                action = int(np.argmax(q[state]))
            nxt = step_state(state, action)
            option = tuple(axes[i][nxt[i]] for i in range(len(axes)))
            if option in valid:
                rep = memo(option)
                ok = _within(rep, th)
                key = (rep.f_avg, space.tiebreak(option))
                # ---- Algorithm 1: reward shaping -------------------
                if ok:
                    if key > best_key:
                        best_key = key
                        reward = BETA * rep.f_avg
                        best, best_rep = option, rep
                        improved = True
                    else:
                        reward = 0.0
                else:
                    reward = -1.0
                history.append((option, rep.f_avg, ok))
            else:
                reward = -1.0  # infeasible (divisibility) — treated as over-threshold
            q[state][action] += alpha * (
                reward + GAMMA * float(np.max(q[nxt])) - q[state][action])
            state = nxt
        stale_episodes = 0 if improved else stale_episodes + 1
        if stale_episodes >= patience:
            break  # converged: no new H_best for `patience` episodes
    wall = time.perf_counter() - t0 + memo.simulated_time
    return DSEResult(best, best_rep, best_key[0], len(memo.cache), steps,
                     wall, history)
