"""Hardware-aware design-space exploration (§4.3/§4.4 of the paper).

Two fitters over a generic ``DesignSpace``:

  * ``brute_force`` (BF-DSE, §4.3.1) — exhaustively evaluates every
    feasible option, keeps the one maximizing resource utilization
    below the user thresholds (utilization ∝ throughput for the
    pipelined architecture).
  * ``rl_dse`` (RL-DSE, §4.4) — a time-limited tabular Q-learning agent.
    Actions (the paper's): 1) increase N_l, 2) increase N_i,
    3) increase both; a variable that passes its maximum wraps back to
    its minimum.  Reward shaping is Algorithm 1 verbatim: -1 when any
    quota exceeds its threshold, β·F_avg when a new best utilization is
    observed (β = 0.01 scales percent → [0, 1]), else 0.  Discount
    γ = 0.1, episodes are step-limited (time-limited RL [34]).

Both fitters share a memoised ``evaluate`` — in the real system each
evaluation is a multi-second vendor-compiler call, so the number of
*unique* evaluations is the cost that RL-DSE reduces (Table 2: 2.5 min
vs 3.5 min ≈ 25 % faster).  We report wall time and unique-eval counts.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import telemetry as tele
from .resources import ResourceReport
from .verify import VerificationError

BETA = 0.01     # reward scale (percent -> [0, 1]), §4.4
GAMMA = 0.1     # discount factor, §4.4

#: Quota charged to a quarantined/failed candidate: far over every
#: threshold, so both fitters treat it exactly like an over-quota
#: compile (BF skips it, RL rewards -1) instead of dying on it.
FAILED_PCT = 1e9

Thresholds = Dict[str, float]
DEFAULT_THRESHOLDS: Thresholds = {"lut": 100.0, "dsp": 100.0,
                                  "mem": 100.0, "reg": 100.0}


class DesignSpace:
    """An enumerable option space + a compiler-feedback oracle.

    Concrete spaces: ``repro.core.spaces.CNNDesignSpace`` ((N_i, N_l)
    pairs under the divisibility constraints of §4.2) and
    ``repro.core.spaces.ShardingSpace`` (pod-scale parallelism options
    scored by the real XLA compiler — the paper's fitter lifted to TPU).
    """

    def options(self) -> List[Tuple]:
        raise NotImplementedError

    def evaluate(self, option: Tuple) -> ResourceReport:
        raise NotImplementedError

    # Axes for the RL agent's increase/wrap actions: list of sorted
    # per-dimension value lists; an option is a tuple indexed alike.
    def axes(self) -> List[List]:
        raise NotImplementedError

    def axis_names(self) -> List[str]:
        """Human-readable names for the option tuple's positions
        (reports, CLI output)."""
        return [f"axis{i}" for i in range(len(self.axes()))]

    def tiebreak(self, option: Tuple) -> float:
        """Secondary score among options with equal F_avg.  The CNN space
        prefers *balanced* (N_i, N_l): the memory-read kernel's delivery
        rate scales with N_i while lane consumption scales with N_l, so
        among equal-resource options the balanced pair minimises pipe
        stalls (this is why the paper's 5CSEMA5 result is (8, 8) rather
        than an equal-product skewed pair)."""
        return 0.0


@dataclasses.dataclass
class DSEResult:
    best: Optional[Tuple]
    best_report: Optional[ResourceReport]
    f_max: float
    evaluations: int           # unique compiler calls
    steps: int                 # agent steps (RL) or options scanned (BF)
    wall_time_s: float
    history: List[Tuple]       # (option, f_avg, fits) per unique eval

    @property
    def found(self) -> bool:
        return self.best is not None


class _Memo:
    """Memoised oracle — models 'one vendor-compiler call per option'."""

    def __init__(self, space: DesignSpace, eval_cost_s: float = 0.0):
        self.space = space
        self.cache: Dict[Tuple, ResourceReport] = {}
        self.eval_cost_s = eval_cost_s
        self.simulated_time = 0.0

    def __call__(self, option: Tuple) -> ResourceReport:
        if option not in self.cache:
            self.cache[option] = self.space.evaluate(option)
            self.simulated_time += self.eval_cost_s
        return self.cache[option]


class EvalTimeout(RuntimeError):
    """A candidate evaluation exceeded its wall-clock budget."""


class RobustEvaluator(DesignSpace):
    """Fault-tolerant wrapper around a ``DesignSpace`` oracle.

    Real vendor-compiler calls hang, crash, and flake; a multi-hour
    sweep must survive all three and be resumable.  This wrapper adds:

      * **per-candidate timeout** — the underlying ``evaluate`` runs on
        a daemon thread and is abandoned after ``timeout_s`` (a hung
        compiler call cannot stall the sweep; the orphaned thread dies
        with the process).  Timeouts are not retried: a hang is almost
        never transient and each retry would cost another full budget.
      * **retry with exponential backoff + jitter** — a raising
        evaluation is retried up to ``retries`` times, sleeping
        ``backoff_s * 2^k * (1 + jitter)`` between attempts
        (deterministic jitter from ``seed``).
      * **quarantine** — a candidate that exhausts its retries (or
        times out) is recorded with its failure reason and charged a
        :data:`FAILED_PCT` report (``fits=False``, every quota far over
        threshold), so BF-DSE skips it and RL-DSE rewards it -1; the
        search itself never sees the exception.
      * **resumable journal** — every completed report and quarantine
        decision is appended to ``journal_path`` as one JSON line
        (schema v2: a ``{"journal": ..., "version": 2}`` header line,
        then one record per line).  A fresh evaluator pointed at the
        same journal replays those results without touching the
        underlying space — kill the sweep, rerun the command, and only
        the remaining candidates compile.  Append-per-record means a
        crash mid-write can only tear the LAST line: on load, a
        corrupt/truncated journal is detected, backed up aside
        (``<path>.corrupt``), and the sweep resumes from the valid
        prefix instead of crashing — ``stats["journal_dropped"]``
        counts the discarded lines.  Legacy v1 journals (one monolithic
        JSON object) are migrated in place on first load.

    ``stats`` counts evaluated / journal_hits / retries / errors /
    timeouts / quarantined / journal_dropped for reporting.  Every
    count is mirrored into the telemetry registry (``dse.evaluated``,
    ``dse.quarantined``, ... — DESIGN.md §12) and each underlying
    ``evaluate`` runs inside a ``dse.evaluate`` span carrying the
    option, so a ``--robust`` sweep's retry/timeout/quarantine totals
    show up in any profile snapshot without parsing the autotune
    payload.
    """

    QUOTAS = ("lut", "dsp", "mem", "reg")
    JOURNAL_VERSION = 2

    def __init__(self, space: DesignSpace,
                 timeout_s: Optional[float] = None,
                 retries: int = 2,
                 backoff_s: float = 0.05,
                 journal_path: Optional[str] = None,
                 seed: int = 0,
                 registry: Optional[tele.MetricsRegistry] = None,
                 tracer: Optional[tele.Tracer] = None):
        self.space = space
        self.timeout_s = timeout_s
        self.retries = max(0, retries)
        self.backoff_s = backoff_s
        self.journal_path = journal_path
        self._rng = np.random.default_rng(seed)
        self._registry = registry if registry is not None \
            else tele.get_registry()
        self._tracer = tracer if tracer is not None else tele.get_tracer()
        self.completed: Dict[str, dict] = {}
        self.quarantined: Dict[str, str] = {}
        self.stats = {"evaluated": 0, "journal_hits": 0, "retries": 0,
                      "errors": 0, "timeouts": 0, "quarantined": 0,
                      "verifier_rejects": 0, "journal_dropped": 0}
        if journal_path and os.path.exists(journal_path):
            self._load_journal()

    def _count(self, key: str, n: int = 1) -> None:
        """One robustness event: the local stats dict AND the registry
        counter move together, so the autotune payload and any profile
        snapshot agree."""
        self.stats[key] += n
        self._registry.counter(f"dse.{key}").inc(n)

    # ------------------------------------------------ space delegation
    def options(self) -> List[Tuple]:
        return self.space.options()

    def axes(self) -> List[List]:
        return self.space.axes()

    def axis_names(self) -> List[str]:
        return self.space.axis_names()

    def tiebreak(self, option: Tuple) -> float:
        return self.space.tiebreak(option)

    # ---------------------------------------------------------- oracle
    @staticmethod
    def _key(option: Tuple) -> str:
        return json.dumps(list(option), default=str)

    def _failed(self) -> ResourceReport:
        return ResourceReport(percents={k: FAILED_PCT for k in self.QUOTAS},
                              raw={}, fits=False)

    def _attempt(self, option: Tuple) -> ResourceReport:
        if self.timeout_s is None:
            return self.space.evaluate(option)
        box: dict = {}

        def run():
            try:
                box["report"] = self.space.evaluate(option)
            except BaseException as e:  # surfaced on the caller thread
                box["error"] = e

        t = threading.Thread(target=run, daemon=True,
                             name=f"dse-eval-{self._key(option)}")
        t.start()
        t.join(self.timeout_s)
        if t.is_alive():
            raise EvalTimeout(f"evaluation of {option} exceeded "
                              f"{self.timeout_s}s")
        if "error" in box:
            raise box["error"]
        return box["report"]

    def evaluate(self, option: Tuple) -> ResourceReport:
        key = self._key(option)
        if key in self.completed:
            self._count("journal_hits")
            rec = self.completed[key]
            return ResourceReport(percents=dict(rec["percents"]),
                                  raw=dict(rec["raw"]),
                                  fits=bool(rec["fits"]))
        if key in self.quarantined:
            self._count("journal_hits")
            return self._failed()
        last: Optional[BaseException] = None
        for attempt in range(self.retries + 1):
            if attempt:
                self._count("retries")
                jitter = 1.0 + float(self._rng.random())
                time.sleep(self.backoff_s * (2 ** (attempt - 1)) * jitter)
            try:
                with self._tracer.span("dse.evaluate", cat="dse",
                                       args={"option": key,
                                             "attempt": attempt}):
                    rep = self._attempt(option)
            except EvalTimeout as e:
                self._count("timeouts")
                last = e
                break  # hangs are not retried — see class docstring
            except VerificationError as e:
                # static DRC failure: deterministic, retrying re-proves
                # the same theorem — quarantine immediately
                self._count("verifier_rejects")
                last = e
                break
            except Exception as e:
                self._count("errors")
                last = e
                continue
            self._count("evaluated")
            rec = {"percents": rep.percents, "raw": rep.raw,
                   "fits": rep.fits}
            self.completed[key] = rec
            self._append({"kind": "completed", "key": key, "record": rec})
            return rep
        why = f"{type(last).__name__}: {last}"
        self.quarantined[key] = why
        self._count("quarantined")
        self._append({"kind": "quarantined", "key": key, "why": why})
        return self._failed()

    def quarantined_options(self) -> List[Tuple[List, str]]:
        """Quarantine list with the option decoded back from its key."""
        return [(json.loads(k), why) for k, why in self.quarantined.items()]

    # ---------------------------------------------------- journal (v2)
    def _load_journal(self) -> None:
        """Load ``journal_path``: v2 JSONL, legacy v1 monolithic JSON
        (migrated in place), or a corrupt/truncated file of either —
        detected, backed up to ``<path>.corrupt`` and resumed from the
        longest valid prefix."""
        with open(self.journal_path) as f:
            text = f.read()
        lines = text.splitlines()
        dropped = 0
        if len(lines) == 1 or (lines and not lines[0].lstrip()
                               .startswith('{"journal"')):
            # legacy v1: the whole file is one JSON object (possibly
            # pretty-printed across lines).  A truncated v1 journal
            # fails to parse and is discarded wholesale — v1 had no
            # record boundaries to salvage a prefix from.
            try:
                state = json.loads(text)
                self.completed = dict(state.get("completed", {}))
                self.quarantined = dict(state.get("quarantined", {}))
            except (json.JSONDecodeError, AttributeError):
                dropped = max(1, len(lines))
        else:
            for n, line in enumerate(lines):
                if not line.strip():
                    continue
                try:
                    rec = json.loads(line)
                    if rec.get("journal"):  # header line
                        continue
                    if rec["kind"] == "completed":
                        self.completed[rec["key"]] = rec["record"]
                    elif rec["kind"] == "quarantined":
                        self.quarantined[rec["key"]] = rec["why"]
                    else:
                        raise KeyError(rec["kind"])
                except (json.JSONDecodeError, KeyError, TypeError):
                    # torn tail (or mid-file corruption): keep the
                    # valid prefix, drop this line and everything after
                    # it — later lines may depend on sync we can no
                    # longer trust
                    dropped = len(lines) - n
                    break
        if dropped:
            os.replace(self.journal_path, self.journal_path + ".corrupt")
            self._count("journal_dropped", dropped)
        # persist migration/recovery so the next crash tears v2 lines,
        # not a half-migrated hybrid
        self._rewrite_journal()

    def _journal_header(self) -> str:
        return json.dumps({"journal": "dse-robust-evaluator",
                           "version": self.JOURNAL_VERSION})

    def _rewrite_journal(self) -> None:
        if not self.journal_path:
            return
        d = os.path.dirname(self.journal_path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self.journal_path + ".tmp"
        with open(tmp, "w") as f:
            f.write(self._journal_header() + "\n")
            for key, rec in self.completed.items():
                f.write(json.dumps({"kind": "completed", "key": key,
                                    "record": rec}, default=str) + "\n")
            for key, why in self.quarantined.items():
                f.write(json.dumps({"kind": "quarantined", "key": key,
                                    "why": why}, default=str) + "\n")
        os.replace(tmp, self.journal_path)

    def _append(self, entry: dict) -> None:
        """One record, one line, one append: a crash can only tear the
        final line, which ``_load_journal`` recovers from."""
        if not self.journal_path:
            return
        d = os.path.dirname(self.journal_path)
        if d:
            os.makedirs(d, exist_ok=True)
        fresh = not os.path.exists(self.journal_path)
        with open(self.journal_path, "a") as f:
            if fresh:
                f.write(self._journal_header() + "\n")
            f.write(json.dumps(entry, default=str) + "\n")


def _within(report: ResourceReport, th: Thresholds) -> bool:
    return all(report.percents[k] <= th.get(k, 100.0) for k in report.percents)


def brute_force(space: DesignSpace,
                thresholds: Optional[Thresholds] = None,
                eval_cost_s: float = 0.0) -> DSEResult:
    """BF-DSE: scan every option; keep the first strict-max F_avg."""
    th = thresholds or DEFAULT_THRESHOLDS
    memo = _Memo(space, eval_cost_s)
    t0 = time.perf_counter()
    best, best_rep = None, None
    best_key = (-1.0, float("-inf"))
    history: List[Tuple] = []
    opts = space.options()
    for opt in opts:
        rep = memo(opt)
        ok = _within(rep, th)
        history.append((opt, rep.f_avg, ok))
        key = (rep.f_avg, space.tiebreak(opt))
        if ok and key > best_key:
            best_key, best, best_rep = key, opt, rep
    wall = time.perf_counter() - t0 + memo.simulated_time
    return DSEResult(best, best_rep, best_key[0], len(memo.cache), len(opts),
                     wall, history)


def rl_dse(space: DesignSpace,
           thresholds: Optional[Thresholds] = None,
           episodes: int = 12,
           steps_per_episode: int = 24,
           epsilon: float = 0.25,
           alpha: float = 0.5,
           seed: int = 0,
           patience: int = 3,
           eval_cost_s: float = 0.0) -> DSEResult:
    """RL-DSE: Q-learning over (axis-index) states with the paper's
    increase/wrap action set and Algorithm-1 reward shaping.  Episodes
    stop early once ``patience`` consecutive episodes bring no new
    H_best — this is where the paper's ~25 % wall-time saving over
    BF-DSE comes from (fewer unique vendor-compiler calls)."""
    th = thresholds or DEFAULT_THRESHOLDS
    axes = space.axes()
    dims = [len(a) for a in axes]
    n_actions = 3  # ++axis0 | ++axis1 | ++both   (paper's action set)
    if len(axes) != 2:
        # generalised: ++axis_i for each axis, plus ++all (e.g. the CNN
        # space's third block_h row-band axis, DESIGN.md §4)
        n_actions = len(axes) + 1
    q = np.zeros(dims + [n_actions], np.float64)
    rng = np.random.default_rng(seed)
    memo = _Memo(space, eval_cost_s)
    valid = set(space.options())

    t0 = time.perf_counter()
    best_key = (-1.0, float("-inf"))
    best: Optional[Tuple] = None
    best_rep: Optional[ResourceReport] = None
    history: List[Tuple] = []
    steps = 0
    stale_episodes = 0

    def step_state(state: Tuple[int, ...], action: int) -> Tuple[int, ...]:
        s = list(state)
        if action < len(axes):
            targets = [action]
        else:
            targets = list(range(len(axes)))
        for t in targets:
            s[t] += 1
            if s[t] >= dims[t]:
                s[t] = 0  # paper: reset to initial value on overflow
        return tuple(s)

    for _ep in range(episodes):
        state = tuple(0 for _ in axes)  # start from minimum values (§4.4)
        improved = False
        for _t in range(steps_per_episode):  # time-limited episode [34]
            steps += 1
            if rng.random() < epsilon:
                action = int(rng.integers(n_actions))
            else:
                action = int(np.argmax(q[state]))
            nxt = step_state(state, action)
            option = tuple(axes[i][nxt[i]] for i in range(len(axes)))
            if option in valid:
                rep = memo(option)
                ok = _within(rep, th)
                key = (rep.f_avg, space.tiebreak(option))
                # ---- Algorithm 1: reward shaping -------------------
                if ok:
                    if key > best_key:
                        best_key = key
                        reward = BETA * rep.f_avg
                        best, best_rep = option, rep
                        improved = True
                    else:
                        reward = 0.0
                else:
                    reward = -1.0
                history.append((option, rep.f_avg, ok))
            else:
                reward = -1.0  # infeasible (divisibility) — treated as over-threshold
            q[state][action] += alpha * (
                reward + GAMMA * float(np.max(q[nxt])) - q[state][action])
            state = nxt
        stale_episodes = 0 if improved else stale_episodes + 1
        if stale_episodes >= patience:
            break  # converged: no new H_best for `patience` episodes
    wall = time.perf_counter() - t0 + memo.simulated_time
    return DSEResult(best, best_rep, best_key[0], len(memo.cache), steps,
                     wall, history)
