"""(N, m) fixed-point post-training quantization application (§4.2).

The paper's "Physical domain" step: CNN2Gate *does not invent* a
quantization — it applies a user-given per-layer ``(N, m)`` pair where a
fixed-point value is represented as ``N × 2^-m`` with 8-bit arithmetic
units.  This module implements:

  * ``QuantSpec`` — the per-layer (m_w, m_x, m_y) exponents (weights,
    input activations, output activations).  All scales are powers of
    two, matching the paper's shift-based arithmetic.  ``m_w`` may be a
    **per-output-channel vector** (a tuple, one exponent per Cout lane)
    — the standard accuracy-recovery move of the FPGA-inference
    literature the paper builds on (per-channel weight scaling keeps
    the shift-only datapath: the requant shift simply becomes a
    per-lane shift vector).  Activations stay per-tensor either way,
    so merge (Add/Concat) alignment is untouched.
  * ``quantize_weights`` — float weights/biases → int8 N with the given
    m (biases are int32 at scale 2^-(m_w+m_x) so they add directly into
    the int32 accumulator; with per-channel m_w each bias lane uses its
    own channel's accumulator scale).
  * ``best_pow2_exponent`` / ``best_pow2_exponents_per_channel`` — the
    max-abs power-of-two PTQ rule the DAG-aware calibrator
    (synthesis.calibrate_quantization) applies per named tensor (and,
    in per-channel mode, per output channel of each weight), standing
    in for the external tool the paper assumes the user ran.
  * ``requant_shift`` — the right-shift that maps int32 accumulators back
    to int8 outputs: shift = m_w + m_x - m_y (per-lane when m_w is a
    vector).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import numpy as np

INT8_MIN, INT8_MAX = -128, 127

#: Widest per-lane requant shift the int32 datapath supports: the
#: round-half-up bias ``1 << (s-1)`` must stay an int32 constant.
MAX_SHIFT = 30


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Per-layer fixed-point format: value = N * 2^-m.

    ``m_w`` is an int (per-tensor weight scale) or a tuple of ints
    (per-output-channel scales, one per Cout lane).  ``m_x``/``m_y``
    are always per-tensor: activations keep one position so the
    shift-only merge alignment of residual/concat stages is unchanged.
    """

    m_w: Union[int, Tuple[int, ...]]  # weight fraction bits (scalar | per-Cout)
    m_x: int  # input-activation fraction bits
    m_y: int  # output-activation fraction bits

    @property
    def per_channel(self) -> bool:
        return isinstance(self.m_w, tuple)

    @property
    def m_w_min(self) -> int:
        """Smallest weight exponent across lanes (the lane that caps
        ``m_y``: every per-lane shift must stay non-negative)."""
        return min(self.m_w) if self.per_channel else self.m_w

    @property
    def requant_shift(self) -> Union[int, Tuple[int, ...]]:
        """int32 accumulator (scale 2^-(m_w+m_x)) -> int8 out (scale
        2^-m_y).  A per-channel spec yields a per-lane shift vector."""
        shifts = shift_lanes(self)
        if self.per_channel:
            if any(s < 0 for s in shifts):
                raise ValueError(f"negative per-lane requant shift for {self}")
            if any(s > MAX_SHIFT for s in shifts):
                raise ValueError(
                    f"per-lane requant shift exceeds {MAX_SHIFT} for {self}")
            return shifts
        (s,) = shifts
        if s < 0:
            raise ValueError(f"negative requant shift for {self}")
        return s


def shift_lanes(spec: "QuantSpec") -> Tuple[int, ...]:
    """Per-lane requant shifts of a spec with NO range enforcement —
    the static verifier's view (it *reports* out-of-range shifts as
    diagnostics instead of raising mid-analysis).  Always a tuple; a
    per-tensor spec yields one lane."""
    if spec.per_channel:
        return tuple(mw + spec.m_x - spec.m_y for mw in spec.m_w)
    return (spec.m_w + spec.m_x - spec.m_y,)


@dataclasses.dataclass
class QuantizedTensor:
    """int8 payload + its fixed-point exponent m (value = q * 2^-m)."""

    q: np.ndarray
    m: int

    def dequantize(self) -> np.ndarray:
        return self.q.astype(np.float32) * (2.0 ** -self.m)


def quantize_array(x: np.ndarray, m, bits: int = 8) -> np.ndarray:
    """Round-to-nearest fixed-point quantization to ``bits`` at scale
    2^-m.  ``m`` may be an int or an array broadcastable against ``x``
    (per-channel quantization pre-shapes it along the channel axis)."""
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    scale = np.power(2.0, np.asarray(m, np.float64))
    q = np.clip(np.rint(np.asarray(x, np.float64) * scale), lo, hi)
    dtype = np.int8 if bits <= 8 else np.int32
    return q.astype(dtype)


def dequantize_array(q: np.ndarray, m: int) -> np.ndarray:
    return q.astype(np.float32) * (2.0 ** -m)


def _mw_broadcast(w: np.ndarray, m_w: Tuple[int, ...]) -> np.ndarray:
    """Shape a per-Cout exponent vector for broadcasting against ``w``:
    OIHW conv weights carry Cout on axis 0, (K, N) FC weights on the
    last axis."""
    mv = np.asarray(m_w, np.int64)
    if w.ndim == 4:  # OIHW (exporter layout — staging to HWIO happens later)
        if mv.shape[0] != w.shape[0]:
            raise ValueError(
                f"per-channel m_w has {mv.shape[0]} lanes for OIHW weight "
                f"with Cout={w.shape[0]}")
        return mv.reshape(-1, 1, 1, 1)
    if mv.shape[0] != w.shape[-1]:
        raise ValueError(
            f"per-channel m_w has {mv.shape[0]} lanes for weight with "
            f"{w.shape[-1]} output features")
    return mv.reshape((1,) * (w.ndim - 1) + (-1,))


def quantize_weights(
    w: np.ndarray, b: Optional[np.ndarray], spec: QuantSpec
) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Apply the given (N, m) format: int8 weights, int32 biases at the
    accumulator scale (so bias adds need no extra shift).  With a
    per-channel spec every output channel quantizes at its own
    ``m_w[c]`` and its bias at ``m_w[c] + m_x``."""
    if spec.per_channel:
        mw = _mw_broadcast(w, spec.m_w)
        wq = quantize_array(w, mw, bits=8)
        bq = None
        if b is not None:
            bq = quantize_array(
                b, np.asarray(spec.m_w, np.int64) + spec.m_x, bits=32)
        return wq, bq
    wq = quantize_array(w, spec.m_w, bits=8)
    bq = None
    if b is not None:
        bq = quantize_array(b, spec.m_w + spec.m_x, bits=32)
    return wq, bq


def requantize(acc: np.ndarray, spec: QuantSpec, relu: bool = False) -> np.ndarray:
    """int32 accumulator -> int8 output via arithmetic right shift with
    round-to-nearest (add half before shifting), optional fused ReLU.
    A per-channel spec shifts each output-channel lane (the last axis
    of ``acc``) by its own count."""
    s = spec.requant_shift
    acc = np.asarray(acc, np.int64)
    if isinstance(s, tuple):
        sv = np.asarray(s, np.int64)
        half = np.where(sv > 0, np.left_shift(1, np.maximum(sv - 1, 0)), 0)
        acc = np.right_shift(acc + half, sv)
    elif s > 0:
        acc = (acc + (1 << (s - 1))) >> s
    if relu:
        acc = np.maximum(acc, 0)
    return np.clip(acc, INT8_MIN, INT8_MAX).astype(np.int8)


def best_pow2_exponent(x: np.ndarray, bits: int = 8) -> int:
    """Largest m such that max|x| * 2^m still fits in ``bits`` signed —
    the standard max-abs power-of-two PTQ rule."""
    amax = float(np.max(np.abs(x))) if x.size else 0.0
    if amax == 0.0:
        return bits - 1
    hi = 2 ** (bits - 1) - 1
    m = int(np.floor(np.log2(hi / amax)))
    return max(-(bits - 1), min(m, 24))


def best_pow2_exponents_per_channel(w: np.ndarray,
                                    bits: int = 8) -> Tuple[int, ...]:
    """Per-output-channel max-abs exponents for a weight tensor (OIHW
    conv: Cout on axis 0; (K, N) FC: output features on the last axis).

    The spread over the per-tensor exponent is clamped to keep every
    per-lane requant shift (``m_w[c] + m_x - m_y``) inside the int32
    round-half-up datapath (``MAX_SHIFT``): a near-dead channel would
    otherwise push its exponent to the PTQ cap and its shift past the
    representable range — those lanes gain nothing past the clamp (the
    shifted-away bits are already below one output LSB)."""
    caxis = 0 if w.ndim == 4 else w.ndim - 1
    per = [best_pow2_exponent(np.take(w, c, axis=caxis), bits)
           for c in range(w.shape[caxis])]
    lo = min(per)
    return tuple(min(m, lo + 15) for m in per)


def quantization_error(x: np.ndarray, m: int, bits: int = 8) -> float:
    """RMS relative error of round-tripping x through (N, m)."""
    q = quantize_array(x, m, bits)
    xd = dequantize_array(q, m)
    denom = float(np.sqrt(np.mean(x.astype(np.float64) ** 2))) or 1.0
    return float(np.sqrt(np.mean((xd - x) ** 2))) / denom
