"""(N, m) fixed-point post-training quantization application (§4.2).

The paper's "Physical domain" step: CNN2Gate *does not invent* a
quantization — it applies a user-given per-layer ``(N, m)`` pair where a
fixed-point value is represented as ``N × 2^-m`` with 8-bit arithmetic
units.  This module implements:

  * ``QuantSpec`` — the per-layer (m_w, m_x, m_y) exponents (weights,
    input activations, output activations).  All scales are powers of
    two, matching the paper's shift-based arithmetic.
  * ``quantize_weights`` — float weights/biases → int8 N with the given
    m (biases are int32 at scale 2^-(m_w+m_x) so they add directly into
    the int32 accumulator).
  * ``best_pow2_exponent`` — the max-abs power-of-two PTQ rule the
    DAG-aware calibrator (synthesis.calibrate_quantization) applies per
    named tensor, standing in for the external tool the paper assumes
    the user ran.
  * ``requant_shift`` — the right-shift that maps int32 accumulators back
    to int8 outputs: shift = m_w + m_x - m_y.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

INT8_MIN, INT8_MAX = -128, 127


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Per-layer fixed-point format: value = N * 2^-m."""

    m_w: int  # weight fraction bits
    m_x: int  # input-activation fraction bits
    m_y: int  # output-activation fraction bits

    @property
    def requant_shift(self) -> int:
        """int32 accumulator (scale 2^-(m_w+m_x)) -> int8 out (scale 2^-m_y)."""
        s = self.m_w + self.m_x - self.m_y
        if s < 0:
            raise ValueError(f"negative requant shift for {self}")
        return s


@dataclasses.dataclass
class QuantizedTensor:
    """int8 payload + its fixed-point exponent m (value = q * 2^-m)."""

    q: np.ndarray
    m: int

    def dequantize(self) -> np.ndarray:
        return self.q.astype(np.float32) * (2.0 ** -self.m)


def quantize_array(x: np.ndarray, m: int, bits: int = 8) -> np.ndarray:
    """Round-to-nearest fixed-point quantization to ``bits`` at scale 2^-m."""
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    q = np.clip(np.rint(np.asarray(x, np.float64) * (2.0 ** m)), lo, hi)
    dtype = np.int8 if bits <= 8 else np.int32
    return q.astype(dtype)


def dequantize_array(q: np.ndarray, m: int) -> np.ndarray:
    return q.astype(np.float32) * (2.0 ** -m)


def quantize_weights(
    w: np.ndarray, b: Optional[np.ndarray], spec: QuantSpec
) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Apply the given (N, m) format: int8 weights, int32 biases at the
    accumulator scale (so bias adds need no extra shift)."""
    wq = quantize_array(w, spec.m_w, bits=8)
    bq = None
    if b is not None:
        bq = quantize_array(b, spec.m_w + spec.m_x, bits=32)
    return wq, bq


def requantize(acc: np.ndarray, spec: QuantSpec, relu: bool = False) -> np.ndarray:
    """int32 accumulator -> int8 output via arithmetic right shift with
    round-to-nearest (add half before shifting), optional fused ReLU."""
    s = spec.requant_shift
    acc = np.asarray(acc, np.int64)
    if s > 0:
        acc = (acc + (1 << (s - 1))) >> s
    if relu:
        acc = np.maximum(acc, 0)
    return np.clip(acc, INT8_MIN, INT8_MAX).astype(np.int8)


def best_pow2_exponent(x: np.ndarray, bits: int = 8) -> int:
    """Largest m such that max|x| * 2^m still fits in ``bits`` signed —
    the standard max-abs power-of-two PTQ rule."""
    amax = float(np.max(np.abs(x))) if x.size else 0.0
    if amax == 0.0:
        return bits - 1
    hi = 2 ** (bits - 1) - 1
    m = int(np.floor(np.log2(hi / amax)))
    return max(-(bits - 1), min(m, 24))


def quantization_error(x: np.ndarray, m: int, bits: int = 8) -> float:
    """RMS relative error of round-tripping x through (N, m)."""
    q = quantize_array(x, m, bits)
    xd = dequantize_array(q, m)
    denom = float(np.sqrt(np.mean(x.astype(np.float64) ** 2))) or 1.0
    return float(np.sqrt(np.mean((xd - x) ** 2))) / denom
