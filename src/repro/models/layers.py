"""Transformer building blocks shared across the architecture fleet.

Everything is expressed as pure functions over param pytrees (dict
leaves) so stacks can be ``lax.scan``-ed over stacked per-layer params —
essential to keep HLO size O(1) in depth for the 64-layer dry-runs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops

Params = Dict[str, Any]


# ------------------------------------------------------------------ norms

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def apply_norm(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm_type == "layer":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def init_norm(cfg: ModelConfig, d: int) -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layer":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


# ------------------------------------------------------------------- rope

def rope_frequencies(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int] = (2, 3, 3)) -> jnp.ndarray:
    """Multimodal RoPE (qwen2-vl): positions (3, B, S) carry (temporal,
    height, width) ids; the D/2 frequency channels are split into three
    sections (proportions per the qwen2-vl mrope_section) and each section
    rotates by its own position component."""
    hd = x.shape[-1]
    half = hd // 2
    total = sum(sections)
    bounds = [half * sections[0] // total,
              half * (sections[0] + sections[1]) // total]
    freqs = rope_frequencies(hd, theta)                       # (D/2,)
    section_id = jnp.zeros((half,), jnp.int32)
    section_id = section_id.at[bounds[0]:bounds[1]].set(1)
    section_id = section_id.at[bounds[1]:].set(2)
    # pos-per-channel: (B, S, D/2) — each channel rotates by the position
    # component of its section
    pos = jnp.moveaxis(positions, 0, -1).astype(jnp.float32)[..., section_id]
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention

def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      *, causal: bool, window: Optional[int],
                      q_offset: int, chunk: int,
                      unroll: bool = False) -> jnp.ndarray:
    """Online-softmax attention, scanning KV chunks.  Pure JAX (lowers on
    every backend) with O(Sq * chunk) score memory — this is the impl the
    32k-prefill dry-runs use; the Pallas flash kernel is the TPU fast
    path with identical math."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = h // hkv
    scale = d ** -0.5
    nchunks = -(-skv // chunk)
    pad = nchunks * chunk - skv
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = kp.reshape(b, hkv, nchunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = vp.reshape(b, hkv, nchunks, chunk, d).transpose(2, 0, 1, 3, 4)
    qf = q.astype(jnp.float32)
    qpos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        m, l, acc = carry
        ci, kci, vci = xs
        kr = jnp.repeat(kci, g, axis=1).astype(jnp.float32)
        vr = jnp.repeat(vci, g, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kr) * scale
        kpos = ci * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < skv
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum("bhqk,bhkd->bhqd", p, vr)
        return (m_new, l, acc), None

    init = (jnp.full((b, h, sq, 1), -1e30, jnp.float32),
            jnp.zeros((b, h, sq, 1), jnp.float32),
            jnp.zeros((b, h, sq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, init, (jnp.arange(nchunks), kc, vc), unroll=unroll)
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l).astype(q.dtype)


def naive_attention(q, k, v, *, causal, window, q_offset):
    return ops.ref.attention_ref(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset)


def run_attention(cfg: ModelConfig, q, k, v, *, causal: bool = True,
                  q_offset: int = 0) -> jnp.ndarray:
    """Dispatch on cfg.attention_impl.  Shapes: q (B,H,Sq,D), kv (B,HKV,Skv,D)."""
    window = cfg.sliding_window
    if cfg.attention_impl == "flash":
        return ops.flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)
    if cfg.attention_impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, chunk=cfg.attention_chunk,
                                 unroll=cfg.scan_unroll)
    return naive_attention(q, k, v, causal=causal, window=window,
                           q_offset=q_offset)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, lengths: jnp.ndarray,
                     window: Optional[int] = None) -> jnp.ndarray:
    """Single-token decode: q (B,H,1,D) over cache (B,HKV,S,D) with valid
    ``lengths`` (B,) — one masked GQA matmul pair (memory-bound)."""
    b, h, _, d = q.shape
    hkv = k_cache.shape[1]
    g = h // hkv
    scale = d ** -0.5
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qg,
                   k_cache.astype(jnp.float32)) * scale
    kpos = jnp.arange(k_cache.shape[2])[None, :]
    mask = kpos < lengths[:, None]
    if window is not None:
        mask &= kpos > (lengths[:, None] - 1 - window)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, 1, d).astype(q.dtype)


# ------------------------------------------------------------ projections

def init_attention(cfg: ModelConfig, key: jax.Array) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    dt = jnp.dtype(cfg.dtype)
    p: Params = {
        "wq": (jax.random.normal(k1, (d, h * hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d, kv * hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d, kv * hd)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (h * hd, d)) * s).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def qkv_project(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                positions: Optional[jnp.ndarray],
                rope: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> q (B,H,S,hd), k/v (B,HKV,S,hd) with bias/qk-norm/rope."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope and cfg.pos_embedding == "rope" and positions is not None:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3))


# -------------------------------------------------------------------- mlp

def init_mlp(cfg: ModelConfig, key: jax.Array, d_ff: Optional[int] = None
             ) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type == "gated_silu":
        return {
            "w_gate": (jax.random.normal(k1, (d, f)) * d ** -0.5).astype(dt),
            "w_up": (jax.random.normal(k2, (d, f)) * d ** -0.5).astype(dt),
            "w_down": (jax.random.normal(k3, (f, d)) * f ** -0.5).astype(dt),
        }
    return {
        "w_up": (jax.random.normal(k1, (d, f)) * d ** -0.5).astype(dt),
        "b_up": jnp.zeros((f,), dt),
        "w_down": (jax.random.normal(k2, (f, d)) * f ** -0.5).astype(dt),
        "b_down": jnp.zeros((d,), dt),
    }


def mlp(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.mlp_type == "gated_silu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"] + p["b_up"]) @ p["w_down"] + p["b_down"]


# -------------------------------------------------------------------- moe

def init_moe(cfg: ModelConfig, key: jax.Array) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": (jax.random.normal(k1, (d, e)) * d ** -0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (e, d, f)) * d ** -0.5).astype(dt),
        "w_up": (jax.random.normal(k3, (e, d, f)) * d ** -0.5).astype(dt),
        "w_down": (jax.random.normal(k4, (e, f, d)) * f ** -0.5).astype(dt),
    }


def moe(cfg: ModelConfig, p: Params, x: jnp.ndarray
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-based top-k routing, einsum dispatch, *group-wise*.

    Tokens route within independent groups (one group per batch row) so
    the dispatch/combine tensors are (G, Tg, E, C) with C ∝ Tg — linear
    in total tokens, not quadratic — and the group axis shards on the
    data axes while experts shard on the model axis (expert parallel).
    FLOPs scale with active experts x capacity_factor.
    Returns (y, aux_loss)."""
    b0, s0, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tg = s0                                                  # per-group tokens
    if cfg.moe_group_size and s0 % cfg.moe_group_size == 0:
        tg = cfg.moe_group_size                              # bounded groups
    xt = x.reshape(b0 * s0 // tg, tg, d)                     # (G, Tg, D)
    b, s = xt.shape[0], tg
    logits = (xt.astype(jnp.float32) @ p["router"])          # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (G, Tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # capacity-dropping routing; capacity_factor >= e/k makes it dropless
    # (smoke/consistency tests use that; production cells accept drops)
    capacity = min(tg * k, max(1, int(cfg.capacity_factor * k * tg / e)))
    # assignment one-hots per routing slot
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (G, Tg, k, E)
    # position of each (token, slot) within its expert queue (per group)
    flat = onehot.reshape(b, tg * k, e)
    pos = jnp.cumsum(flat, axis=1) * flat - 1.0              # (G, Tg*k, E)
    pos = pos.reshape(b, tg, k, e)
    keep = (pos >= 0) & (pos < capacity)
    pos = jnp.where(keep, pos, 0.0).astype(jnp.int32)
    onehot = onehot * keep

    slot = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)  # (G,Tg,k,E,C)
    dispatch = jnp.einsum("gtke,gtkec->gtec", onehot, slot)  # (G,Tg,E,C)
    combine = jnp.einsum("gtk,gtke,gtkec->gtec", gate_vals, onehot, slot)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xt)
    hidden = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
    hidden = hidden * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", hidden, p["w_down"])   # (G,E,C,D)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                        # (E,)
    ce = jnp.mean(onehot.sum(2), axis=(0, 1))                # fraction per e
    aux = e * jnp.sum(me * ce)
    return y.reshape(b0, s0, d), aux
