"""Unified Model API over the architecture fleet.

    model = Model(cfg)
    params = model.init(jax.random.key(0))
    loss   = model.loss(params, batch)                   # train
    logits, cache = model.prefill(params, batch, cache_len)
    logits, cache = model.decode_step(params, batch, cache)

Batches are dicts: ``tokens``/``embeds`` (+ ``audio_embeds`` for
enc-dec, ``positions`` (3,B,S) for M-RoPE), ``labels`` for training,
``lengths`` (B,) or scalar for decode.  ``input_specs`` builds
ShapeDtypeStruct stand-ins for every assigned shape cell (frontend
stubs included) — the dry-run lowers against these with no allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from . import layers as L
from . import mamba2 as M
from . import transformer as T

Params = Dict[str, Any]


def _vocab_pad(v: int, mult: int = 256) -> int:
    """Pad vocab to a shardable multiple (see DESIGN.md §5)."""
    return -(-v // mult) * mult


class Model:
    def __init__(self, cfg: ModelConfig, remat: str = "none",
                 policy=None, unroll: bool = False):
        self.cfg = cfg
        self.remat = remat
        self.policy = policy
        self.unroll = unroll or cfg.scan_unroll  # roofline dry-run unroll
        self.padded_vocab = _vocab_pad(cfg.vocab_size)

    # ------------------------------------------------------------- init
    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        k_emb, k_stack, k_head, k_pos = jax.random.split(key, 4)
        params: Params = {}
        if not cfg.input_embeds:
            params["embed"] = (jax.random.normal(
                k_emb, (self.padded_vocab, cfg.d_model)) * 0.02).astype(dt)
        if cfg.family == "encdec":
            params["stack"] = T.init_encdec(cfg, k_stack)
            params["embed"] = (jax.random.normal(
                k_emb, (self.padded_vocab, cfg.d_model)) * 0.02).astype(dt)
            params["dec_pos"] = (jax.random.normal(
                k_pos, (8192, cfg.d_model)) * 0.02).astype(dt)
        elif cfg.family == "hybrid":
            params["stack"] = T.init_hybrid(cfg, k_stack)
        else:
            params["stack"] = T.init_stack(cfg, k_stack, cfg.n_layers)
        params["final_norm"] = L.init_norm(cfg, cfg.d_model)
        if cfg.tie_embeddings and "embed" in params:
            pass  # lm head reuses embed
        else:
            params["lm_head"] = (jax.random.normal(
                k_head, (cfg.d_model, self.padded_vocab))
                * cfg.d_model ** -0.5).astype(dt)
        return params

    # ----------------------------------------------------------- embed/out
    def _embed(self, params: Params, batch: Dict[str, Any]) -> jnp.ndarray:
        if self.cfg.input_embeds and "embeds" in batch:
            return batch["embeds"].astype(jnp.dtype(self.cfg.dtype))
        return jnp.take(params["embed"], batch["tokens"], axis=0)

    def _logits(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        x = L.apply_norm(self.cfg, params["final_norm"], x)
        if self.cfg.tie_embeddings and "lm_head" not in params:
            logits = x @ params["embed"].T
        else:
            logits = x @ params["lm_head"]
        return logits[..., :self.cfg.vocab_size]

    def _dec_pos(self, params: Params, seq: int) -> jnp.ndarray:
        """Learned decoder positional embedding, zero-padded past the
        table (whisper backbone exercised beyond its 448-token design
        point — see DESIGN.md arch notes)."""
        table = params["dec_pos"]
        n = table.shape[0]
        if seq <= n:
            return table[:seq]
        return jnp.pad(table, ((0, seq - n), (0, 0)))

    def _positions(self, batch: Dict[str, Any], seq: int,
                   bsz: int) -> jnp.ndarray:
        if "positions" in batch:
            return batch["positions"]
        base = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None],
                                (bsz, seq))
        if self.cfg.mrope:
            return jnp.broadcast_to(base[None], (3, bsz, seq))
        return base

    # ------------------------------------------------------------ forward
    def forward(self, params: Params, batch: Dict[str, Any]) -> jnp.ndarray:
        cfg = self.cfg
        x = self._embed(params, batch)
        bsz, seq = x.shape[0], x.shape[1]
        positions = self._positions(batch, seq, bsz)
        if self.policy is not None:
            x = self.policy.act(x)
        if cfg.family == "encdec":
            enc = batch["audio_embeds"].astype(x.dtype)
            enc_out = T.encoder_forward(cfg, params["stack"], enc,
                                        self.remat, self.policy,
                                        self.unroll)
            x = x + self._dec_pos(params, seq)[None]
            x = T.decoder_forward_encdec(cfg, params["stack"], x,
                                         positions, enc_out,
                                         self.remat, self.policy,
                                         self.unroll)
        elif cfg.family == "hybrid":
            x = T.hybrid_forward(cfg, params["stack"], x, positions,
                                 self.remat, self.policy, self.unroll)
        else:
            x, self._last_aux = T.stack_forward(cfg, params["stack"], x,
                                                positions, self.remat,
                                                self.policy, self.unroll)
        return self._logits(params, x)

    def loss(self, params: Params, batch: Dict[str, Any]) -> jnp.ndarray:
        logits = self.forward(params, batch).astype(jnp.float32)
        labels = batch["labels"]
        # CE as logsumexp - logit[label]: both terms reduce over the
        # (vocab-sharded) axis, so GSPMD lowers them as partial
        # reductions + psum instead of all-gathering full logits
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, labels[..., None],
                                     axis=-1)[..., 0]
        nll = lse - picked
        mask = (labels >= 0).astype(jnp.float32)
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        aux = getattr(self, "_last_aux", None)
        if aux is not None and self.cfg.family == "moe":
            loss = loss + 0.01 * aux / max(self.cfg.n_layers, 1)
        return loss

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, cache_len: int) -> Params:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        lyr, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        if cfg.family == "ssm":
            st = M.init_mamba_state(cfg, batch, dt)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (lyr,) + a.shape), st)
        if cfg.family == "hybrid":
            every = cfg.hybrid_attn_every or cfg.n_layers
            groups = cfg.n_layers // every
            st = M.init_mamba_state(cfg, batch, dt)
            mamba = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None, None],
                                           (groups, every) + a.shape), st)
            attn = {
                "k": jnp.zeros((groups, batch, kv, cache_len, hd), dt),
                "v": jnp.zeros((groups, batch, kv, cache_len, hd), dt),
            }
            return {"mamba": mamba, "attn": attn}
        if cfg.family == "encdec":
            return {
                "k": jnp.zeros((lyr, batch, kv, cache_len, hd), dt),
                "v": jnp.zeros((lyr, batch, kv, cache_len, hd), dt),
                "xk": jnp.zeros((lyr, batch, kv, cfg.encoder_seq, hd), dt),
                "xv": jnp.zeros((lyr, batch, kv, cfg.encoder_seq, hd), dt),
            }
        window = cfg.sliding_window
        eff = min(cache_len, window) if window else cache_len
        return {
            "k": jnp.zeros((lyr, batch, kv, eff, hd), dt),
            "v": jnp.zeros((lyr, batch, kv, eff, hd), dt),
        }

    def prefill(self, params: Params, batch: Dict[str, Any],
                cache_len: int) -> Tuple[jnp.ndarray, Params]:
        cfg = self.cfg
        x = self._embed(params, batch)
        bsz, seq = x.shape[0], x.shape[1]
        positions = self._positions(batch, seq, bsz)
        if self.policy is not None:
            x = self.policy.act(x)
        if cfg.family == "encdec":
            enc = batch["audio_embeds"].astype(x.dtype)
            enc_out = T.encoder_forward(cfg, params["stack"], enc,
                                        "none", self.policy, self.unroll)
            x = x + self._dec_pos(params, seq)[None]
            x, cache = T.decoder_prefill_encdec(cfg, params["stack"], x,
                                                positions, enc_out,
                                                cache_len, self.policy,
                                                self.unroll)
            return self._logits(params, x[:, -1:]), cache
        if cfg.family == "hybrid":
            x, cache = T.hybrid_prefill(cfg, params["stack"], x, positions,
                                        cache_len, self.policy, self.unroll)
        else:
            x, cache = T.stack_prefill(cfg, params["stack"], x, positions,
                                       cache_len, self.policy, self.unroll)
        return self._logits(params, x[:, -1:]), cache

    def decode_step(self, params: Params, batch: Dict[str, Any],
                    cache: Params) -> Tuple[jnp.ndarray, Params]:
        """One new token per sequence.  batch: tokens (B,1) or embeds
        (B,1,D); lengths (B,) or scalar current cache fill."""
        cfg = self.cfg
        x = self._embed(params, batch)
        lengths = batch["lengths"]
        if self.policy is not None:
            x = self.policy.act(x)
        if cfg.family == "encdec":
            pos = (lengths if lengths.ndim else
                   jnp.full((x.shape[0],), lengths))
            x = x + jnp.take(params["dec_pos"],
                             jnp.minimum(pos, 8191), axis=0)[:, None]
            x, cache = T.decoder_decode_encdec(cfg, params["stack"], x,
                                               cache, lengths, self.policy,
                                               self.unroll)
        elif cfg.family == "hybrid":
            x, cache = T.hybrid_decode(cfg, params["stack"], x, cache,
                                       lengths, self.policy, self.unroll)
        else:
            x, cache = T.stack_decode(cfg, params["stack"], x, cache,
                                      lengths, self.policy, self.unroll)
        return self._logits(params, x), cache

    # --------------------------------------------------------- input specs
    def input_specs(self, shape: ShapeConfig,
                    batch_override: Optional[int] = None) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for one shape cell (no allocation).
        Frontend stubs: VLM/audio cells get precomputed embeddings."""
        cfg = self.cfg
        b = batch_override or shape.global_batch
        s = shape.seq_len
        f32, i32 = jnp.float32, jnp.int32
        bf16 = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            batch: Dict[str, Any] = {}
            if cfg.input_embeds:
                batch["embeds"] = sds((b, s, cfg.d_model), bf16)
            else:
                batch["tokens"] = sds((b, s), i32)
            batch["labels"] = sds((b, s), i32)
            if cfg.mrope:
                batch["positions"] = sds((3, b, s), i32)
            if cfg.family == "encdec":
                batch["audio_embeds"] = sds((b, cfg.encoder_seq,
                                             cfg.d_model), bf16)
            return batch
        if shape.kind == "prefill":
            batch = {}
            if cfg.input_embeds:
                batch["embeds"] = sds((b, s, cfg.d_model), bf16)
            else:
                batch["tokens"] = sds((b, s), i32)
            if cfg.mrope:
                batch["positions"] = sds((3, b, s), i32)
            if cfg.family == "encdec":
                batch["audio_embeds"] = sds((b, cfg.encoder_seq,
                                             cfg.d_model), bf16)
            return batch
        # decode: one token against a cache of seq_len
        batch = {"lengths": sds((b,), i32)}
        if cfg.input_embeds:
            batch["embeds"] = sds((b, 1, cfg.d_model), bf16)
        else:
            batch["tokens"] = sds((b, 1), i32)
        if cfg.mrope:
            batch["positions"] = sds((b, 1), i32)
        batch["cache"] = jax.eval_shape(lambda: self.init_cache(b, s))
        return batch
