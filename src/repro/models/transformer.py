"""Decoder / encoder-decoder / hybrid stacks over the layer library.

All stacks scan over depth with stacked per-layer params (HLO size O(1)
in depth — 64-layer configs compile in seconds and stay parsable for
the roofline).  Remat policy is configurable per train-step.

Cache convention: every attention layer owns ``k``/``v`` of shape
(L, B, HKV, S, hd) (stacked on the scan axis); mamba layers own
``conv`` (L, B, K-1, C) and ``ssm`` (L, B, H, P, N).  ``lengths`` (B,)
tracks valid entries; writes happen at position ``lengths`` (uniform
scalar fast path or per-sequence vmap path for continuous batching).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import layers as L
from . import mamba2 as M

Params = Dict[str, Any]


# ------------------------------------------------------------ cache utils

def update_kv_cache(k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                    k: jnp.ndarray, v: jnp.ndarray,
                    lengths: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Write new k/v (B, HKV, T, hd) at per-sequence offsets ``lengths``.
    Uniform offsets (dry-run / static batching) use the scalar fast path."""
    if lengths.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, 0, lengths, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, 0, lengths, 0))
        return k_cache, v_cache

    def upd(cache_b, new_b, pos):
        return jax.lax.dynamic_update_slice(cache_b, new_b, (0, pos, 0))

    k_cache = jax.vmap(upd)(k_cache, k.astype(k_cache.dtype), lengths)
    v_cache = jax.vmap(upd)(v_cache, v.astype(v_cache.dtype), lengths)
    return k_cache, v_cache


# -------------------------------------------------------- decoder layers

def init_decoder_layer(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    if cfg.family == "ssm":
        return {"norm1": L.init_norm(cfg, cfg.d_model),
                "mamba": M.init_mamba2(cfg, k1)}
    if cfg.family == "hybrid":
        return {"norm1": L.init_norm(cfg, cfg.d_model),
                "mamba": M.init_mamba2(cfg, k1)}
    p = {"norm1": L.init_norm(cfg, cfg.d_model),
         "attn": L.init_attention(cfg, k1),
         "norm2": L.init_norm(cfg, cfg.d_model)}
    if cfg.family == "moe":
        p["moe"] = L.init_moe(cfg, k2)
    else:
        p["mlp"] = L.init_mlp(cfg, k2)
    return p


def attn_block_full(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                    positions: jnp.ndarray, q_offset: int = 0,
                    causal: bool = True,
                    policy=None) -> Tuple[jnp.ndarray, Tuple]:
    """Self-attention over the layer's own sequence (train / prefill).
    Returns (out, (k, v)) so prefill can stash the cache."""
    h = L.apply_norm(cfg, p["norm1"], x)
    q, k, v = L.qkv_project(cfg, p["attn"], h, positions)
    if policy is not None:
        q, k, v = policy.attn_qkv(q, k, v)
    o = L.run_attention(cfg, q, k, v, causal=causal, q_offset=q_offset)
    o = o.transpose(0, 2, 1, 3).reshape(x.shape[0], x.shape[1], -1)
    o = o @ p["attn"]["wo"]
    if policy is not None:
        o = policy.act(o)
    return x + o, (k, v)


def attn_block_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                      k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                      lengths: jnp.ndarray, policy=None):
    """One-token decode against the cache.  x: (B, 1, D).

    Sliding-window archs may hand a *ring buffer* cache of size == window:
    the write position wraps and every slot stays visible once filled —
    the ring then IS the window (RoPE is applied at write time, so scores
    only depend on absolute positions, not storage slots).  This is what
    bounds the ``long_500k`` cell's live memory for SWA archs."""
    h = L.apply_norm(cfg, p["norm1"], x)
    pos = (lengths.reshape(-1, 1) if lengths.ndim else
           jnp.full((x.shape[0], 1), lengths, jnp.int32))
    if cfg.mrope:  # decode: all three M-RoPE components advance together
        pos = jnp.broadcast_to(pos[None], (3,) + pos.shape)
    q, k, v = L.qkv_project(cfg, p["attn"], h, pos)
    cache_size = k_cache.shape[2]
    window = cfg.sliding_window
    ring = window is not None and cache_size == window
    write_at = lengths % cache_size if ring else lengths
    k_cache, v_cache = update_kv_cache(k_cache, v_cache, k, v, write_at)
    valid = (lengths + 1 if lengths.ndim else
             jnp.full((x.shape[0],), lengths + 1, jnp.int32))
    if ring:
        valid = jnp.minimum(valid, cache_size)
        window = None  # the ring already implements the window
    if policy is not None and policy.seq_sharded_decode:
        o = policy.sharded_decode_attention(q, k_cache, v_cache, valid,
                                            window)
    else:
        o = L.decode_attention(q, k_cache, v_cache, valid, window)
    o = o.transpose(0, 2, 1, 3).reshape(x.shape[0], 1, -1)
    o = o @ p["attn"]["wo"]
    return x + o, (k_cache, v_cache)


def mlp_block(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              policy=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = L.apply_norm(cfg, p["norm2"], x)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        y, aux = L.moe(cfg, p["moe"], h)
    else:
        y = L.mlp(cfg, p["mlp"], h)
    if policy is not None:
        y = policy.act(y)
    return x + y, aux


def decoder_layer_full(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                       positions: jnp.ndarray, q_offset: int = 0,
                       policy=None):
    """Full-sequence pass of one layer.  Returns (x, (k, v), aux)."""
    if cfg.family in ("ssm", "hybrid"):
        h = L.apply_norm(cfg, p["norm1"], x)
        y = M.mamba2_forward(cfg, p["mamba"], h, policy=policy)
        if policy is not None:
            y = policy.act(y)
        return x + y, None, jnp.zeros((), jnp.float32)
    x, kv = attn_block_full(cfg, p, x, positions, q_offset, policy=policy)
    x, aux = mlp_block(cfg, p, x, policy=policy)
    return x, kv, aux


def decoder_layer_full_with_state(cfg: ModelConfig, p: Params,
                                  x: jnp.ndarray, policy=None):
    """Mamba layer full pass that also returns the final SSM/conv state
    (prefill path for ssm/hybrid)."""
    h = L.apply_norm(cfg, p["norm1"], x)
    y, state = M.mamba2_forward(cfg, p["mamba"], h, return_state=True,
                                policy=policy)
    if policy is not None:
        y = policy.act(y)
    return x + y, state


def decoder_layer_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                         cache: Dict[str, jnp.ndarray],
                         lengths: jnp.ndarray, policy=None):
    if cfg.family in ("ssm", "hybrid"):
        h = L.apply_norm(cfg, p["norm1"], x)
        y, new_state = M.mamba2_decode_step(cfg, p["mamba"], h, cache,
                                            policy=policy)
        if policy is not None:
            y = policy.act(y)
        return x + y, new_state, jnp.zeros((), jnp.float32)
    x, (kc, vc) = attn_block_decode(cfg, p, x, cache["k"], cache["v"],
                                    lengths, policy=policy)
    x, aux = mlp_block(cfg, p, x, policy=policy)
    return x, {"k": kc, "v": vc}, aux


# ----------------------------------------------------------------- stacks

def init_stack(cfg: ModelConfig, key: jax.Array, n_layers: int) -> Params:
    keys = jax.random.split(key, n_layers)
    per_layer = [init_decoder_layer(cfg, k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)


def stack_forward(cfg: ModelConfig, stacked: Params, x: jnp.ndarray,
                  positions: jnp.ndarray, remat: str = "none",
                  policy=None, unroll: bool = False
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Train-mode pass over all layers (scan).  Returns (x, aux_sum).
    ``unroll`` fully unrolls the depth loop — used by the roofline
    dry-run so cost_analysis counts every layer (XLA reports while
    bodies once)."""

    def body(h, layer_p):
        h2, _kv, aux = decoder_layer_full(cfg, layer_p, h, positions,
                                          policy=policy)
        return h2, aux

    body = _maybe_remat(body, remat)
    x, auxs = jax.lax.scan(body, x, stacked, unroll=unroll)
    return x, jnp.sum(auxs)


def stack_prefill(cfg: ModelConfig, stacked: Params, x: jnp.ndarray,
                  positions: jnp.ndarray, cache_len: int,
                  policy=None, unroll: bool = False
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence pass returning the populated cache (padded to
    ``cache_len``)."""
    if cfg.family in ("ssm", "hybrid"):
        def body(h, layer_p):
            h2, state = decoder_layer_full_with_state(cfg, layer_p, h,
                                                      policy=policy)
            return h2, state
        x, states = jax.lax.scan(body, x, stacked, unroll=unroll)
        return x, states

    pad = cache_len - x.shape[1]

    def body(h, layer_p):
        h2, (k, v), _aux = decoder_layer_full(cfg, layer_p, h, positions,
                                              policy=policy)
        kpad = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vpad = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return h2, {"k": kpad, "v": vpad}

    x, cache = jax.lax.scan(body, x, stacked, unroll=unroll)
    return x, cache


def stack_decode(cfg: ModelConfig, stacked: Params, x: jnp.ndarray,
                 cache: Dict[str, jnp.ndarray], lengths: jnp.ndarray,
                 policy=None, unroll: bool = False):
    def body(h, xs):
        layer_p, layer_cache = xs
        h2, new_cache, _aux = decoder_layer_decode(cfg, layer_p, h,
                                                   layer_cache, lengths,
                                                   policy=policy)
        return h2, new_cache

    x, new_cache = jax.lax.scan(body, x, (stacked, cache), unroll=unroll)
    return x, new_cache


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(f"unknown remat policy {remat!r}")


# ------------------------------------------------------- hybrid (zamba2)

def init_hybrid(cfg: ModelConfig, key: jax.Array) -> Params:
    """n_layers mamba blocks + ONE shared attention block applied every
    ``hybrid_attn_every`` layers (weights reused — zamba2's shared
    block, simplified to act on the running hidden state; see DESIGN.md)."""
    k1, k2, k3 = jax.random.split(key, 3)
    dense_cfg = _as_dense(cfg)
    return {
        "mamba_stack": init_stack(cfg, k1, cfg.n_layers),
        "shared_attn": init_decoder_layer(dense_cfg, k2),
    }


def _as_dense(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, family="dense")


def hybrid_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                   positions: jnp.ndarray, remat: str = "none",
                   policy=None, unroll: bool = False) -> jnp.ndarray:
    every = cfg.hybrid_attn_every or cfg.n_layers
    groups = cfg.n_layers // every
    dense_cfg = _as_dense(cfg)
    stacked = p["mamba_stack"]
    grouped = jax.tree.map(
        lambda a: a.reshape((groups, every) + a.shape[1:]), stacked)
    for gi in range(groups):
        group = jax.tree.map(lambda a: a[gi], grouped)
        x, _ = stack_forward(cfg, group, x, positions, remat, policy,
                             unroll)
        x, _kv, _aux = decoder_layer_full(dense_cfg, p["shared_attn"], x,
                                          positions, policy=policy)
    return x


def hybrid_prefill(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                   positions: jnp.ndarray, cache_len: int, policy=None,
                   unroll: bool = False):
    every = cfg.hybrid_attn_every or cfg.n_layers
    groups = cfg.n_layers // every
    dense_cfg = _as_dense(cfg)
    grouped = jax.tree.map(
        lambda a: a.reshape((groups, every) + a.shape[1:]),
        p["mamba_stack"])
    mamba_states, attn_caches = [], []
    pad = cache_len - x.shape[1]
    for gi in range(groups):
        group = jax.tree.map(lambda a: a[gi], grouped)
        x, st = stack_prefill(cfg, group, x, positions, cache_len, policy,
                              unroll)
        mamba_states.append(st)
        x, (k, v), _ = decoder_layer_full(dense_cfg, p["shared_attn"], x,
                                          positions, policy=policy)
        attn_caches.append({
            "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))})
    cache = {
        "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *mamba_states),
        "attn": jax.tree.map(lambda *xs: jnp.stack(xs), *attn_caches),
    }
    return x, cache


def hybrid_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                  cache: Dict[str, Any], lengths: jnp.ndarray, policy=None,
                  unroll: bool = False):
    every = cfg.hybrid_attn_every or cfg.n_layers
    groups = cfg.n_layers // every
    dense_cfg = _as_dense(cfg)
    grouped = jax.tree.map(
        lambda a: a.reshape((groups, every) + a.shape[1:]),
        p["mamba_stack"])
    new_mamba, new_attn = [], []
    for gi in range(groups):
        group = jax.tree.map(lambda a: a[gi], grouped)
        gcache = jax.tree.map(lambda a: a[gi], cache["mamba"])
        x, st = stack_decode(cfg, group, x, gcache, lengths, policy,
                             unroll)
        new_mamba.append(st)
        acache = jax.tree.map(lambda a: a[gi], cache["attn"])
        x, st2, _ = decoder_layer_decode(dense_cfg, p["shared_attn"], x,
                                         acache, lengths, policy=policy)
        new_attn.append(st2)
    new_cache = {
        "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_mamba),
        "attn": jax.tree.map(lambda *xs: jnp.stack(xs), *new_attn),
    }
    return x, new_cache


# ------------------------------------------------------ enc-dec (whisper)

def init_encdec_layer(cfg: ModelConfig, key: jax.Array,
                      cross: bool) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": L.init_norm(cfg, cfg.d_model),
         "attn": L.init_attention(cfg, k1),
         "norm2": L.init_norm(cfg, cfg.d_model),
         "mlp": L.init_mlp(cfg, k2)}
    if cross:
        p["norm_x"] = L.init_norm(cfg, cfg.d_model)
        p["xattn"] = L.init_attention(cfg, k3)
    return p


def init_encdec(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    enc_layers = [init_encdec_layer(cfg, k, cross=False)
                  for k in jax.random.split(k1, cfg.encoder_layers)]
    dec_layers = [init_encdec_layer(cfg, k, cross=True)
                  for k in jax.random.split(k2, cfg.n_layers)]
    return {
        "encoder": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers),
        "decoder": jax.tree.map(lambda *xs: jnp.stack(xs), *dec_layers),
        "enc_norm": L.init_norm(cfg, cfg.d_model),
    }


def encoder_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                    remat: str = "none", policy=None,
                    unroll: bool = False) -> jnp.ndarray:
    """Bidirectional encoder over precomputed frame embeddings."""
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                 x.shape[:2])

    def body(h, layer_p):
        h, _ = attn_block_full(cfg, layer_p, h, positions, causal=False,
                               policy=policy)
        h2 = L.apply_norm(cfg, layer_p["norm2"], h)
        h = h + L.mlp(cfg, layer_p["mlp"], h2)
        return h, None

    body = _maybe_remat(body, remat)
    x, _ = jax.lax.scan(body, x, p["encoder"], unroll=unroll)
    return L.apply_norm(cfg, p["enc_norm"], x)


def cross_attention(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                    enc_kv: Tuple[jnp.ndarray, jnp.ndarray],
                    policy=None) -> jnp.ndarray:
    """Decoder cross-attn against precomputed encoder K/V."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    hn = L.apply_norm(cfg, p["norm_x"], x)
    q = (hn @ p["xattn"]["wq"])
    if cfg.qkv_bias:
        q = q + p["xattn"]["bq"]
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k, v = enc_kv
    o = L.run_attention(cfg, q, k, v, causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1) @ p["xattn"]["wo"]
    if policy is not None:
        o = policy.act(o)
    return x + o


def encoder_kv(cfg: ModelConfig, dec_stacked: Params,
               enc_out: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Precompute per-decoder-layer cross K/V from encoder output
    (stacked on the layer axis)."""
    b, se, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.hd

    def per_layer(layer_p):
        k = enc_out @ layer_p["xattn"]["wk"]
        v = enc_out @ layer_p["xattn"]["wv"]
        if cfg.qkv_bias:
            k, v = k + layer_p["xattn"]["bk"], v + layer_p["xattn"]["bv"]
        k = k.reshape(b, se, kv, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, se, kv, hd).transpose(0, 2, 1, 3)
        return k, v

    return jax.vmap(per_layer)(dec_stacked)


def decoder_forward_encdec(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                           positions: jnp.ndarray, enc_out: jnp.ndarray,
                           remat: str = "none", policy=None,
                           unroll: bool = False) -> jnp.ndarray:
    xk, xv = encoder_kv(cfg, p["decoder"], enc_out)

    def body(h, xs):
        layer_p, ek, ev = xs
        h, _ = attn_block_full(cfg, layer_p, h, positions, policy=policy)
        h = cross_attention(cfg, layer_p, h, (ek, ev), policy=policy)
        h2 = L.apply_norm(cfg, layer_p["norm2"], h)
        h = h + L.mlp(cfg, layer_p["mlp"], h2)
        return h, None

    body = _maybe_remat(body, remat)
    x, _ = jax.lax.scan(body, x, (p["decoder"], xk, xv), unroll=unroll)
    return x


def decoder_prefill_encdec(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                           positions: jnp.ndarray, enc_out: jnp.ndarray,
                           cache_len: int, policy=None,
                           unroll: bool = False):
    """Full decoder pass that also returns the populated self-attn cache
    (k/v captured from the same projections the forward pass used)."""
    xk, xv = encoder_kv(cfg, p["decoder"], enc_out)
    pad = cache_len - x.shape[1]

    def body(h, xs):
        layer_p, ek, ev = xs
        h, (k, v) = attn_block_full(cfg, layer_p, h, positions,
                                    policy=policy)
        h = cross_attention(cfg, layer_p, h, (ek, ev), policy=policy)
        h2 = L.apply_norm(cfg, layer_p["norm2"], h)
        h = h + L.mlp(cfg, layer_p["mlp"], h2)
        kpad = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vpad = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return h, {"k": kpad, "v": vpad}

    x, kv = jax.lax.scan(body, x, (p["decoder"], xk, xv), unroll=unroll)
    cache = {"k": kv["k"], "v": kv["v"], "xk": xk, "xv": xv}
    return x, cache


def decoder_decode_encdec(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                          cache: Dict[str, jnp.ndarray],
                          lengths: jnp.ndarray, policy=None,
                          unroll: bool = False):
    """One-token enc-dec decode: causal self-attn cache + static cross KV."""
    def body(h, xs):
        layer_p, layer_cache = xs
        h, (kc, vc) = attn_block_decode(cfg, layer_p, h,
                                        layer_cache["k"], layer_cache["v"],
                                        lengths, policy=policy)
        h = cross_attention(cfg, layer_p, h,
                            (layer_cache["xk"], layer_cache["xv"]),
                            policy=policy)
        h2 = L.apply_norm(cfg, layer_p["norm2"], h)
        h = h + L.mlp(cfg, layer_p["mlp"], h2)
        return h, {"k": kc, "v": vc, "xk": layer_cache["xk"],
                   "xv": layer_cache["xv"]}

    x, new_cache = jax.lax.scan(body, x, (p["decoder"], cache),
                                unroll=unroll)
    return x, new_cache
