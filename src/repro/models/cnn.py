"""CNN model zoo expressed in the ONNX-lite transport format.

Builders emit exactly the graphs a framework exporter would (ONNX op
names, NCHW, initializers as numpy arrays), so the front-end parser is
exercised the same way it would be on a real ONNX file.  AlexNet and
VGG-16 match the paper's workloads (Tables 1–4).  A float JAX executor
(``run_float``) serves as the accuracy oracle for the int8 pipeline.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, Node, TensorInfo


class GraphBuilder:
    """Tiny builder DSL ("the ML framework" whose export we parse).

    The builder threads one *current* tensor; ``tap()`` captures a
    handle to it and ``from_tap`` rewinds, which is how branches
    (residual skips, inception-style splits) are expressed — the emitted
    graph is a plain ONNX-style DAG either way."""

    def __init__(self, name: str, input_shape: Sequence[int], seed: int = 0):
        self.name = name
        self.nodes: List[Node] = []
        self.inits: Dict[str, np.ndarray] = {}
        self.rng = np.random.default_rng(seed)
        self.input = TensorInfo("input", tuple(input_shape))
        self.cur = "input"
        self.cur_shape: Tuple[int, ...] = tuple(input_shape)
        self._n = 0

    def _name(self, op: str) -> str:
        self._n += 1
        return f"{op.lower()}_{self._n}"

    # ------------------------------------------------- branch plumbing
    def tap(self) -> Tuple[str, Tuple[int, ...]]:
        """Handle to the current tensor (for skips/merges)."""
        return self.cur, self.cur_shape

    def from_tap(self, handle: Tuple[str, Tuple[int, ...]]) -> "GraphBuilder":
        """Rewind the builder to a tapped tensor (start a branch)."""
        self.cur, self.cur_shape = handle[0], tuple(handle[1])
        return self

    def conv(self, c_out: int, k: int, stride: int = 1, pad: int = 0,
             relu: bool = True, group: int = 1) -> "GraphBuilder":
        name = self._name("Conv")
        c_in = self.cur_shape[1]
        w = (self.rng.standard_normal((c_out, c_in // group, k, k)) *
             np.sqrt(2.0 / (c_in // group * k * k))).astype(np.float32)
        b = (self.rng.standard_normal(c_out) * 0.01).astype(np.float32)
        self.inits[name + "_w"] = w
        self.inits[name + "_b"] = b
        out = name + "_out"
        self.nodes.append(Node(
            "Conv", name, [self.cur, name + "_w", name + "_b"], [out],
            {"kernel_shape": [k, k], "strides": [stride, stride],
             "pads": [pad, pad, pad, pad], "dilations": [1, 1],
             "group": group}))
        self.cur = out
        h = (self.cur_shape[2] + 2 * pad - k) // stride + 1
        w_ = (self.cur_shape[3] + 2 * pad - k) // stride + 1
        self.cur_shape = (self.cur_shape[0], c_out, h, w_)
        if relu:
            self.relu()
        return self

    def dwconv(self, k: int, stride: int = 1, pad: int = 0,
               relu: bool = True) -> "GraphBuilder":
        """Depthwise conv (group == C, multiplier 1, MobileNet-style)."""
        return self.conv(self.cur_shape[1], k, stride=stride, pad=pad,
                         relu=relu, group=self.cur_shape[1])

    def add_from(self, handle: Tuple[str, Tuple[int, ...]],
                 relu: bool = True) -> "GraphBuilder":
        """Residual merge: current tensor + tapped tensor."""
        name = self._name("Add")
        out = name + "_out"
        self.nodes.append(Node("Add", name, [self.cur, handle[0]], [out]))
        self.cur = out
        if relu:
            self.relu()
        return self

    def concat_from(self, *handles: Tuple[str, Tuple[int, ...]]
                    ) -> "GraphBuilder":
        """Channel merge: concat current tensor with tapped tensors."""
        name = self._name("Concat")
        out = name + "_out"
        self.nodes.append(Node(
            "Concat", name, [self.cur] + [h[0] for h in handles], [out],
            {"axis": 1}))
        c = self.cur_shape[1] + sum(h[1][1] for h in handles)
        self.cur_shape = (self.cur_shape[0], c) + tuple(self.cur_shape[2:])
        self.cur = out
        return self

    def relu(self) -> "GraphBuilder":
        name = self._name("Relu")
        out = name + "_out"
        self.nodes.append(Node("Relu", name, [self.cur], [out]))
        self.cur = out
        return self

    def maxpool(self, k: int, stride: Optional[int] = None,
                pad: int = 0) -> "GraphBuilder":
        stride = stride or k
        name = self._name("MaxPool")
        out = name + "_out"
        self.nodes.append(Node(
            "MaxPool", name, [self.cur], [out],
            {"kernel_shape": [k, k], "strides": [stride, stride],
             "pads": [pad, pad, pad, pad]}))
        self.cur = out
        n, c, h, w = self.cur_shape
        self.cur_shape = (n, c, (h + 2 * pad - k) // stride + 1,
                          (w + 2 * pad - k) // stride + 1)
        return self

    def avgpool(self, k: int, stride: Optional[int] = None,
                pad: int = 0) -> "GraphBuilder":
        stride = stride or k
        name = self._name("AveragePool")
        out = name + "_out"
        self.nodes.append(Node(
            "AveragePool", name, [self.cur], [out],
            {"kernel_shape": [k, k], "strides": [stride, stride],
             "pads": [pad, pad, pad, pad]}))
        self.cur = out
        n, c, h, w = self.cur_shape
        self.cur_shape = (n, c, (h + 2 * pad - k) // stride + 1,
                          (w + 2 * pad - k) // stride + 1)
        return self

    def global_avgpool(self) -> "GraphBuilder":
        name = self._name("GlobalAveragePool")
        out = name + "_out"
        self.nodes.append(Node("GlobalAveragePool", name, [self.cur], [out]))
        self.cur = out
        n, c, _h, _w = self.cur_shape
        self.cur_shape = (n, c, 1, 1)
        return self

    def flatten(self) -> "GraphBuilder":
        name = self._name("Flatten")
        out = name + "_out"
        self.nodes.append(Node("Flatten", name, [self.cur], [out], {"axis": 1}))
        self.cur = out
        n = self.cur_shape[0]
        self.cur_shape = (n, int(np.prod(self.cur_shape[1:])))
        return self

    def fc(self, n_out: int, relu: bool = True, softmax: bool = False) -> "GraphBuilder":
        if len(self.cur_shape) != 2:
            self.flatten()
        name = self._name("Gemm")
        k = self.cur_shape[1]
        w = (self.rng.standard_normal((k, n_out)) * np.sqrt(2.0 / k)).astype(np.float32)
        b = (self.rng.standard_normal(n_out) * 0.01).astype(np.float32)
        self.inits[name + "_w"] = w
        self.inits[name + "_b"] = b
        out = name + "_out"
        self.nodes.append(Node("Gemm", name, [self.cur, name + "_w", name + "_b"],
                               [out], {"transA": 0, "transB": 0}))
        self.cur = out
        self.cur_shape = (self.cur_shape[0], n_out)
        if relu:
            self.relu()
        if softmax:
            name = self._name("Softmax")
            out = name + "_out"
            self.nodes.append(Node("Softmax", name, [self.cur], [out], {"axis": 1}))
            self.cur = out
        return self

    def build(self) -> Graph:
        return Graph(self.name, self.nodes, [self.input], [self.cur], self.inits)


def alexnet(batch: int = 1, num_classes: int = 1000, seed: int = 0,
            channels_base: int = 64) -> Graph:
    """AlexNet [36] (single-tower variant, as in torchvision / PipeCNN).

    Five conv layers (1,2,5 followed by 3x3/2 max-pool) + three FC —
    the paper's Fig. 6 structure: 5 fused conv/pool stages + 3 FC stages.
    """
    cb = channels_base
    b = GraphBuilder("alexnet", (batch, 3, 224, 224), seed)
    b.conv(cb, 11, stride=4, pad=2).maxpool(3, 2)
    b.conv(cb * 3, 5, pad=2).maxpool(3, 2)
    b.conv(cb * 6, 3, pad=1)
    b.conv(cb * 4, 3, pad=1)
    b.conv(cb * 4, 3, pad=1).maxpool(3, 2)
    b.fc(4096).fc(4096).fc(num_classes, relu=False, softmax=True)
    return b.build()


def vgg16(batch: int = 1, num_classes: int = 1000, seed: int = 0) -> Graph:
    """VGG-16 [37]: 13 conv (5 pool stages) + 3 FC."""
    b = GraphBuilder("vgg16", (batch, 3, 224, 224), seed)
    for c, reps in ((64, 2), (128, 2), (256, 3), (512, 3), (512, 3)):
        for _ in range(reps):
            b.conv(c, 3, pad=1)
        b.maxpool(2, 2)
    b.fc(4096).fc(4096).fc(num_classes, relu=False, softmax=True)
    return b.build()


def tiny_cnn(batch: int = 1, num_classes: int = 10, seed: int = 0,
             in_hw: int = 32) -> Graph:
    """A small CIFAR-scale CNN for fast tests/examples."""
    b = GraphBuilder("tiny_cnn", (batch, 3, in_hw, in_hw), seed)
    b.conv(16, 3, pad=1).maxpool(2, 2)
    b.conv(32, 3, pad=1).maxpool(2, 2)
    b.fc(64).fc(num_classes, relu=False, softmax=True)
    return b.build()


def tiny_cnn_gap(batch: int = 1, num_classes: int = 10, seed: int = 0,
                 in_hw: int = 32) -> Graph:
    """Variant with average-pool + global-average-pool head (exercises
    the standalone avg-pool pipeline stages)."""
    b = GraphBuilder("tiny_cnn_gap", (batch, 3, in_hw, in_hw), seed)
    b.conv(16, 3, pad=1).avgpool(2, 2)
    b.conv(32, 3, pad=1).global_avgpool()
    b.fc(num_classes, relu=False, softmax=True)
    return b.build()


def _basic_block(b: GraphBuilder, c_out: int, stride: int = 1) -> None:
    """ResNet basic block: two 3x3 convs + identity/projection skip,
    post-add ReLU (the canonical v1 ordering)."""
    skip = b.tap()
    b.conv(c_out, 3, stride=stride, pad=1)
    b.conv(c_out, 3, pad=1, relu=False)
    main = b.tap()
    if stride != 1 or skip[1][1] != c_out:
        # 1x1 strided projection on the skip path (ResNet option B)
        b.from_tap(skip).conv(c_out, 1, stride=stride, relu=False)
        skip = b.tap()
    b.from_tap(main).add_from(skip, relu=True)


def resnet_tiny(batch: int = 1, num_classes: int = 10, seed: int = 0,
                in_hw: int = 32) -> Graph:
    """CIFAR-scale residual net: stem + identity block + downsample
    block (strided projection) — the smallest graph that exercises
    multi-consumer fan-out, residual merge and branch requantization."""
    b = GraphBuilder("resnet_tiny", (batch, 3, in_hw, in_hw), seed)
    b.conv(16, 3, pad=1)
    _basic_block(b, 16)
    _basic_block(b, 32, stride=2)
    b.global_avgpool()
    b.fc(num_classes, relu=False, softmax=True)
    return b.build()


def resnet18(batch: int = 1, num_classes: int = 1000, seed: int = 0,
             in_hw: int = 224) -> Graph:
    """ResNet-18 [He et al.]: 7x7/2 stem + padded 3x3/2 max-pool, four
    basic-block groups (64/128/256/512, two blocks each, strided
    projection at each group boundary), GAP head.  ``in_hw`` shrinks
    the input for interpret-mode tests (the GAP head absorbs any size
    the five stride-2 stages leave >= 1)."""
    b = GraphBuilder("resnet18", (batch, 3, in_hw, in_hw), seed)
    b.conv(64, 7, stride=2, pad=3).maxpool(3, 2, pad=1)
    for c_out, stride in ((64, 1), (64, 1), (128, 2), (128, 1),
                          (256, 2), (256, 1), (512, 2), (512, 1)):
        _basic_block(b, c_out, stride)
    b.global_avgpool()
    b.fc(num_classes, relu=False, softmax=True)
    return b.build()


def mobilenet_tiny(batch: int = 1, num_classes: int = 10, seed: int = 0,
                   in_hw: int = 32) -> Graph:
    """MobileNet-v1-style separable stack: strided stem + three
    depthwise(3x3)+pointwise(1x1) pairs — exercises the depthwise band
    kernel and the grouped feasibility rules."""
    b = GraphBuilder("mobilenet_tiny", (batch, 3, in_hw, in_hw), seed)
    b.conv(16, 3, stride=2, pad=1)
    for c_out, stride in ((32, 1), (64, 2), (64, 1)):
        b.dwconv(3, stride=stride, pad=1)
        b.conv(c_out, 1)
    b.global_avgpool()
    b.fc(num_classes, relu=False, softmax=True)
    return b.build()


def _inception(b: GraphBuilder, c1: int, c3r: int, c3: int,
               c5r: int, c5: int, cp: int) -> None:
    """GoogLeNet inception module: four parallel branches — 1x1, 1x1→3x3,
    1x1→5x5, 3x3-maxpool→1x1 — channel-concatenated.  Every branch ends
    in a dense conv, so the whole 4-way merge is concat-epilogue
    eligible (each branch writes its channel slice of the shared merge
    buffer in place)."""
    split = b.tap()
    b.conv(c1, 1)
    b1 = b.tap()
    b.from_tap(split).conv(c3r, 1).conv(c3, 3, pad=1)
    b2 = b.tap()
    b.from_tap(split).conv(c5r, 1).conv(c5, 5, pad=2)
    b3 = b.tap()
    b.from_tap(split).maxpool(3, 1, pad=1).conv(cp, 1)
    b4 = b.tap()
    b.from_tap(b1).concat_from(b2, b3, b4)


def googlenet_tiny(batch: int = 1, num_classes: int = 10, seed: int = 0,
                   in_hw: int = 24) -> Graph:
    """CIFAR-scale GoogLeNet: stem + two inception modules (4-way
    channel merges; a post-merge max-pool between them that the concat
    fusion absorbs into the producers' epilogues) + GAP head — the
    inception-class stress test of the toolflow surveys, small enough
    for interpret mode."""
    b = GraphBuilder("googlenet_tiny", (batch, 3, in_hw, in_hw), seed)
    b.conv(16, 3, pad=1).maxpool(2, 2)
    _inception(b, 8, 8, 12, 4, 6, 6)      # merge Cout 8+12+6+6 = 32
    b.maxpool(2, 2)                        # absorbed by the concat
    _inception(b, 10, 8, 12, 4, 6, 4)     # ragged offsets 0/10/22/28
    b.global_avgpool()
    b.fc(num_classes, relu=False, softmax=True)
    return b.build()


def _fire(b: GraphBuilder, s: int, e1: int, e3: int) -> None:
    """SqueezeNet fire module: 1x1 squeeze feeding parallel 1x1 and 3x3
    expands, channel-concatenated (both expands are dense convs, so the
    2-way merge is concat-epilogue eligible)."""
    b.conv(s, 1)
    split = b.tap()
    b.conv(e1, 1)
    left = b.tap()
    b.from_tap(split).conv(e3, 3, pad=1)
    right = b.tap()
    b.from_tap(left).concat_from(right)


def squeezenet_tiny(batch: int = 1, num_classes: int = 10, seed: int = 0,
                    in_hw: int = 24) -> Graph:
    """CIFAR-scale SqueezeNet: strided stem + three fire modules (2-way
    expand concats; a post-merge max-pool after the second that the
    concat fusion absorbs) + GAP head."""
    b = GraphBuilder("squeezenet_tiny", (batch, 3, in_hw, in_hw), seed)
    b.conv(16, 3, stride=2, pad=1)
    _fire(b, 8, 12, 12)
    _fire(b, 8, 12, 12)
    b.maxpool(2, 2)                        # absorbed by fire-2's concat
    _fire(b, 12, 20, 12)                   # ragged offsets 0/20
    b.global_avgpool()
    b.fc(num_classes, relu=False, softmax=True)
    return b.build()


# ---------------------------------------------------------------------
# Float oracle: run the graph directly with lax ops (NCHW).
# ---------------------------------------------------------------------

def run_float(graph: Graph, x: jnp.ndarray, return_env: bool = False):
    """Execute the ONNX-lite graph in float32 — the emulation-mode
    accuracy oracle against which the int8 pipeline is validated."""
    env: Dict[str, jnp.ndarray] = {graph.inputs[0].name: x}
    for k, v in graph.initializers.items():
        env[k] = jnp.asarray(v)
    for n in graph.nodes:
        if n.op_type == "Conv":
            xin, w = env[n.inputs[0]], env[n.inputs[1]]
            pads = n.attr("pads", [0, 0, 0, 0])
            out = jax.lax.conv_general_dilated(
                xin, w,
                window_strides=tuple(n.attr("strides", [1, 1])),
                padding=((pads[0], pads[2]), (pads[1], pads[3])),
                rhs_dilation=tuple(n.attr("dilations", [1, 1])),
                dimension_numbers=("NCHW", "OIHW", "NCHW"),
                feature_group_count=int(n.attr("group", 1)),
            )
            if len(n.inputs) > 2:
                out = out + env[n.inputs[2]][None, :, None, None]
            env[n.outputs[0]] = out
        elif n.op_type == "MaxPool":
            xin = env[n.inputs[0]]
            k = n.attr("kernel_shape")
            s = n.attr("strides", k)
            p = n.attr("pads", [0, 0, 0, 0])
            env[n.outputs[0]] = jax.lax.reduce_window(
                xin, -jnp.inf, jax.lax.max,
                (1, 1, k[0], k[1]), (1, 1, s[0], s[1]),
                ((0, 0), (0, 0), (p[0], p[2]), (p[1], p[3])))
        elif n.op_type in ("AveragePool", "GlobalAveragePool"):
            xin = env[n.inputs[0]]
            if n.op_type == "GlobalAveragePool":
                env[n.outputs[0]] = jnp.mean(xin, axis=(2, 3), keepdims=True)
            else:
                k = n.attr("kernel_shape")
                s = n.attr("strides", k)
                p = n.attr("pads", [0, 0, 0, 0])
                padding = ((0, 0), (0, 0), (p[0], p[2]), (p[1], p[3]))
                dims, strides = (1, 1, k[0], k[1]), (1, 1, s[0], s[1])
                summed = jax.lax.reduce_window(
                    xin, 0.0, jax.lax.add, dims, strides, padding)
                if any(p):
                    # ONNX count_include_pad=0: divide by the real
                    # window population, matching the int8 path
                    counts = jax.lax.reduce_window(
                        jnp.ones_like(xin), 0.0, jax.lax.add,
                        dims, strides, padding)
                    env[n.outputs[0]] = summed / counts
                else:
                    env[n.outputs[0]] = summed / (k[0] * k[1])
        elif n.op_type == "Relu":
            env[n.outputs[0]] = jax.nn.relu(env[n.inputs[0]])
        elif n.op_type == "Softmax":
            env[n.outputs[0]] = jax.nn.softmax(env[n.inputs[0]], axis=int(n.attr("axis", -1)))
        elif n.op_type == "Gemm":
            a, w = env[n.inputs[0]], env[n.inputs[1]]
            if int(n.attr("transA", 0)):
                a = a.T
            if int(n.attr("transB", 0)):
                w = w.T
            out = a @ w
            if len(n.inputs) > 2:
                out = out + env[n.inputs[2]]
            env[n.outputs[0]] = out
        elif n.op_type == "MatMul":
            env[n.outputs[0]] = env[n.inputs[0]] @ env[n.inputs[1]]
        elif n.op_type == "Flatten":
            xin = env[n.inputs[0]]
            axis = int(n.attr("axis", 1))
            lead = int(np.prod(xin.shape[:axis])) if axis else 1
            env[n.outputs[0]] = xin.reshape(lead, -1)
        elif n.op_type == "Reshape":
            target = n.attr("shape") or env[n.inputs[1]].tolist()
            env[n.outputs[0]] = env[n.inputs[0]].reshape([int(t) for t in target])
        elif n.op_type == "Add":
            env[n.outputs[0]] = env[n.inputs[0]] + env[n.inputs[1]]
        elif n.op_type == "Concat":
            env[n.outputs[0]] = jnp.concatenate(
                [env[i] for i in n.inputs], axis=int(n.attr("axis", 1)))
        elif n.op_type in ("Dropout", "Identity"):
            env[n.outputs[0]] = env[n.inputs[0]]
        else:
            raise NotImplementedError(n.op_type)
    if return_env:
        return env
    return env[graph.outputs[0]]


def collect_activations(graph: Graph, x: np.ndarray) -> Dict[str, np.ndarray]:
    """Run float and keep every intermediate (for PTQ calibration)."""
    env = run_float(graph, jnp.asarray(x), return_env=True)
    return {k: np.asarray(v) for k, v in env.items()}
