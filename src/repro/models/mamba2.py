"""Mamba-2 block (state-space duality) [arXiv:2405.21060].

Training/prefill uses the chunked SSD decomposition (pure-JAX einsum
form here; the Pallas kernel in ``repro.kernels.ssd_scan`` is the TPU
fast path with identical math — both validated against the sequential
oracle).  Decode keeps the (H, P, N) SSM state + a (K-1)-deep causal
conv state: constant memory per sequence, which is why mamba archs run
the ``long_500k`` cell that full-attention archs must skip.

Weights are stored per component (z / x / B / C / dt) rather than as
one fused in_proj so tensor-parallel sharding can split d_inner and
heads on the model axis without slicing across component boundaries
(B/C are per-group and replicated; see repro/sharding.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


def init_mamba2(cfg: ModelConfig, key: jax.Array) -> Params:
    d, di = cfg.d_model, cfg.d_inner
    g, ns, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    ck = cfg.ssm_conv_kernel
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "w_z": (jax.random.normal(keys[0], (d, di)) * s).astype(dt),
        "w_x": (jax.random.normal(keys[1], (d, di)) * s).astype(dt),
        "w_b": (jax.random.normal(keys[2], (d, g * ns)) * s).astype(dt),
        "w_c": (jax.random.normal(keys[3], (d, g * ns)) * s).astype(dt),
        "w_dt": (jax.random.normal(keys[4], (d, nh)) * s).astype(dt),
        "conv_x": (jax.random.normal(keys[5], (ck, di)) * 0.1).astype(dt),
        "conv_b": (jnp.zeros((ck, g * ns))).astype(dt),
        "conv_c": (jnp.zeros((ck, g * ns))).astype(dt),
        "conv_bias_x": jnp.zeros((di,), dt),
        "conv_bias_b": jnp.zeros((g * ns,), dt),
        "conv_bias_c": jnp.zeros((g * ns,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "gate_norm": jnp.ones((di,), jnp.float32),
        "w_out": (jax.random.normal(keys[6], (di, d)) * di ** -0.5
                  ).astype(dt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d.  x: (B, L, C); w: (K, C).  ``state`` is
    the trailing K-1 inputs from the previous call (decode)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)          # (B, L+K-1, C)
    out = sum(xx[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xx[:, -(k - 1):, :] if k > 1 else state
    return out + b, new_state


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                b: jnp.ndarray, c: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None,
                unroll: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD, pure JAX (einsum + scan over chunk states).

    x (B,L,H,P) dt (B,L,H) a (H,) b/c (B,L,G,N) -> (y, final_state).
    Math identical to kernels/ssd_scan.py and to the sequential oracle.
    """
    B_, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    g = H // G
    q = min(chunk, L)
    pad = (-L) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lp = L + pad
    nc = lp // q
    xf = x.astype(jnp.float32).reshape(B_, nc, q, H, P)
    dtf = dt.astype(jnp.float32).reshape(B_, nc, q, H)
    bf = jnp.repeat(b.astype(jnp.float32), g, axis=2).reshape(B_, nc, q, H, N)
    cf = jnp.repeat(c.astype(jnp.float32), g, axis=2).reshape(B_, nc, q, H, N)

    logdec = jnp.cumsum(dtf * a[None, None, None, :], axis=2)  # (B,nc,q,H)
    tri = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])
    diff = logdec[:, :, :, None, :] - logdec[:, :, None, :, :]  # (B,nc,t,s,H)
    # mask BEFORE exp: masked entries have diff > 0 (logdec decreasing),
    # and exp(large)*0 in the cotangent would give inf*0 = NaN grads
    diff = jnp.where(tri[None, None, :, :, None], diff, 0.0)
    gmat = jnp.where(tri[None, None, :, :, None],
                     jnp.exp(diff) * dtf[:, :, None, :, :], 0.0)
    scores = jnp.einsum("bcthn,bcshn->bctsh", cf, bf) * gmat
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", scores, xf)

    # per-chunk boundary state and carried recurrence
    tail = jnp.exp(logdec[:, :, -1:, :] - logdec) * dtf       # (B,nc,q,H)
    s_chunk = jnp.einsum("bcqh,bcqhp,bcqhn->bchpn", tail, xf, bf)
    decay_chunk = jnp.exp(logdec[:, :, -1, :])                # (B,nc,H)

    def scan_fn(s_prev, inp):
        dchunk, schunk = inp
        s_new = dchunk[..., None, None] * s_prev + schunk
        return s_new, s_prev

    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((B_, H, P, N), jnp.float32))
    s_fin, s_prevs = jax.lax.scan(
        scan_fn, s0,
        (decay_chunk.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4)),
        unroll=unroll)
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)                # (B,nc,H,P,N)
    y_inter = jnp.exp(logdec)[..., None] * jnp.einsum(
        "bcqhn,bchpn->bcqhp", cf, s_prevs)

    y = (y_intra + y_inter).reshape(B_, lp, H, P)[:, :L]
    return y.astype(x.dtype), s_fin


def mamba2_forward(cfg: ModelConfig, p: Params, u: jnp.ndarray,
                   init_state: Optional[Dict[str, jnp.ndarray]] = None,
                   return_state: bool = False, policy=None):
    """Full block: proj -> causal conv -> SSD -> gated norm -> out_proj.
    u: (B, L, D).  Returns y (and new state when requested)."""
    B_, L, D = u.shape
    nh, hp = cfg.ssm_nheads, cfg.ssm_headdim
    g, ns = cfg.ssm_ngroups, cfg.ssm_state
    z = u @ p["w_z"]
    x = u @ p["w_x"]
    bmat = u @ p["w_b"]
    cmat = u @ p["w_c"]
    dtr = u @ p["w_dt"]
    if policy is not None:
        x, z = policy.mamba_inner(x), policy.mamba_inner(z)

    st = init_state or {}
    x, new_cx = _causal_conv(x, p["conv_x"], p["conv_bias_x"],
                             st.get("conv_x"))
    bmat, new_cb = _causal_conv(bmat, p["conv_b"], p["conv_bias_b"],
                                st.get("conv_b"))
    cmat, new_cc = _causal_conv(cmat, p["conv_c"], p["conv_bias_c"],
                                st.get("conv_c"))
    x, bmat, cmat = jax.nn.silu(x), jax.nn.silu(bmat), jax.nn.silu(cmat)

    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])   # (B,L,H)
    a = -jnp.exp(p["a_log"])                                       # (H,)
    xh = x.reshape(B_, L, nh, hp)
    bh = bmat.reshape(B_, L, g, ns)
    ch = cmat.reshape(B_, L, g, ns)
    y, s_fin = ssd_chunked(xh, dt, a, bh, ch, cfg.ssm_chunk,
                           st.get("ssm"), unroll=cfg.scan_unroll)
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, L, cfg.d_inner).astype(u.dtype)

    # gated RMSNorm (mamba2's norm_before_gate=False style)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + cfg.norm_eps) * p["gate_norm"]
    out = yf.astype(u.dtype) @ p["w_out"]
    if return_state:
        return out, {"conv_x": new_cx, "conv_b": new_cb, "conv_c": new_cc,
                     "ssm": s_fin}
    return out


def mamba2_decode_step(cfg: ModelConfig, p: Params, u: jnp.ndarray,
                       state: Dict[str, jnp.ndarray], policy=None
                       ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token recurrent step.  u: (B, 1, D)."""
    return mamba2_forward(cfg, p, u, init_state=state, return_state=True,
                          policy=policy)


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16
                     ) -> Dict[str, jnp.ndarray]:
    k = cfg.ssm_conv_kernel - 1
    gns = cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv_x": jnp.zeros((batch, k, cfg.d_inner), dtype),
        "conv_b": jnp.zeros((batch, k, gns), dtype),
        "conv_c": jnp.zeros((batch, k, gns), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim,
                          cfg.ssm_state), jnp.float32),
    }
