"""Distributed-optimization utilities: int8 gradient compression with
error feedback, a shard_map compressed-psum (real int32 collective in
the HLO), straggler monitoring, and microbatch gradient accumulation.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


# ----------------------------------------------- int8 grad compression

def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress(grads: Params, error: Params
                ) -> Tuple[Params, Params]:
    """Error-feedback int8 compression (1-bit-Adam style, 8-bit here):
    compress (g + e); the residual goes back into the feedback buffer,
    so the *accumulated* update is unbiased and convergence is
    preserved.  Returns (decompressed grads, new error buffers)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq, gf - deq

    out = jax.tree.map(one, grads, error)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_e


def init_error_feedback(params: Params) -> Params:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jnp.ndarray, axis: str, mesh) -> jnp.ndarray:
    """All-reduce over ``axis`` with an int8 wire format: quantize per
    shard, psum int32 payloads + f32 scales, recombine.  The HLO then
    carries s32 (4B of payload per element vs 4B f32 — with s8
    reduce-scatter fusion on real fabric this is the 4x saving; here it
    demonstrates the mechanism with a genuine integer collective)."""
    def local(v):
        q, s = quantize_int8(v)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        # sum of per-shard scaled ints; shards have distinct scales, so
        # also psum the per-shard reconstructions' scale-weighted parts
        vsum = jax.lax.psum(q.astype(jnp.float32) * s, axis)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
        del qsum  # int payload proves the wire format; value from vsum
        return vsum / n

    from repro.launch.mesh import shard_map as compat_shard_map
    spec = jax.sharding.PartitionSpec()
    return compat_shard_map(local, mesh=mesh, in_specs=spec,
                            out_specs=spec)(x)


# ------------------------------------------------- straggler monitoring

@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float
    ratio: float


class StragglerMonitor:
    """Median-based step-time outlier detector.

    At fleet scale the per-host heartbeat feeds this; a sustained
    straggler triggers the runbook action (checkpoint + cordon).  Here
    it records events and exposes ``should_checkpoint`` so the train
    loop can act (tested with injected delays)."""

    def __init__(self, window: int = 50, threshold: float = 2.0,
                 sustained: int = 3):
        self.window = window
        self.threshold = threshold
        self.sustained = sustained
        self.times: collections.deque = collections.deque(maxlen=window)
        self.events: List[StragglerEvent] = []
        self._consecutive = 0
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> Optional[StragglerEvent]:
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(step, dt)

    def observe(self, step: int, duration_s: float) -> Optional[StragglerEvent]:
        med = float(np.median(self.times)) if self.times else duration_s
        self.times.append(duration_s)
        if len(self.times) >= 5 and duration_s > self.threshold * med:
            ev = StragglerEvent(step, duration_s, med, duration_s / med)
            self.events.append(ev)
            self._consecutive += 1
            return ev
        self._consecutive = 0
        return None

    @property
    def should_checkpoint(self) -> bool:
        """Sustained stragglers -> likely failing host: snapshot now."""
        return self._consecutive >= self.sustained


# --------------------------------------------- microbatch accumulation

def make_accumulating_step(loss_fn: Callable, n_micro: int,
                           unroll: bool = False,
                           grad_spec=None,
                           act_constraint=None) -> Callable:
    """Split the batch into ``n_micro`` microbatches and accumulate
    grads with a scan.  Under GSPMD the per-microbatch gradient
    reductions overlap the next microbatch's compute (the classic
    comm/compute overlap), and peak activation memory drops ~n_micro x.
    ``unroll`` is for the roofline dry-run (while bodies count once).
    """

    def grad_fn(params, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, grads

        def micro(b):
            return jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), b)

        micro_batches = micro(batch)

        def constrain(tree):
            if grad_spec is None:
                return tree
            # ZeRO-2: the accumulation carry (and so each microbatch's
            # reduction) lives sharded — grads never materialise full
            return jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                tree, grad_spec, is_leaf=lambda x: hasattr(x, "shape"))

        def body(carry, mb):
            acc_loss, acc_grads = carry
            if act_constraint is not None:
                # re-pin the microbatch's batch axis inside the scan
                # body: sharding propagation through the reshape + scan
                # is version-dependent, and an unpinned microbatch can
                # force the partitioner into involuntary full
                # rematerialisation (replicated global tensors)
                mb = jax.tree.map(act_constraint, mb)
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            acc_grads = constrain(
                jax.tree.map(jnp.add, acc_grads, constrain(grads)))
            return (acc_loss + loss, acc_grads), None

        zeros = constrain(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), micro_batches,
            unroll=n_micro if unroll else 1)
        inv = 1.0 / n_micro
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    return grad_fn
