"""Banded concat-epilogue fusion: inception-class merges written
in-place by the conv kernels.

Tentpole claims pinned bit-for-bit:

  * the dense and depthwise band kernels' ``out_buf`` path — each
    producer writes its Cout tiles into a channel-offset slice of the
    shared merge buffer, applying its operand alignment shift and the
    merge's ReLU (and absorbed max-pool) in the producing epilogue — is
    exactly the standalone Conv -> Concat program, swept over ragged
    Cout tiles straddling a channel offset, stride-2 producers,
    per-channel requant, mismatched operand scales and fused-pool-
    after-concat ordering;
  * the parser fold pass annotates producers/offsets so that the fused
    and unfused programs are byte-identical at the spec level and
    bit-identical at the output, with every ineligible shape falling
    back to the standalone merge;
  * the fused executor contains no standalone ``concatenate`` op
    (probed in the jaxpr, the way the skip-fusion tests probe the
    int add).

Plus satellites: offsets exactly partition the merge Cout (property
test), alias-resolved concat operands, the depthwise channel-multiplier
and grouped band kernels, and the working-set model's single-charge /
zero-charge concat rules.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import parser as P
from repro.core import pipeline as pipe
from repro.core import verify as V
from repro.core.graph import Graph, Node
from repro.core.resources import conv_band_working_set
from repro.core.synthesis import CNN2Gate
from repro.kernels import ref
from repro.kernels.qconv import (dw_vmem_bytes, gconv_vmem_bytes, qconv2d,
                                 qdwconv2d, qgconv2d)
from repro.models import cnn

RNG = np.random.default_rng(31)


def i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, shape, np.int8))


def i32(*shape):
    return jnp.asarray(RNG.integers(-500, 500, shape, np.int32))


# ------------------------------------------------- kernel parity matrix

def _oracle_concat(parts, shifts, relu, pool):
    """The unfused program: every producer conv writes its own int8
    tensor, the Concat stage aligns + merges them, a trailing max-pool
    runs after the merge (graph order Concat -> ReLU -> MaxPool)."""
    ys = [ref.qconv2d_ref(x, w, b, strides, shift, prelu, None)
          for (x, w, b, strides, shift, prelu) in parts]
    merged = ref.qconcat_ref(ys, shifts, axis=-1, relu=relu)
    if pool is not None:
        merged = ref.maxpool2d_ref(merged, pool[0], pool[1])
    return merged


def _fused_concat(parts, shifts, relu, pool, block_cout, block_h):
    """The fused program: one shared merge buffer, each producer writes
    its channel slice in place (offsets accumulate in operand order)."""
    x0, w0, b0, strides0, _, _ = parts[0]
    k = w0.shape[0]
    ho = (x0.shape[1] - k) // strides0[0] + 1
    wo = (x0.shape[2] - k) // strides0[1] + 1
    if pool is not None:
        ho = (ho - pool[0]) // pool[1] + 1
        wo = (wo - pool[0]) // pool[1] + 1
    ctot = sum(p[1].shape[-1] for p in parts)
    buf = jnp.zeros((x0.shape[0], ho, wo, ctot), jnp.int8)
    off = 0
    for (x, w, b, strides, shift, prelu), s in zip(parts, shifts):
        buf = qconv2d(x, w, b, strides=strides, shift=shift, relu=prelu,
                      pool=pool, block_cout=block_cout, block_h=block_h,
                      out_buf=buf, out_off=off, concat_shift=s,
                      concat_relu=relu, interpret=True)
        off += w.shape[-1]
    return buf


@pytest.mark.parametrize("cfg", [
    # (h, couts, k, stride, pool, block_cout, block_h)
    (14, (8, 8), 3, 1, None, 8, 4),        # tile-aligned offsets
    (14, (5, 7, 6), 3, 1, None, 4, 3),     # ragged tiles straddle offsets
    (15, (6, 10), 3, 2, None, 8, 2),       # stride-2 producers
    (14, (5, 7), 3, 1, (2, 2), 4, 2),      # pool absorbed after concat
    (19, (9, 7, 8), 3, 1, (3, 2), 16, 3),  # overlapping pool + one tile
])
@pytest.mark.parametrize("shifts_relu", [
    ((0, 0, 0), False),      # aligned operands, plain concat
    ((2, 0, 1), True),       # mismatched scales + merge ReLU
])
def test_concat_fused_kernel_matches_standalone(cfg, shifts_relu):
    h, couts, k, stride, pool, bco, bh = cfg
    shifts, relu = shifts_relu
    shifts = shifts[:len(couts)]
    cin = 6
    x = i8(2, h, h, cin)
    parts = [(x, i8(k, k, cin, c), i32(c), (stride, stride), 4, False)
             for c in couts]
    got = _fused_concat(parts, shifts, relu, pool, bco, bh)
    want = _oracle_concat(parts, shifts, relu, pool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_concat_fused_per_channel_producer():
    """A per-channel-quantized producer (tuple shift) writes its slice
    of the merge buffer through the same epilogue."""
    cin, c1, c2 = 6, 5, 7
    x = i8(2, 12, 12, cin)
    shift_vec = tuple(int(s) for s in RNG.integers(2, 6, c1))
    parts = [(x, i8(3, 3, cin, c1), i32(c1), (1, 1), shift_vec, True),
             (x, i8(3, 3, cin, c2), i32(c2), (1, 1), 4, True)]
    got = _fused_concat(parts, (1, 0), False, None, 4, 3)
    ys = [ref.qconv2d_ref(x, w, b, st, sh, rl, None)
          for (x, w, b, st, sh, rl) in parts]
    want = ref.qconcat_ref(ys, (1, 0), axis=-1, relu=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_concat_fused_preserves_sibling_slices():
    """Writing one producer's slice must not disturb channels already
    written by a sibling — probed with a sentinel-filled buffer."""
    cin, c1 = 4, 6
    x = i8(1, 10, 10, cin)
    w, b = i8(3, 3, cin, c1), i32(c1)
    buf = jnp.full((1, 8, 8, 16), 77, jnp.int8)
    out = qconv2d(x, w, b, strides=(1, 1), shift=4, relu=False,
                  block_cout=4, block_h=3, out_buf=buf, out_off=5,
                  interpret=True)
    out = np.asarray(out)
    assert np.all(out[..., :5] == 77) and np.all(out[..., 11:] == 77)
    want = np.asarray(ref.qconv2d_ref(x, w, b, (1, 1), 4, False, None))
    np.testing.assert_array_equal(out[..., 5:11], want)


# --------------------------------- depthwise multiplier / skip / concat

def _dw_ref(x, w, b, strides, shift, relu, pool, m):
    """ONNX depthwise with integer channel multiplier: output channel c
    convolves input channel c // m."""
    cout = w.shape[-1]
    return ref.qconv2d_ref(x, w[:, :, None, :], b, strides, shift, relu,
                           pool, groups=cout // m)


@pytest.mark.parametrize("m", [1, 2, 4])
@pytest.mark.parametrize("pool", [None, (2, 2)])
def test_dwconv_channel_multiplier_matches_ref(m, pool):
    cin = 6
    cout = m * cin
    x, w, b = i8(2, 13, 13, cin), i8(3, 3, cout), i32(cout)
    got = qdwconv2d(x, w, b, strides=(1, 1), shift=4, relu=True,
                    pool=pool, block_c=4 * m, block_h=3, interpret=True)
    want = _dw_ref(x, w, b, (1, 1), 4, True, pool, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dwconv_skip_epilogue_matches_two_stage():
    """The depthwise kernel's new fused residual merge == the unfused
    DwConv -> Add program (the dense kernel's epilogue semantics)."""
    cin = 8
    x, w, b = i8(2, 12, 12, cin), i8(3, 3, cin), i32(cin)
    skip = i8(2, 10, 10, cin)
    got = qdwconv2d(x, w, b, strides=(1, 1), shift=4, relu=False,
                    block_c=4, block_h=3, skip=skip, skip_shifts=(2, 0),
                    merge_shift=1, merge_relu=True, interpret=True)
    y1 = _dw_ref(x, w, b, (1, 1), 4, False, None, 1)
    want = ref.qadd_ref([y1, skip], (2, 0), shift=1, relu=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dwconv_concat_out_buf_matches_standalone():
    """A depthwise producer (m = 2) and a dense producer sharing one
    merge buffer == the standalone Concat over both outputs."""
    cin, m = 4, 2
    cdw, cdense = m * cin, 6
    x = i8(2, 11, 11, cin)
    wd, bd = i8(3, 3, cdw), i32(cdw)
    wc, bc = i8(3, 3, cin, cdense), i32(cdense)
    buf = jnp.zeros((2, 9, 9, cdw + cdense), jnp.int8)
    buf = qdwconv2d(x, wd, bd, strides=(1, 1), shift=4, relu=False,
                    block_c=2 * m, block_h=4, out_buf=buf, out_off=0,
                    concat_shift=1, concat_relu=True, interpret=True)
    buf = qconv2d(x, wc, bc, strides=(1, 1), shift=5, relu=False,
                  block_cout=4, block_h=4, out_buf=buf, out_off=cdw,
                  concat_shift=0, concat_relu=True, interpret=True)
    ys = [_dw_ref(x, wd, bd, (1, 1), 4, False, None, m),
          ref.qconv2d_ref(x, wc, bc, (1, 1), 5, False, None)]
    want = ref.qconcat_ref(ys, (1, 0), axis=-1, relu=True)
    np.testing.assert_array_equal(np.asarray(buf), np.asarray(want))


@pytest.mark.parametrize("groups,cin,cout", [(2, 8, 12), (3, 9, 6)])
def test_ragged_grouped_conv_matches_ref(groups, cin, cout):
    """qgconv2d (group on its own grid axis) == the grouped oracle."""
    x = i8(2, 12, 12, cin)
    w, b = i8(3, 3, cin // groups, cout), i32(cout)
    got = qgconv2d(x, w, b, groups=groups, strides=(1, 1), shift=4,
                   relu=True, pool=(2, 2), block_h=3, interpret=True)
    want = ref.qconv2d_ref(x, w, b, (1, 1), 4, True, (2, 2),
                           groups=groups)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------ parser fold pass

def _two_branch(name="cc2", c1=8, c2=8, fanout=False):
    b = cnn.GraphBuilder(name, (1, 3, 12, 12), 7)
    b.conv(8, 3, pad=1)
    split = b.tap()
    b.conv(c1, 1, relu=False)
    left = b.tap()
    b.from_tap(split).conv(c2, 3, pad=1, relu=False)
    right = b.tap()
    if fanout:  # second consumer of the right operand (output dangles)
        b.from_tap(right).conv(4, 1)
    b.from_tap(left).concat_from(right)
    b.global_avgpool()
    b.fc(3, relu=False, softmax=True)
    return b.build()


def test_fold_annotates_producers_and_offsets():
    pm = P.parse(_two_branch(c1=5, c2=7))
    cc = next(li for li in pm.layers if li.kind == P.CONCAT)
    assert cc.concat_fused
    prods = [li for li in pm.layers if li.concat is cc]
    assert [p.concat_offset for p in prods] == [0, 5]
    assert sum(p.c_out for p in prods) == cc.c_out == 12


def test_fold_keeps_concat_stage_scheduled():
    """The Concat stays in the schedule (it is the merge tensor's
    binding point), so fused/unfused stage names line up 1:1 apart from
    any absorbed pool."""
    pm_f = P.parse(_two_branch())
    pm_u = P.parse(_two_branch(), fuse_concat=False)
    assert [li.name for li in pm_f.layers] == [li.name for li in pm_u.layers]


def test_fanout_operand_not_folded():
    """An operand that also feeds another consumer must stay
    addressable — the whole concat falls back to the standalone merge."""
    pm = P.parse(_two_branch(fanout=True))
    cc = next(li for li in pm.layers if li.kind == P.CONCAT)
    assert not cc.concat_fused
    assert not any(li.concat is not None for li in pm.layers)


def test_nonconv_operand_not_folded():
    """An operand produced by a standalone pool (not a band-kernel
    conv) makes the whole concat fall back to the standalone merge."""
    b = cnn.GraphBuilder("ccpoolop", (1, 3, 12, 12), 7)
    b.conv(8, 3, pad=1)
    split = b.tap()
    b.conv(8, 1, relu=False)
    left = b.tap()
    b.from_tap(split).maxpool(3, 1, pad=1)   # same spatial geometry
    right = b.tap()
    b.from_tap(left).concat_from(right)
    b.global_avgpool()
    b.fc(3, relu=False, softmax=True)
    pm = P.parse(b.build())
    cc = next(li for li in pm.layers if li.kind == P.CONCAT)
    assert not cc.concat_fused
    assert not any(li.concat is not None for li in pm.layers)


def test_pooled_producer_not_folded():
    """A producer with its own fused pool is ineligible (its epilogue
    already pools; the merge cannot ride the same tail)."""
    b = cnn.GraphBuilder("ccpool", (1, 3, 12, 12), 7)
    b.conv(8, 3, pad=1)
    split = b.tap()
    b.conv(8, 3, pad=1, relu=False)
    b.maxpool(2, 2)
    left = b.tap()
    b.from_tap(split).conv(8, 2, stride=2, relu=False)
    right = b.tap()
    b.from_tap(left).concat_from(right)
    b.global_avgpool()
    b.fc(3, relu=False, softmax=True)
    pm = P.parse(b.build())
    cc = next(li for li in pm.layers if li.kind == P.CONCAT)
    assert not cc.concat_fused


def test_absorbed_pool_after_concat():
    """Concat -> MaxPool collapses: the pool runs in every producer's
    epilogue and the shared buffer takes the pooled geometry."""
    b = cnn.GraphBuilder("ccpool2", (1, 3, 12, 12), 7)
    b.conv(8, 3, pad=1)
    split = b.tap()
    b.conv(6, 1, relu=False)
    left = b.tap()
    b.from_tap(split).conv(6, 3, pad=1, relu=False)
    right = b.tap()
    b.from_tap(left).concat_from(right)
    b.maxpool(2, 2)
    b.global_avgpool()
    b.fc(3, relu=False, softmax=True)
    pm = P.parse(b.build())
    cc = next(li for li in pm.layers if li.kind == P.CONCAT)
    assert cc.concat_fused and cc.pool is not None
    assert cc.out_shape[2:] == (6, 6)
    assert not any(li.kind == P.POOL and li.pool_type == "max"
                   for li in pm.layers)


def test_elided_op_between_branch_and_merge_still_folds():
    """A single-consumer Dropout between a branch conv and the Concat is
    absorbed into the conv's stage (output renamed); the fold must still
    see the conv as the operand's producer and annotate it."""
    g = _two_branch()
    cat = next(n for n in g.nodes if n.op_type == "Concat")
    t = cat.inputs[1]
    nodes = list(g.nodes)
    nodes.insert(nodes.index(cat),
                 Node("Dropout", "drop0", [t], [t + "_drop"]))
    cat.inputs = [cat.inputs[0], t + "_drop"]
    g2 = Graph(g.name, nodes, g.inputs, g.outputs, g.initializers)
    pm = P.parse(g2)
    cc = next(li for li in pm.layers if li.kind == P.CONCAT)
    assert cc.concat_fused
    prods = [li for li in pm.layers if li.concat is cc]
    assert len(prods) == 2 and prods[1].output == cc.inputs[1]


def test_alias_resolved_operand_reads_canonical_tensor():
    """An Identity behind a fan-out tensor is NOT absorbed — it lands in
    the alias map, and the Concat's operand must canonicalise through it
    (the fold then correctly declines: the operand fans out) so the
    standalone merge reads a tensor that actually exists at runtime."""
    b = cnn.GraphBuilder("ccalias", (1, 3, 10, 10), 7)
    b.conv(8, 3, pad=1, relu=True)
    split = b.tap()
    b.conv(8, 1, relu=False)
    left = b.tap()
    b.from_tap(split).conv(8, 3, pad=1, relu=False)
    right = b.tap()
    b.from_tap(left).concat_from(right, split)
    b.global_avgpool()
    b.fc(3, relu=False, softmax=True)
    g = b.build()
    cat = next(n for n in g.nodes if n.op_type == "Concat")
    t = cat.inputs[2]             # the fan-out split tensor
    nodes = list(g.nodes)
    nodes.insert(nodes.index(cat),
                 Node("Identity", "id0", [t], [t + "_id"]))
    cat.inputs = cat.inputs[:2] + [t + "_id"]
    g2 = Graph(g.name, nodes, g.inputs, g.outputs, g.initializers)
    pm = P.parse(g2)
    cc = next(li for li in pm.layers if li.kind == P.CONCAT)
    assert cc.inputs[2] == t      # canonicalised through the alias
    assert not cc.concat_fused    # split operand fans out: no fold
    x = np.random.default_rng(9).standard_normal(
        g2.inputs[0].shape).astype(np.float32)
    gate = CNN2Gate.from_graph(g2)
    gate.calibrate_quantization(x)
    y = pipe.run_int8(gate.quantized, x)  # env lookup hits the real tensor
    assert y.shape == (1, 3)


# ------------------------------- offsets partition the merge (property)

def _offsets_partition(couts):
    b = cnn.GraphBuilder("prop", (1, 3, 8, 8), 11)
    b.conv(4, 3, pad=1)
    split = b.tap()
    taps = []
    for c in couts:
        b.from_tap(split).conv(int(c), 1, relu=False)
        taps.append(b.tap())
    b.from_tap(taps[0]).concat_from(*taps[1:])
    b.global_avgpool()
    b.fc(2, relu=False, softmax=True)
    pm = P.parse(b.build())
    cc = next(li for li in pm.layers if li.kind == P.CONCAT)
    assert cc.concat_fused
    prods = [(li.concat_offset, li.c_out)
             for li in pm.layers if li.concat is cc]
    prods.sort()
    cursor = 0
    for off, c in prods:
        assert off == cursor  # contiguous, in operand order
        cursor += c
    assert cursor == cc.c_out


@given(st.lists(st.integers(1, 9), min_size=2, max_size=5))
@settings(max_examples=20, deadline=None)
def test_offsets_exactly_partition_merge_cout(couts):
    _offsets_partition(couts)


@pytest.mark.parametrize("seed", range(6))
def test_offsets_partition_seeded(seed):
    """Deterministic stand-in for the property test (always runs, even
    where hypothesis is stubbed out by conftest)."""
    rng = np.random.default_rng(seed)
    couts = rng.integers(1, 10, rng.integers(2, 6)).tolist()
    _offsets_partition(couts)


# --------------------------------------------------- end-to-end parity

@pytest.mark.parametrize("build", [cnn.googlenet_tiny, cnn.squeezenet_tiny])
def test_model_fused_matches_unfused_bit_exact(build):
    """The acceptance gate: every eligible concat fused, and the single
    jitted closure is bit-identical to the standalone-merge program."""
    g = build(batch=2)
    x = np.random.default_rng(3).standard_normal(
        g.inputs[0].shape).astype(np.float32)
    gate = CNN2Gate.from_graph(g)
    gate.calibrate_quantization(x)
    pm_f = gate.parsed
    ccs = [li for li in pm_f.layers if li.kind == P.CONCAT]
    assert ccs and all(cc.concat_fused for cc in ccs)
    pm_u = P.parse(g, fuse_concat=False)
    y_f = pipe.run_int8(gate.quantized, x)
    y_u = pipe.run_int8(pipe.build_quantized(pm_u, gate.specs), x)
    assert jnp.array_equal(y_f, y_u)


def test_fused_closure_lowers_at_any_batch():
    """The merge buffer takes its batch from the traced activation, not
    the parse-time shape — the fused closure must run at a batch other
    than the one the graph was built with (fullflow compiles a
    batch-1 sample)."""
    g = cnn.squeezenet_tiny(batch=2)
    rng = np.random.default_rng(11)
    x2 = rng.standard_normal(g.inputs[0].shape).astype(np.float32)
    gate = CNN2Gate.from_graph(g)
    gate.calibrate_quantization(x2)
    x3 = rng.standard_normal((3,) + g.inputs[0].shape[1:]).astype(
        np.float32)
    y_f = pipe.run_int8(gate.quantized, x3)
    qm_u = pipe.build_quantized(P.parse(g, fuse_concat=False), gate.specs)
    assert jnp.array_equal(y_f, pipe.run_int8(qm_u, x3))


def test_specs_byte_identical_fused_vs_unfused():
    """calibrate_quantization must emit the SAME specs for both
    programs — the concat keeps its name, operand tensors and relu, so
    scale threading never sees the fusion."""
    g = cnn.googlenet_tiny(batch=1)
    x = np.random.default_rng(5).standard_normal(
        g.inputs[0].shape).astype(np.float32)
    gate_f = CNN2Gate.from_graph(g)
    gate_f.calibrate_quantization(x)
    gate_u = CNN2Gate.from_graph(g, fuse_concat=False)
    gate_u.calibrate_quantization(x)
    assert gate_f.specs == gate_u.specs
    mf = pipe.thread_scales(gate_f.parsed, gate_f.specs)
    mu = pipe.thread_scales(gate_u.parsed, gate_u.specs)
    assert all(mu[t] == m for t, m in mf.items())
    # the only tensors the fused threading lacks are pre-pool concat
    # intermediates absorbed into the merge (pool is scale-transparent)
    absorbed = {cc.name + "_out" for cc in gate_f.parsed.layers
                if cc.kind == P.CONCAT and cc.pool is not None}
    assert set(mu) - set(mf) == absorbed and absorbed


# -------------------------------------- jaxpr: no standalone concat op
# (the probe is the verifier's reusable concat_eqns — one walker for
# this file, test_skip_fusion, and the QV502 CLI probe)

def test_fused_program_has_no_standalone_concat():
    g = cnn.squeezenet_tiny(batch=1)
    x = np.random.default_rng(7).standard_normal(
        g.inputs[0].shape).astype(np.float32)
    gate = CNN2Gate.from_graph(g)
    gate.calibrate_quantization(x)
    ex_f = pipe.make_executor(gate.quantized, interpret=True)
    assert V.concat_eqns(jax.make_jaxpr(ex_f)(jnp.asarray(x)).jaxpr) == 0
    # ...and the QV502 probe agrees wholesale
    assert V.structural_probes(gate.quantized) == []
    # ...and the unfused program DOES concatenate (the probe is valid)
    gate_u = CNN2Gate.from_graph(g, fuse_concat=False)
    gate_u.apply_quantization(gate.specs)
    ex_u = pipe.make_executor(gate_u.quantized, interpret=True)
    assert V.concat_eqns(jax.make_jaxpr(ex_u)(jnp.asarray(x)).jaxpr) > 0


# ------------------------------------------------- working-set model

def test_standalone_concat_charged_once_per_merge():
    """The concat merge buffer is charged once per merge tensor (its
    operand slices partition the output band), unlike an Add whose
    operands stack on top of the output."""
    pm = P.parse(cnn.googlenet_tiny(batch=1), fuse_concat=False)
    cc = next(li for li in pm.layers if li.kind == P.CONCAT)
    _n, c, _h, w = cc.out_shape
    bh = 2
    band = bh * w * c
    only_cc = conv_band_working_set([cc], 1, bh)
    assert only_cc == band * (1 + 4 + 1)    # NOT (n_ops + 4 + 1)
    add = P.LayerInfo(kind=P.ADD, name="a", inputs=["x", "y"],
                      output="a_out", weight=None, bias=None,
                      in_shape=cc.out_shape, out_shape=cc.out_shape,
                      kernel_shape=(0, 0), strides=(1, 1),
                      pads=(0, 0, 0, 0), dilations=(1, 1))
    assert conv_band_working_set([add], 1, bh) == band * (2 + 4 + 1)


def test_fused_concat_charges_zero():
    """A fused concat stage adds nothing: the slices live in the
    producers' own output bands, so the fused program's peak never
    exceeds the unfused one."""
    pm_f = P.parse(cnn.googlenet_tiny(batch=1))
    pm_u = P.parse(cnn.googlenet_tiny(batch=1), fuse_concat=False)
    ccs_f = [li for li in pm_f.layers if li.kind == P.CONCAT]
    assert all(cc.concat_fused for cc in ccs_f)
    assert conv_band_working_set(ccs_f, 2, 2) == 0
    ws_f = conv_band_working_set(pm_f.layers, 2, 2)
    ws_u = conv_band_working_set(pm_u.layers, 2, 2)
    assert 0 < ws_f <= ws_u


def test_dw_multiplier_and_grouped_working_set():
    """The dw estimate's input band shrinks with the multiplier; the
    grouped estimate is banded per group, far below the old whole-plane
    reference charge."""
    base = dw_vmem_bytes(14, 32, 3, 3, 8, 12, 12, block_h=4)
    m4 = dw_vmem_bytes(14, 32, 3, 3, 8, 12, 12, block_h=4, multiplier=4)
    assert m4 < base
    hp = wp = 26
    cin, cout, groups, oh = 16, 16, 2, 24
    whole_plane = (hp * wp * cin + 3 * 3 * (cin // groups) * cout
                   + 4 * oh * oh * cout + oh * oh * cout + 4)
    banded = gconv_vmem_bytes(wp, cin // groups, cout // groups,
                              3, 3, oh, oh, block_h=4)
    assert banded < whole_plane // 4
