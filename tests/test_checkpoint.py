"""Checkpointing: roundtrip, atomicity, resume, elastic reshard, GC."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import checkpoint as ckpt


def make_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((8, 16)),
                                    jnp.float32),
                   "b": jnp.asarray(rng.standard_normal(16), jnp.bfloat16)},
        "opt": {"mu": {"w": jnp.zeros((8, 16)), "b": jnp.ones(16)}},
        "step": jnp.asarray(42, jnp.int32),
    }


def assert_tree_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x, dtype=np.float32), np.asarray(y, dtype=np.float32)),
        a, b)


def test_roundtrip(tmp_path):
    tree = make_tree()
    ckpt.save(str(tmp_path), 42, tree, extra={"note": "hi"})
    skel = jax.tree.map(np.zeros_like, tree)
    restored, step, extra = ckpt.restore(str(tmp_path), skel)
    assert step == 42 and extra["note"] == "hi"
    assert_tree_equal(tree, restored)
    # dtype preservation (bf16 leaf)
    assert np.asarray(restored["params"]["b"]).dtype == jnp.bfloat16


def test_latest_pointer_and_resume(tmp_path):
    t1, t2 = make_tree(1), make_tree(2)
    ckpt.save(str(tmp_path), 10, t1)
    ckpt.save(str(tmp_path), 20, t2)
    assert ckpt.latest_step(str(tmp_path)) == 20
    restored, step, _ = ckpt.restore(str(tmp_path),
                                     jax.tree.map(np.zeros_like, t2))
    assert step == 20
    assert_tree_equal(t2, restored)


def test_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"w": np.zeros((8, 4))})


def test_elastic_reshard_across_mesh_sizes(tmp_path):
    """Save under one mesh, restore under a different sharding — the
    manifest stores global shapes, so any target works."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    if len(jax.devices()) < 2:
        mesh1 = jax.make_mesh((1,), ("data",))
        mesh2 = jax.make_mesh((1,), ("data",))
    else:
        mesh1 = jax.make_mesh((2,), ("data",))
        mesh2 = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    sharded = jax.device_put(
        tree["w"], NamedSharding(mesh1, P("data", None)))
    ckpt.save(str(tmp_path), 5, {"w": sharded})
    target = {"w": NamedSharding(mesh2, P(None, None))}
    restored, step, _ = ckpt.restore(str(tmp_path),
                                     {"w": np.zeros((8, 8), np.float32)},
                                     shardings=target)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_gc_keeps_newest(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, {"x": jnp.zeros(3)})
    removed = ckpt.gc_old(str(tmp_path), keep=2)
    assert len(removed) == 3
    assert ckpt.latest_step(str(tmp_path)) == 5
    remaining = sorted(d for d in os.listdir(tmp_path)
                       if d.startswith("step_"))
    assert remaining == ["step_00000004", "step_00000005"]


def test_async_checkpointer(tmp_path):
    ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = make_tree(3)
    ac.save_async(7, tree)
    ac.wait()
    restored, step, _ = ckpt.restore(str(tmp_path),
                                     jax.tree.map(np.zeros_like, tree))
    assert step == 7
    assert_tree_equal(tree, restored)


def test_async_snapshot_isolated_from_mutation(tmp_path):
    """The async save must snapshot values at call time."""
    ac = ckpt.AsyncCheckpointer(str(tmp_path))
    x = np.ones((1000, 100), np.float32)
    tree = {"x": x}
    ac.save_async(1, tree)
    x *= 0.0  # mutate after snapshot
    ac.wait()
    restored, _, _ = ckpt.restore(str(tmp_path),
                                  {"x": np.zeros((1000, 100), np.float32)})
    assert np.all(np.asarray(restored["x"]) == 1.0)


def test_train_resume_equivalence(tmp_path):
    """Training N steps == training k, checkpoint, restore, train N-k
    (deterministic data pipeline + exact state checkpoint)."""
    from repro import configs
    from repro.models.model import Model
    from repro.optim import (OptimizerConfig, init_train_state,
                             make_train_step)
    from repro.data.pipeline import DataConfig, make_source

    cfg = configs.get_smoke("qwen2-1.5b")
    model = Model(cfg)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=2)
    data = make_source(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=4, seed=3))
    step_fn = jax.jit(make_train_step(model, opt))

    def run(state, a, b):
        for s in range(a, b):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
            state, m = step_fn(state, batch)
        return state, float(m["loss"])

    s0 = init_train_state(model, jax.random.key(0), opt)
    full, loss_full = run(s0, 0, 6)

    s0b = init_train_state(model, jax.random.key(0), opt)
    mid, _ = run(s0b, 0, 3)
    ckpt.save(str(tmp_path), 3, mid)
    restored, step, _ = ckpt.restore(str(tmp_path), mid)
    restored = jax.tree.map(jnp.asarray, restored)
    resumed, loss_resumed = run(restored, 3, 6)
    assert abs(loss_full - loss_resumed) < 1e-5
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-5, atol=2e-6),
        full["params"], resumed["params"])
