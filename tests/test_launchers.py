"""Integration tests: the training and serving drivers end-to-end."""
import json

import numpy as np
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_loss_decreases(tmp_path):
    metrics = tmp_path / "m.json"
    rc = train_mod.main([
        "--arch", "qwen2-1.5b", "--preset", "smoke",
        "--steps", "40", "--seq-len", "32", "--global-batch", "8",
        "--lr", "5e-3", "--warmup", "5",
        "--metrics-out", str(metrics)])
    assert rc == 0
    log = json.loads(metrics.read_text())
    first = np.mean([m["loss"] for m in log[:5]])
    last = np.mean([m["loss"] for m in log[-5:]])
    assert last < first * 0.9, (first, last)


def test_train_checkpoint_resume(tmp_path):
    ckpt_dir = tmp_path / "ckpt"
    args = ["--arch", "qwen2-1.5b", "--preset", "smoke",
            "--seq-len", "32", "--global-batch", "4",
            "--ckpt-dir", str(ckpt_dir), "--ckpt-every", "5"]
    rc = train_mod.main(args + ["--steps", "10"])
    assert rc == 0
    from repro import checkpoint as ckpt
    assert ckpt.latest_step(str(ckpt_dir)) == 10
    # resume continues from step 10, runs 5 more
    rc = train_mod.main(args + ["--steps", "15"])
    assert rc == 0
    assert ckpt.latest_step(str(ckpt_dir)) == 15


def test_train_with_grad_compression(tmp_path):
    metrics = tmp_path / "m.json"
    rc = train_mod.main([
        "--arch", "qwen2-1.5b", "--preset", "smoke",
        "--steps", "30", "--seq-len", "32", "--global-batch", "8",
        "--lr", "5e-3", "--warmup", "5",
        "--grad-compression", "int8_ef",
        "--metrics-out", str(metrics)])
    assert rc == 0
    log = json.loads(metrics.read_text())
    assert log[-1]["loss"] < log[0]["loss"]


def test_serving_engine_completes_all_requests(capsys):
    rc = serve_mod.main(["--arch", "qwen2-1.5b", "--preset", "smoke",
                         "--slots", "3", "--requests", "5",
                         "--prompt-len", "4", "--max-new", "6",
                         "--cache-len", "64"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "served 5 requests" in out


def test_serving_deterministic_outputs():
    """Two runs with the same seed produce identical generations."""
    import io
    from contextlib import redirect_stdout
    outs = []
    for _ in range(2):
        buf = io.StringIO()
        with redirect_stdout(buf):
            serve_mod.main(["--arch", "qwen2-1.5b", "--preset", "smoke",
                            "--slots", "2", "--requests", "3",
                            "--prompt-len", "4", "--max-new", "4",
                            "--cache-len", "32", "--seed", "7"])
        outs.append(buf.getvalue().split("served")[1].split(" in")[0])
    assert outs[0] == outs[1]


def test_serving_sheds_load_past_queue_bound(capsys):
    """Admission control: submissions past --max-queue are rejected
    (marked done, counted) instead of growing the queue without limit;
    the admitted requests still complete."""
    rc = serve_mod.main(["--arch", "qwen2-1.5b", "--preset", "smoke",
                         "--slots", "1", "--requests", "6",
                         "--max-queue", "2",
                         "--prompt-len", "4", "--max-new", "4",
                         "--cache-len", "32"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "served 6 requests" in out
    assert "rejected=4" in out


def test_server_guard_outcome_counters():
    """ISSUE satellite: a deployment reports each guarded inference's
    GuardReport into the server; the per-outcome counters surface in
    the stats payload next to the admission counters."""
    from repro.core.guard import ActionResult, GuardReport

    class _StubModel:
        def init_cache(self, slots, cache_len):
            return None

        def decode_step(self, params, cache, lengths, tokens):
            raise NotImplementedError

    srv = serve_mod.Server(_StubModel(), params=None, slots=2,
                           cache_len=8)
    clean = GuardReport(flagged=[], audits=[], actions=[],
                        recovered_by=None, degraded=False, ok=True)
    replayed = GuardReport(
        flagged=["conv_10"], audits=[],
        actions=[ActionResult("checkpoint_replay", [], replayed=4,
                              boundary="conv_8")],
        recovered_by="checkpoint_replay", degraded=False, ok=True)
    lost = GuardReport(flagged=["conv_1"], audits=[], actions=[],
                       recovered_by=None, degraded=True, ok=False)
    assert srv.record_guard_report(clean) == "clean"
    assert srv.record_guard_report(replayed) == "checkpoint_replayed"
    assert srv.record_guard_report(lost) == "unrecovered"
    srv.record_guard_report("masked")  # offline campaign verdict
    srv.record_guard_report("masked")
    stats = srv.stats()
    assert set(stats) == {"rejected", "expired", "queued", "active",
                          "guard", "latency_s", "tokens",
                          "tokens_per_s"}
    assert stats["guard"] == {"clean": 1, "checkpoint_replayed": 1,
                              "reexecuted": 0, "fell_back": 0,
                              "unrecovered": 1, "masked": 2}
    with pytest.raises(ValueError, match="unknown guard outcome"):
        srv.record_guard_report("exploded")


def test_serving_reports_latency_percentiles(capsys):
    """ISSUE satellite: the serving summary surfaces p50/p95/p99 request
    latency and tokens/s from the telemetry histogram."""
    from repro.core import telemetry as tele

    tele.reset()
    try:
        rc = serve_mod.main(["--arch", "qwen2-1.5b", "--preset", "smoke",
                             "--slots", "2", "--requests", "3",
                             "--prompt-len", "4", "--max-new", "4",
                             "--cache-len", "32"])
        assert rc == 0
        out = capsys.readouterr().out
        assert "latency: p50=" in out and "p99=" in out
        assert "tokens/s=" in out
        snap = tele.get_registry().snapshot()
        hist = snap["histograms"]["serve.request_latency_s"]
        assert hist["count"] == 3
        assert hist["p50"] is not None
        assert snap["counters"]["serve.completed"] == 3
        # each completed request produced a span
        reqs = [e for e in tele.get_tracer().events()
                if e["name"].startswith("serve.request:")]
        assert len(reqs) == 3
        assert all(e["args"]["outcome"] == "completed" for e in reqs)
    finally:
        tele.reset()


def test_serving_drops_expired_requests(capsys):
    """A zero deadline expires every queued request at admission time;
    the engine drains without serving a single token."""
    rc = serve_mod.main(["--arch", "qwen2-1.5b", "--preset", "smoke",
                         "--slots", "2", "--requests", "4",
                         "--deadline-s", "0",
                         "--prompt-len", "4", "--max-new", "4",
                         "--cache-len", "32"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "served 4 requests, 0 tokens" in out
    assert "expired=4" in out
