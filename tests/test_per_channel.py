"""Per-output-channel power-of-two quantization (DESIGN.md §8).

Three layers of guarantees:

  * **kernel parity** — the per-lane shift-vector epilogues of the
    dense band kernel, the depthwise band kernel and the FC kernel are
    bit-exact against the per-channel ``ref.py`` oracles across ragged
    Cout, block_cin sweeps, strides, fused pools and the fused-skip
    epilogue on a per-channel host conv;
  * **per-tensor invariance** — with scalar specs nothing changes:
    outputs are byte-identical, and a jaxpr probe shows no shift-vector
    operand is staged on any kernel call;
  * **accuracy** — per-channel calibration is never worse than
    per-tensor on a fixed-seed mobilenet_tiny batch (depthwise layers
    are the motivating case), and is strictly better when channel
    magnitudes are skewed.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import pipeline as pipe
from repro.core import quantize as Q
from repro.core import verify as V
from repro.core.synthesis import CNN2Gate
from repro.kernels import ops, ref
from repro.models import cnn

RNG = np.random.default_rng(7)


def _rand_shifts(n, lo=0, hi=14):
    return tuple(int(s) for s in RNG.integers(lo, hi, n))


# ------------------------------------------------------ kernel parity

@pytest.mark.parametrize("cout", [16, 32, 130])
@pytest.mark.parametrize("block_cin", [None, 8, 16])
def test_dense_per_channel_parity(cout, block_cin):
    """Dense band kernel == per-channel oracle (incl. ragged Cout=130
    across Cout tiles and the Cin contraction sweep)."""
    x = jnp.asarray(RNG.integers(-128, 128, (2, 12, 12, 24)), jnp.int8)
    w = jnp.asarray(RNG.integers(-128, 128, (3, 3, 24, cout)), jnp.int8)
    b = jnp.asarray(RNG.integers(-1000, 1000, (cout,)), jnp.int32)
    shifts = _rand_shifts(cout)
    got = ops.qconv2d_nhwc(x, w, b, shift=shifts, relu=True,
                           block_cout=64, block_h=4, block_cin=block_cin,
                           interpret=True)
    want = ref.qconv2d_ref(x, w, b, (1, 1), shifts, True, None)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("strides,pool", [((1, 1), (2, 2)), ((2, 2), None),
                                          ((1, 1), (3, 2))])
def test_dense_per_channel_pool_stride_parity(strides, pool):
    """Per-lane requant composes with fused max-pool and strides
    exactly as the scalar epilogue does (pool runs on requantized
    int8, so the vector shift must land before the window max)."""
    cout = 40
    x = jnp.asarray(RNG.integers(-128, 128, (2, 13, 13, 16)), jnp.int8)
    w = jnp.asarray(RNG.integers(-128, 128, (3, 3, 16, cout)), jnp.int8)
    b = jnp.asarray(RNG.integers(-500, 500, (cout,)), jnp.int32)
    shifts = _rand_shifts(cout)
    got = ops.qconv2d_nhwc(x, w, b, strides=strides, shift=shifts,
                           relu=True, pool=pool, block_cout=32, block_h=2,
                           interpret=True)
    want = ref.qconv2d_ref(x, w, b, strides, shifts, True, pool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("c", [32, 48, 130])
def test_depthwise_per_channel_parity(c):
    """Depthwise band kernel: the channel tile IS the lane dim, so the
    shift row tiles with it (ragged C=130 exercises the padded tile)."""
    x = jnp.asarray(RNG.integers(-128, 128, (2, 10, 10, c)), jnp.int8)
    w = jnp.asarray(RNG.integers(-128, 128, (3, 3, 1, c)), jnp.int8)
    b = jnp.asarray(RNG.integers(-500, 500, (c,)), jnp.int32)
    shifts = _rand_shifts(c)
    got = ops.qconv2d_nhwc(x, w, b, shift=shifts, relu=True, groups=c,
                           block_cout=32, block_h=3, interpret=True)
    want = ref.qconv2d_ref(x, w, b, (1, 1), shifts, True, None, groups=c)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_depthwise_per_channel_pool_parity():
    c = 24
    x = jnp.asarray(RNG.integers(-128, 128, (1, 12, 12, c)), jnp.int8)
    w = jnp.asarray(RNG.integers(-128, 128, (3, 3, 1, c)), jnp.int8)
    b = jnp.asarray(RNG.integers(-500, 500, (c,)), jnp.int32)
    shifts = _rand_shifts(c)
    got = ops.qconv2d_nhwc(x, w, b, shift=shifts, relu=True, pool=(2, 2),
                           groups=c, block_cout=16, block_h=2,
                           interpret=True)
    want = ref.qconv2d_ref(x, w, b, (1, 1), shifts, True, (2, 2), groups=c)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n", [64, 130])
@pytest.mark.parametrize("block_k", [32, 128])
def test_fc_per_channel_parity(n, block_k):
    x = jnp.asarray(RNG.integers(-128, 128, (5, 96)), jnp.int8)
    w = jnp.asarray(RNG.integers(-128, 128, (96, n)), jnp.int8)
    b = jnp.asarray(RNG.integers(-500, 500, (n,)), jnp.int32)
    shifts = _rand_shifts(n)
    got = ops.qgemm(x, w, b, shift=shifts, relu=True, block_n=64,
                    block_k=block_k, interpret=True)
    want = ref.qgemm_ref(x, w, b, shifts, True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ragged_grouped_fallback_per_channel():
    """Ragged grouped convs run on the reference path — the vector
    shift must flow through the dispatch unchanged."""
    g, cin, cout = 3, 12, 18
    x = jnp.asarray(RNG.integers(-128, 128, (1, 8, 8, cin)), jnp.int8)
    w = jnp.asarray(RNG.integers(-128, 128, (3, 3, cin // g, cout)), jnp.int8)
    b = jnp.asarray(RNG.integers(-500, 500, (cout,)), jnp.int32)
    shifts = _rand_shifts(cout)
    got = ops.qconv2d_nhwc(x, w, b, shift=shifts, relu=True, groups=g,
                           interpret=True)
    want = ref.qconv2d_ref(x, w, b, (1, 1), shifts, True, None, groups=g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("pool", [None, (2, 2)])
@pytest.mark.parametrize("block_cin", [None, 8])
def test_fused_skip_with_per_channel_host(pool, block_cin):
    """Residual-add epilogue fusion on a per-channel host conv: the
    per-lane conv requant runs first (producing exactly the int8
    tensor the standalone conv would have written), then the scalar
    merge alignment/requant — bit-exact vs the two-stage oracle."""
    cout = 24
    x = jnp.asarray(RNG.integers(-128, 128, (2, 9, 9, 16)), jnp.int8)
    w = jnp.asarray(RNG.integers(-128, 128, (3, 3, 16, cout)), jnp.int8)
    b = jnp.asarray(RNG.integers(-500, 500, (cout,)), jnp.int32)
    shifts = _rand_shifts(cout)
    skip = jnp.asarray(RNG.integers(-128, 128, (2, 7, 7, cout)), jnp.int8)
    got = ops.qconv2d_nhwc(x, w, b, shift=shifts, relu=True, skip=skip,
                           skip_shifts=(1, 0), merge_shift=1,
                           merge_relu=True, pool=pool, block_cout=16,
                           block_h=2, block_cin=block_cin, interpret=True)
    conv8 = ref.qconv2d_ref(x, w, b, (1, 1), shifts, True, None)
    want = ref.qadd_ref([conv8, skip], (1, 0), 1, True)
    if pool is not None:
        want = ref.maxpool2d_ref(want, pool[0], pool[1])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -------------------------------------------- quantize.py unit rules

def test_per_channel_spec_shift_vector():
    spec = Q.QuantSpec(m_w=(7, 5, 9), m_x=4, m_y=3)
    assert spec.per_channel and spec.m_w_min == 5
    assert spec.requant_shift == (8, 6, 10)
    with pytest.raises(ValueError):
        _ = Q.QuantSpec(m_w=(7, 1), m_x=1, m_y=5).requant_shift


def test_per_channel_weight_quantization_oihw_and_fc():
    """Each Cout lane quantizes at its own exponent; biases land on
    their lane's accumulator scale."""
    w = np.asarray([[[[0.5]]], [[[0.0625]]]], np.float32)  # OIHW (2,1,1,1)
    b = np.asarray([0.25, 0.25], np.float32)
    spec = Q.QuantSpec(m_w=(6, 9), m_x=4, m_y=4)
    wq, bq = Q.quantize_weights(w, b, spec)
    assert wq[0, 0, 0, 0] == round(0.5 * 2 ** 6)
    assert wq[1, 0, 0, 0] == round(0.0625 * 2 ** 9)
    assert bq[0] == round(0.25 * 2 ** 10) and bq[1] == round(0.25 * 2 ** 13)
    # FC: output features on the last axis
    wfc = np.asarray([[0.5, 0.0625]], np.float32)
    wq2, _ = Q.quantize_weights(wfc, None, spec)
    assert wq2[0, 0] == round(0.5 * 2 ** 6)
    assert wq2[0, 1] == round(0.0625 * 2 ** 9)


def test_per_channel_exponents_reduce_roundtrip_error():
    """Skewed channel magnitudes: per-channel max-abs exponents beat
    the single per-tensor exponent at round-trip."""
    cout = 8
    w = np.stack([RNG.standard_normal((4, 3, 3)).astype(np.float32)
                  * (2.0 ** -c) for c in range(cout)])
    m_pt = Q.best_pow2_exponent(w)
    m_pc = Q.best_pow2_exponents_per_channel(w)
    assert len(m_pc) == cout and min(m_pc) >= m_pt

    def rt_err(wq_m):
        err = 0.0
        for c in range(cout):
            m = wq_m[c] if isinstance(wq_m, tuple) else wq_m
            q = Q.quantize_array(w[c], m)
            err += float(np.mean((Q.dequantize_array(q, m) - w[c]) ** 2))
        return err

    assert rt_err(m_pc) < rt_err(m_pt)


def test_requantize_per_channel_matches_per_lane_scalar():
    acc = RNG.integers(-(2 ** 20), 2 ** 20, (6, 4))
    shifts = (0, 3, 7, 12)
    spec = Q.QuantSpec(m_w=tuple(s for s in shifts), m_x=0, m_y=0)
    got = Q.requantize(acc, spec)
    for c, s in enumerate(shifts):
        want = Q.requantize(acc[:, c], Q.QuantSpec(m_w=s, m_x=0, m_y=0))
        np.testing.assert_array_equal(got[:, c], want)


# ------------------------------------------- end-to-end + invariance

def _calibrated(build, x, per_channel, **kw):
    gate = CNN2Gate.from_graph(build(batch=x.shape[0], in_hw=x.shape[-1]),
                               **kw)
    gate.calibrate_quantization(x, per_channel=per_channel)
    return gate


@pytest.mark.parametrize("build", [cnn.resnet_tiny, cnn.mobilenet_tiny])
def test_per_channel_end_to_end_bit_exact_vs_stagewise_oracle(build):
    """Whole-network per-channel executor == stage-by-stage per-channel
    oracle replay (conv/dwconv/FC/merge all covered; resnet_tiny also
    exercises the fused-skip epilogue under a per-channel host)."""
    rng = np.random.default_rng(3)
    x = (rng.standard_normal((2, 3, 32, 32)) * 0.5).astype(np.float32)
    gate = _calibrated(build, x, per_channel=True)
    xj = jnp.asarray(x)
    got = np.asarray(gate.build("emulation")(xj))

    # oracle replay over the *unfused* program with the same specs
    gate_u = CNN2Gate.from_graph(build(batch=2, in_hw=32), fuse_skip=False)
    gate_u.apply_quantization(gate.specs)
    qmu = gate_u.quantized
    h = jnp.clip(jnp.round(xj * 2.0 ** qmu.input_m), -128, 127
                 ).astype(jnp.int8)
    h = jnp.transpose(h, (0, 2, 3, 1))
    env = {qmu.parsed.input_name: h}
    for ql in qmu.layers:
        li = ql.info
        if li.kind == pipe.P.CONV:
            pool = None
            if li.pool is not None:
                pool = (li.pool.kernel_shape[0], li.pool.strides[0])
            xin = env[li.inputs[0]]
            if any(li.pads):
                p = li.pads
                xin = jnp.pad(xin, ((0, 0), (p[0], p[2]), (p[1], p[3]),
                                    (0, 0)))
            wref = ql.w_q
            if li.is_depthwise:
                wref = wref.reshape(wref.shape[0], wref.shape[1], 1, -1)
            env[li.output] = ref.qconv2d_ref(
                xin, wref, ql.b_q, li.strides, ql.spec.requant_shift,
                li.relu, pool, groups=li.group)
        elif li.kind == pipe.P.POOL:
            fn = (ops.avgpool2d_nhwc if li.pool_type == "avg"
                  else ops.maxpool2d_nhwc)
            env[li.output] = fn(env[li.inputs[0]], li.kernel_shape[0],
                                li.strides[0], li.pads)
        elif li.kind == pipe.P.FC:
            hin = env[li.inputs[0]]
            if hin.ndim > 2:
                hin = hin.reshape(hin.shape[0], -1)
            env[li.output] = ref.qgemm_ref(hin, ql.w_q, ql.b_q,
                                           ql.spec.requant_shift, li.relu)
        elif li.kind == pipe.P.ADD:
            env[li.output] = ref.qadd_ref([env[t] for t in li.inputs],
                                          ql.operand_shifts,
                                          ql.spec.requant_shift, li.relu)
        else:
            raise AssertionError(li.kind)
    out = env[qmu.parsed.output_name]
    if out.ndim == 4:
        out = jnp.transpose(out, (0, 3, 1, 2))
    want = out.astype(jnp.float32) * (2.0 ** -qmu.output_m)
    out_stage = qmu.parsed.stage_producing(qmu.parsed.output_name)
    if out_stage is not None and out_stage.softmax:
        want = jax.nn.softmax(want, axis=-1)
    np.testing.assert_array_equal(got, np.asarray(want))


def test_per_tensor_outputs_byte_identical_and_no_shift_operand():
    """per_channel=False must be a no-op: byte-identical logits whether
    the flag is threaded or not, and the jaxpr stages no shift-vector
    operand on any kernel call (the pallas_call arity probe — the
    per-channel program stages exactly one extra (1, Cout) operand)."""
    rng = np.random.default_rng(5)
    x = (rng.standard_normal((2, 3, 32, 32)) * 0.5).astype(np.float32)
    xj = jnp.asarray(x)

    gate = _calibrated(cnn.resnet_tiny, x, per_channel=False)
    y_default = np.asarray(gate.build("emulation")(xj))
    gate2 = CNN2Gate.from_graph(cnn.resnet_tiny(batch=2, in_hw=32))
    gate2.apply_quantization(gate.specs, per_channel=False)
    y_strict = np.asarray(gate2.build("emulation")(xj))
    np.testing.assert_array_equal(y_default, y_strict)

    def pallas_arities(qm):
        # the verifier's reusable probe (one walker shared with the
        # fusion tests' eqn counts and the QV5xx CLI probes)
        return V.pallas_call_arities(
            V.executor_jaxpr(qm, batch=xj.shape[0]))

    scalar_arities = pallas_arities(gate.quantized)
    gate_pc = _calibrated(cnn.resnet_tiny, x, per_channel=True)
    vector_arities = pallas_arities(gate_pc.quantized)
    assert len(scalar_arities) == len(vector_arities) > 0
    # every weighted kernel call stages exactly one extra operand (the
    # per-lane shift row); the per-tensor program stages none
    assert all(v == s + 1 for s, v in zip(scalar_arities, vector_arities)), \
        (scalar_arities, vector_arities)


def test_per_channel_strict_flag_rejects_vector_specs():
    rng = np.random.default_rng(2)
    x = (rng.standard_normal((1, 3, 32, 32)) * 0.5).astype(np.float32)
    gate = _calibrated(cnn.mobilenet_tiny, x, per_channel=True)
    gate2 = CNN2Gate.from_graph(cnn.mobilenet_tiny(batch=1, in_hw=32))
    with pytest.raises(ValueError):
        gate2.apply_quantization(gate.specs, per_channel=False)


def test_per_channel_true_upgrades_scalar_specs_bit_identically():
    """build_quantized(per_channel=True) on scalar specs runs the
    shift-vector datapath with uniform counts — numerics unchanged."""
    rng = np.random.default_rng(4)
    x = (rng.standard_normal((2, 3, 32, 32)) * 0.5).astype(np.float32)
    xj = jnp.asarray(x)
    gate = _calibrated(cnn.resnet_tiny, x, per_channel=False)
    y_scalar = np.asarray(gate.build("emulation")(xj))
    gate_up = CNN2Gate.from_graph(cnn.resnet_tiny(batch=2, in_hw=32))
    gate_up.apply_quantization(gate.specs, per_channel=True)
    assert all(ql.spec.per_channel for ql in gate_up.quantized.layers
               if ql.info.kind in (pipe.P.CONV, pipe.P.FC))
    # the DSE must see the widened program (it reads the quantized
    # layers, not the raw scalar specs) and charge shift-vector bytes
    assert gate_up.per_channel and not gate.per_channel
    assert gate_up.design_space("ARRIA10").weight_bytes > \
        gate.design_space("ARRIA10").weight_bytes
    y_vec = np.asarray(gate_up.build("emulation")(xj))
    np.testing.assert_array_equal(y_scalar, y_vec)


# ------------------------------------------------ accuracy regression

def _stagewise_dequant_error(gate, x):
    """Calibration-accuracy metric: run the int8 program stage by
    stage and sum, over every weighted stage, the mean |dequantized
    stage output - float oracle activation|.  This is the quantity a
    calibration actually controls (the final logits also fold in the
    shared per-tensor activation grids, which per-channel weight
    scales cannot move)."""
    qm = gate.quantized
    acts = cnn.collect_activations(gate.parsed.graph, x)
    tensor_m = pipe.thread_scales(gate.parsed, gate.specs)
    xj = jnp.asarray(x)
    h = jnp.clip(jnp.round(xj * 2.0 ** qm.input_m), -128, 127
                 ).astype(jnp.int8)
    h = jnp.transpose(h, (0, 2, 3, 1))
    env = {gate.parsed.input_name: h}
    total = 0.0
    for ql in qm.layers:
        li = ql.info
        if li.kind == pipe.P.CONV:
            pool = ((li.pool.kernel_shape[0], li.pool.strides[0])
                    if li.pool is not None else None)
            h = ops.qconv2d_nhwc(env[li.inputs[0]], ql.w_q, ql.b_q,
                                 strides=li.strides, pads=li.pads,
                                 shift=ql.spec.requant_shift, relu=li.relu,
                                 pool=pool, groups=li.group, interpret=True)
        elif li.kind == pipe.P.POOL:
            fn = (ops.avgpool2d_nhwc if li.pool_type == "avg"
                  else ops.maxpool2d_nhwc)
            h = fn(env[li.inputs[0]], li.kernel_shape[0], li.strides[0],
                   li.pads)
        elif li.kind == pipe.P.FC:
            hin = env[li.inputs[0]]
            if hin.ndim > 2:
                hin = hin.reshape(hin.shape[0], -1)
            h = ops.qgemm(hin, ql.w_q, ql.b_q,
                          shift=ql.spec.requant_shift, relu=li.relu,
                          interpret=True)
        else:
            raise AssertionError(li.kind)  # mobilenet_tiny: no merges
        env[li.output] = h
        if li.kind in (pipe.P.CONV, pipe.P.FC):
            deq = np.asarray(h, np.float32) * 2.0 ** -tensor_m[li.output]
            want = acts[li.output]
            if want.ndim == 4:
                want = np.transpose(want, (0, 2, 3, 1))
            total += float(np.mean(np.abs(deq - want)))
    return total


def test_mobilenet_per_channel_accuracy_not_worse():
    """Fixed-seed mobilenet_tiny batch: per-channel calibration must be
    at least as accurate as per-tensor (the depthwise stacks are where
    per-channel scales pay off — the summed stage-output error drops
    ~5 % on this net for every seed tried)."""
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((2, 3, 32, 32)) * 0.5).astype(np.float32)
    err = {}
    for mode in (False, True):
        gate = _calibrated(cnn.mobilenet_tiny, x, per_channel=mode)
        err[mode] = _stagewise_dequant_error(gate, x)
    assert err[True] <= err[False], err


def test_skewed_channel_conv_per_channel_strictly_better():
    """A conv whose output channels differ by orders of magnitude:
    per-tensor quantization crushes the small channels to zero,
    per-channel keeps them — strict accuracy win, not a tie."""
    rng = np.random.default_rng(1)
    cout, cin, hw = 8, 4, 8
    w = np.stack([rng.standard_normal((cin, 3, 3)).astype(np.float32)
                  * (2.0 ** -(2 * c)) for c in range(cout)])
    x = rng.standard_normal((1, cin, hw, hw)).astype(np.float32) * 0.5
    xh = jnp.transpose(jnp.asarray(x), (0, 2, 3, 1))
    wh = jnp.transpose(jnp.asarray(w), (2, 3, 1, 0))
    acc_f = np.asarray(jax.lax.conv_general_dilated(
        xh, wh, (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC")))

    m_x = Q.best_pow2_exponent(x)
    xq = jnp.asarray(Q.quantize_array(
        np.asarray(jnp.transpose(jnp.asarray(x), (0, 2, 3, 1))), m_x))

    def int8_out(m_w):
        spec = Q.QuantSpec(m_w=m_w, m_x=m_x, m_y=7)
        wq, _ = Q.quantize_weights(w, None, spec)
        wqh = jnp.asarray(np.transpose(wq, (2, 3, 1, 0)))
        y = ops.qconv2d_nhwc(xq, wqh, None, shift=spec.requant_shift,
                             relu=False, interpret=True)
        return np.asarray(y).astype(np.float32) * 2.0 ** -7

    err_pt = np.mean(np.abs(int8_out(Q.best_pow2_exponent(w)) - acc_f))
    err_pc = np.mean(np.abs(
        int8_out(Q.best_pow2_exponents_per_channel(w)) - acc_f))
    assert err_pc < err_pt, (err_pc, err_pt)
