"""Residual-add epilogue fusion + Cin-tiled contraction.

Two tentpole claims pinned bit-for-bit:

  * the conv band kernel's fused skip path (requant+clip to int8, then
    int32 operand alignment, add, merge requant, then fused pool) is
    exactly the unfused Conv -> Add two-stage program — swept over
    band-straddling rows, stride-2 convs, mismatched operand scales,
    skip + fused-pool ordering and ragged Cout tiles;
  * the ``block_cin`` contraction tile is a pure blocking knob (any
    tile bit-identical to the whole-Cin contraction) that bounds the
    input-band working set — the ``N_i`` axis finally changes measured
    kernel behaviour, not just the analytical report.

Plus the parser fold pass: eligibility/fallback matrix, end-to-end
fused == unfused parity on the resnet builders, and a jaxpr test that
the fused program really contains no standalone add stage.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import parser as P
from repro.core import pipeline as pipe
from repro.core import verify as V
from repro.core.quantize import QuantSpec
from repro.core.resources import conv_band_working_set
from repro.core.synthesis import CNN2Gate
from repro.kernels import ref
from repro.kernels.qconv import band_input_bytes, qconv2d, vmem_bytes
from repro.models import cnn

RNG = np.random.default_rng(23)


def i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, shape, np.int8))


def _oracle_two_stage(x, w, b, strides, shift, relu, skip, skip_shifts,
                      merge_shift, merge_relu, pool):
    """The unfused program: conv stage writes int8, add stage aligns,
    merges and requantizes, a trailing max-pool runs after the merge."""
    y1 = ref.qconv2d_ref(x, w, b, strides, shift, relu, None)
    merged = ref.qadd_ref([y1, skip], skip_shifts, shift=merge_shift,
                          relu=merge_relu)
    if pool is not None:
        merged = ref.maxpool2d_ref(merged, pool[0], pool[1])
    return merged


# ------------------------------------------------- kernel parity matrix
@pytest.mark.parametrize("cfg", [
    # (h, w, cin, cout, k, stride, pool, block_h, block_cin)
    (16, 16, 8, 16, 3, 1, None, 4, None),     # plain banding
    (17, 17, 8, 16, 3, 1, None, 3, 4),        # band-straddling rows
    (15, 15, 8, 16, 3, 2, None, 2, None),     # stride-2 conv
    (14, 14, 8, 130, 3, 1, None, 3, None),    # Cout=130 ragged tile
    (15, 15, 8, 16, 3, 1, (2, 2), 2, None),   # skip + fused pool
    (19, 19, 8, 16, 3, 1, (3, 2), 3, 4),      # overlapping pool straddle
])
@pytest.mark.parametrize("shifts", [
    ((0, 0), 0),          # already aligned, no output requant
    ((2, 0), 1),          # mismatched operand scales
    ((0, 3), 2),
])
def test_skip_fused_kernel_matches_two_stage_oracle(cfg, shifts):
    h, w_, cin, cout, k, stride, pool, bh, bci = cfg
    skip_shifts, merge_shift = shifts
    x, wt = i8(2, h, w_, cin), i8(k, k, cin, cout)
    b = jnp.asarray(RNG.integers(-500, 500, (cout,), np.int32))
    ho = (h - k) // stride + 1
    skip = i8(2, ho, ho, cout)
    got = qconv2d(x, wt, b, strides=(stride, stride), shift=5, relu=False,
                  pool=pool, block_cout=64, block_h=bh, block_cin=bci,
                  skip=skip, skip_shifts=skip_shifts,
                  merge_shift=merge_shift, merge_relu=True, interpret=True)
    want = _oracle_two_stage(x, wt, b, (stride, stride), 5, False, skip,
                             skip_shifts, merge_shift, True, pool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_skip_epilogue_clips_conv_result_first():
    """The conv result must be clipped to int8 *before* the merge — the
    tensor the unfused conv stage would have written.  shift=0 with big
    accumulators makes the intermediate clip observable."""
    x = jnp.asarray(RNG.integers(-128, 128, (1, 6, 6, 32), np.int8))
    wt = jnp.asarray(RNG.integers(-128, 128, (3, 3, 32, 8), np.int8))
    skip = i8(1, 4, 4, 8)
    got = qconv2d(x, wt, None, strides=(1, 1), shift=0, relu=False,
                  block_h=2, skip=skip, skip_shifts=(0, 0),
                  merge_shift=0, merge_relu=False, interpret=True)
    want = _oracle_two_stage(x, wt, None, (1, 1), 0, False, skip,
                             (0, 0), 0, False, None)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------ block_cin invariance
def test_block_cin_pure_blocking_knob():
    """Every Cin tile (incl. ragged Cin) gives the identical bit
    pattern as the whole-Cin contraction."""
    x, wt = i8(1, 13, 13, 130), i8(3, 3, 130, 24)
    b = jnp.asarray(RNG.integers(-500, 500, (24,), np.int32))
    outs = [np.asarray(qconv2d(x, wt, b, strides=(1, 1), shift=6,
                               relu=True, pool=(2, 2), block_h=3,
                               block_cin=bci, interpret=True))
            for bci in (None, 128, 64, 8)]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


def test_executor_invariant_to_n_i():
    """N_i now selects the kernel's real Cin tile; results must stay
    bit-identical across the option space (blocking only)."""
    gate = CNN2Gate.from_graph(cnn.resnet_tiny(batch=2))
    x = (RNG.standard_normal((2, 3, 32, 32)) * 0.5).astype(np.float32)
    gate.calibrate_quantization(x)
    outs = [np.asarray(pipe.run_int8(gate.quantized, jnp.asarray(x),
                                     n_i=ni, interpret=True))
            for ni in (1, 4, 16)]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


# --------------------------------------------------- parser fold pass
def test_resnet_tiny_folds_every_add():
    pm = P.parse(cnn.resnet_tiny())
    assert not any(li.kind == P.ADD for li in pm.layers)
    merged = [li for li in pm.layers if li.merge is not None]
    assert len(merged) == 2
    for li in merged:
        assert li.skip_input in li.inputs and len(li.inputs) == 2
        assert li.merge.relu  # the post-add ReLU rode along
        # the intermediate is the conv's own (pre-fold) product
        assert li.merge_intermediate not in [l.output for l in pm.layers]


def test_projection_block_host_is_later_conv():
    """When both Add operands are single-consumer convs (projection
    block), the later-scheduled conv hosts so the skip is already
    available."""
    pm = P.parse(cnn.resnet_tiny())
    hosts = [li for li in pm.layers if li.merge is not None]
    for host in hosts:
        producers = {li.output: i for i, li in enumerate(pm.layers)}
        if host.skip_input in producers:
            assert producers[host.skip_input] < pm.layers.index(host)


def test_multi_consumer_conv_output_not_folded():
    """A conv output that fans out (feeds the Add *and* another conv)
    must stay addressable — the Add survives as a standalone stage."""
    b = cnn.GraphBuilder("fanout", (1, 3, 10, 10), 2)
    b.conv(8, 3, pad=1, relu=False)
    split = b.tap()                      # conv output: 3 consumers
    b.conv(8, 3, pad=1, relu=False)
    main = b.tap()
    b.from_tap(split).add_from(main, relu=True)  # reads split AND main
    b.from_tap(split).conv(8, 1, relu=False)     # extra consumer
    extra = b.tap()
    b.add_from(extra, relu=False)
    b.global_avgpool()
    b.fc(3, relu=False, softmax=True)
    pm = P.parse(b.build())
    # first add: 'main' conv is single-consumer -> folds; second add
    # merges two tensors whose conv producers both fan out -> survives
    adds = [li for li in pm.layers if li.kind == P.ADD]
    merged = [li for li in pm.layers if li.merge is not None]
    assert len(adds) + len(merged) == 2 and len(merged) >= 1


def test_depthwise_producer_folds_and_matches():
    """The depthwise band kernel now carries the same skip epilogue as
    the dense one: an Add whose second operand is a single-consumer
    depthwise conv folds into that conv, bit-exact vs the unfused
    program (MobileNet-v2-style inverted-residual merges)."""
    def build():
        b = cnn.GraphBuilder("dwadd", (1, 3, 12, 12), 4)
        b.conv(16, 3, pad=1)
        split = b.tap()
        b.dwconv(3, pad=1, relu=False)
        left = b.tap()
        b.from_tap(split).dwconv(3, pad=1, relu=False)
        b.add_from(left, relu=True)
        b.global_avgpool()
        b.fc(3, relu=False, softmax=True)
        return b.build()

    g = build()
    pm = P.parse(g)
    merged = [li for li in pm.layers if li.merge is not None]
    assert len(merged) == 1 and merged[0].is_dw_kernel
    assert not any(li.kind == P.ADD for li in pm.layers)
    x = np.random.default_rng(0).standard_normal(
        g.inputs[0].shape).astype(np.float32)
    gate = CNN2Gate.from_graph(g)
    gate.calibrate_quantization(x)
    y_f = pipe.run_int8(pipe.build_quantized(pm, gate.specs), x)
    y_u = pipe.run_int8(
        pipe.build_quantized(P.parse(g, fuse_skip=False), gate.specs), x)
    assert jnp.array_equal(y_f, y_u)


def test_folded_stage_absorbs_following_maxpool():
    """Conv -> Add -> ReLU -> MaxPool collapses into ONE stage: the
    epilogue pools after the merge (graph order), bit-exact vs the
    unfused program."""
    def build():
        b = cnn.GraphBuilder("addpool", (2, 3, 12, 12), 8)
        b.conv(8, 3, pad=1)
        split = b.tap()
        b.conv(8, 3, pad=1, relu=False)
        b.add_from(split, relu=True)
        b.maxpool(2, 2)
        b.fc(4, relu=False, softmax=True)
        return b.build()
    pm = P.parse(build())
    host = next(li for li in pm.layers if li.merge is not None)
    assert host.pool is not None and not any(li.kind == P.POOL
                                             for li in pm.layers)
    gate_f = CNN2Gate.from_graph(build())
    x = (RNG.standard_normal((2, 3, 12, 12)) * 0.5).astype(np.float32)
    specs = gate_f.calibrate_quantization(x)
    gate_u = CNN2Gate.from_graph(build(), fuse_skip=False)
    gate_u.apply_quantization(specs)
    y_f = np.asarray(gate_f.build("emulation")(jnp.asarray(x)))
    y_u = np.asarray(gate_u.build("emulation")(jnp.asarray(x)))
    np.testing.assert_array_equal(y_f, y_u)


def test_softmax_on_add_blocks_fold():
    """A Softmax fused into the Add stage has no home in the conv
    epilogue — folding it would silently drop the softmax.  The merge
    must stay standalone, and the fused-default program must still
    match the unfused one exactly (regression: the fold used to check
    only the host conv's softmax flag)."""
    def build():
        b = cnn.GraphBuilder("addsm", (2, 3, 8, 8), 5)
        b.conv(4, 3, pad=1)
        split = b.tap()
        b.conv(4, 3, pad=1, relu=False)
        b.add_from(split, relu=False)
        # graph ends Conv -> Add -> Softmax (channel axis)
        name = b._name("Softmax")
        out = name + "_out"
        b.nodes.append(cnn.Node("Softmax", name, [b.cur], [out],
                                {"axis": 1}))
        b.cur = out
        return b.build()
    pm = P.parse(build())
    add = next(li for li in pm.layers if li.kind == P.ADD)
    assert add.softmax and not any(li.merge is not None for li in pm.layers)
    x = (RNG.standard_normal((2, 3, 8, 8)) * 0.5).astype(np.float32)
    gate_f = CNN2Gate.from_graph(build())
    specs = gate_f.calibrate_quantization(x)
    gate_u = CNN2Gate.from_graph(build(), fuse_skip=False)
    gate_u.apply_quantization(specs)
    y_f = np.asarray(gate_f.build("emulation")(jnp.asarray(x)))
    y_u = np.asarray(gate_u.build("emulation")(jnp.asarray(x)))
    np.testing.assert_array_equal(y_f, y_u)
    assert y_f.max() <= 1.0 + 1e-6  # the softmax actually ran


# ------------------------------------------- end-to-end fused parity
@pytest.mark.parametrize("build,in_hw", [
    (cnn.resnet_tiny, 32),
    (cnn.resnet18, 32),
])
def test_fused_program_bit_exact_vs_unfused(build, in_hw):
    """Acceptance: the skip-fused executor is bit-exact against the
    unfused Conv -> Add program under the same specs, on both resnet
    builders."""
    kw = dict(batch=2, in_hw=in_hw)
    gate_f = CNN2Gate.from_graph(build(**kw))
    x = (RNG.standard_normal((2, 3, in_hw, in_hw)) * 0.5
         ).astype(np.float32)
    specs = gate_f.calibrate_quantization(x)
    gate_u = CNN2Gate.from_graph(build(**kw), fuse_skip=False)
    gate_u.apply_quantization(specs)
    assert any(li.merge is not None for li in gate_f.parsed.layers)
    assert any(li.kind == P.ADD for li in gate_u.parsed.layers)
    y_f = np.asarray(gate_f.build("emulation")(jnp.asarray(x)))
    y_u = np.asarray(gate_u.build("emulation")(jnp.asarray(x)))
    np.testing.assert_array_equal(y_f, y_u)


def test_fused_specs_identical_to_unfused_calibration():
    """Calibrating the fused program must produce the same QuantSpecs
    (same names, same values) as calibrating the unfused one — fusion
    never changes the fixed-point program, only where it executes."""
    x = (RNG.standard_normal((2, 3, 32, 32)) * 0.5).astype(np.float32)
    s_f = CNN2Gate.from_graph(
        cnn.resnet_tiny(batch=2)).calibrate_quantization(x)
    s_u = CNN2Gate.from_graph(
        cnn.resnet_tiny(batch=2),
        fuse_skip=False).calibrate_quantization(x)
    assert s_f == s_u


def test_mismatched_branch_scales_fused_bit_exact():
    """Force unequal operand positions (nonzero alignment shifts) on a
    diamond graph and check fused == unfused bit-for-bit."""
    def build():
        b = cnn.GraphBuilder("diamond", (2, 3, 12, 12), 3)
        b.conv(8, 3, pad=1)
        split = b.tap()
        b.conv(8, 3, pad=1, relu=False)
        left = b.tap()
        b.from_tap(split).conv(8, 3, pad=1, relu=False)
        b.add_from(left, relu=True)
        b.global_avgpool()
        b.fc(5, relu=False, softmax=True)
        return b.build()
    pm = P.parse(build(), fuse_skip=False)
    conv_names = [li.name for li in pm.layers if li.kind == P.CONV]
    add_name = next(li.name for li in pm.layers if li.kind == P.ADD)
    fc_name = next(li.name for li in pm.layers if li.kind == P.FC)
    specs = {
        conv_names[0]: QuantSpec(m_w=7, m_x=6, m_y=6),
        conv_names[1]: QuantSpec(m_w=7, m_x=6, m_y=6),
        conv_names[2]: QuantSpec(m_w=7, m_x=6, m_y=4),
        add_name: QuantSpec(m_w=0, m_x=4, m_y=3),
        fc_name: QuantSpec(m_w=7, m_x=3, m_y=7),
    }
    x = (RNG.standard_normal((2, 3, 12, 12)) * 0.5).astype(np.float32)
    gate_f = CNN2Gate.from_graph(build())
    gate_f.apply_quantization(specs)
    host = next(ql for ql in gate_f.quantized.layers
                if ql.info.merge is not None)
    assert sorted(host.operand_shifts) == [0, 2]  # real alignment work
    gate_u = CNN2Gate.from_graph(build(), fuse_skip=False)
    gate_u.apply_quantization(specs)
    y_f = np.asarray(gate_f.build("emulation")(jnp.asarray(x)))
    y_u = np.asarray(gate_u.build("emulation")(jnp.asarray(x)))
    np.testing.assert_array_equal(y_f, y_u)


def test_fused_merge_below_common_scale_rejected():
    """Shift-only alignment cannot scale up — same guard as the
    standalone merge, now raised from the fused path."""
    pm = P.parse(cnn.resnet_tiny())
    host = next(li for li in pm.layers if li.merge is not None)
    specs = {}
    for li in pm.layers:
        if li.kind in (P.CONV, P.FC):
            specs[li.name] = QuantSpec(m_w=7, m_x=6, m_y=6)
    specs[host.merge.name] = QuantSpec(m_w=0, m_x=8, m_y=8)  # above ops
    with pytest.raises(ValueError, match="alignment"):
        pipe.build_quantized(pm, specs)


# ----------------------------------------------- jaxpr: no add stage
# (the probe itself is the verifier's reusable int_add_eqns — the old
# copy-pasted walker lives in core/verify.py now)

def test_fused_program_has_no_standalone_add_stage():
    gate = CNN2Gate.from_graph(cnn.resnet_tiny(batch=1))
    x = (RNG.standard_normal((1, 3, 32, 32)) * 0.5).astype(np.float32)
    gate.calibrate_quantization(x)
    ex_f = pipe.make_executor(gate.quantized, interpret=True)
    jaxpr_f = jax.make_jaxpr(lambda v: ex_f(v))(jnp.asarray(x))
    assert V.int_add_eqns(jaxpr_f.jaxpr) == 0
    # ...and the QV501 probe agrees wholesale
    assert V.structural_probes(gate.quantized) == []
    # ...and the unfused program DOES have them (the probe is valid)
    gate_u = CNN2Gate.from_graph(cnn.resnet_tiny(batch=1),
                                 fuse_skip=False)
    gate_u.apply_quantization(gate.specs)
    ex_u = pipe.make_executor(gate_u.quantized, interpret=True)
    jaxpr_u = jax.make_jaxpr(lambda v: ex_u(v))(jnp.asarray(x))
    assert V.int_add_eqns(jaxpr_u.jaxpr) > 0


# ------------------------------------------------ working-set model
def test_cin_tile_shrinks_input_band_3x():
    """Acceptance: a 224x224x512 conv (3x3, pad 1 -> hp=wp=226) with
    block_cin=128 holds >= 3x less input band than the whole-Cin
    kernel, and the full working set drops accordingly."""
    whole = band_input_bytes(226, 226, 512, 3, 224, block_h=8)
    tiled = band_input_bytes(226, 226, 512, 3, 224, block_h=8,
                             block_cin=128)
    assert whole / tiled >= 3.0
    ws_whole = vmem_bytes(226, 226, 512, 3, 3, 128, 224, 224, block_h=8)
    ws_tiled = vmem_bytes(226, 226, 512, 3, 3, 128, 224, 224, block_h=8,
                          block_cin=128)
    assert ws_tiled < ws_whole


def test_skip_vmem_term_charged_for_fused_merge():
    """The DSE working-set rule must charge the skip band the epilogue
    holds: the fused program's peak conv working set exceeds the same
    conv without the merge."""
    assert vmem_bytes(34, 34, 64, 3, 3, 128, 32, 32, block_h=4,
                      skip=True) > \
        vmem_bytes(34, 34, 64, 3, 3, 128, 32, 32, block_h=4)
    pm_f = P.parse(cnn.resnet_tiny())
    ws = conv_band_working_set(pm_f.layers, 32, 4, n_i=16)
    assert ws > 0


def test_working_set_shrinks_with_n_i():
    """The N_i axis now bounds the measured band: a VGG-scale model's
    working set must be monotone non-increasing as N_i shrinks."""
    pm = P.parse(cnn.vgg16())
    ws = [conv_band_working_set(pm.layers, 32, 8, n_i=ni)
          for ni in (16, 8, 4)]
    assert ws[0] >= ws[1] >= ws[2]
    assert conv_band_working_set(pm.layers, 32, 8) >= ws[0]
