"""DAG stage program: parser scheduling, residual/depthwise execution.

Parity matrix for the DAG-scheduled int8 executor:

  * residual add with mismatched branch scales (per-operand alignment
    shifts), bit-exact against a reference chain built from the
    ``kernels/ref.py`` oracles;
  * multi-consumer tensor fan-out (diamond graphs);
  * depthwise conv vs the float/int reference — bit-for-bit at the
    int32 accumulator;
  * grouped convs may never execute as dense convs: valid groups run
    grouped, invalid groups raise;
  * a toposort property test over randomized DAGs (hypothesis; skipped
    cleanly when the package is absent, per conftest stub).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import parser as P
from repro.core import pipeline as pipe
from repro.core.graph import Graph, GraphError, Node, TensorInfo
from repro.core.quantize import QuantSpec
from repro.core.resources import conv_band_working_set
from repro.core.synthesis import CNN2Gate
from repro.kernels import ops, ref
from repro.kernels.qconv import qdwconv2d
from repro.models import cnn

RNG = np.random.default_rng(11)


def i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, shape, np.int8))


# ---------------------------------------------------------- parser / DAG
def test_parse_resnet_tiny_stage_program():
    # fuse_skip=False: this test pins the *unfused* stage program — the
    # fallback for ineligible merges (the skip-fusion pass has its own
    # suite in tests/test_skip_fusion.py)
    pm = P.parse(cnn.resnet_tiny(), fuse_skip=False)
    kinds = [li.kind for li in pm.layers]
    assert kinds.count(P.ADD) == 2
    adds = [li for li in pm.layers if li.kind == P.ADD]
    assert all(len(li.inputs) == 2 for li in adds)
    assert all(li.relu for li in adds)  # post-add ReLU fused into merge
    # schedule is topological: every input is produced earlier (or is
    # the graph input)
    seen = {pm.input_name}
    for li in pm.layers:
        assert all(t in seen for t in li.inputs), li.name
        seen.add(li.output)
    # multi-consumer fan-out survives as a named tensor: the block
    # input feeds both the first conv and the merge
    stem_out = pm.layers[0].output
    consumers = pm.consumer_stages(stem_out)
    assert len(consumers) == 2
    assert {c.kind for c in consumers} == {P.CONV, P.ADD}


def test_parse_mobilenet_depthwise_stages():
    pm = P.parse(cnn.mobilenet_tiny())
    dws = [li for li in pm.layers if li.is_depthwise]
    assert len(dws) == 3
    assert all(li.group == li.c_in == li.c_out for li in dws)
    # depthwise layers do not destroy the (N_i, N_l) option space
    assert 8 in pm.feasible_ni() and 8 in pm.feasible_nl()


def test_merge_stages_in_memory_schedule_and_latency():
    pm = P.parse(cnn.resnet_tiny(), fuse_skip=False)
    sched = P.memory_schedule(pm, 16, 32)
    assert len(sched) == len(pm.layers)
    assert all(s["read_vectors"] > 0 and s["lanes"] > 0 for s in sched)
    merge_rows = [s for s in sched if s["kind"] == P.ADD]
    assert merge_rows and all(s["weight_vectors"] == 0 for s in merge_rows)
    rep = CNN2Gate.from_graph(cnn.resnet_tiny(),
                              fuse_skip=False).latency_report(
        "ARRIA10", 16, 32)
    add_times = [l for l in rep.layers if l.kind == P.ADD]
    assert add_times and all(l.macs == 0 and l.time_s > 0 for l in add_times)


def test_band_working_set_covers_branch_and_depthwise():
    for g in (cnn.resnet_tiny(), cnn.mobilenet_tiny()):
        pm = P.parse(g)
        ws = [conv_band_working_set(pm.layers, 32, bh) for bh in (1, 4, 16)]
        assert all(w > 0 for w in ws)
        assert ws == sorted(ws)  # monotone in block_h, branches included


# ------------------------------------------------- residual merge parity
def _diamond_graph(seed=3):
    """One tensor fans out into two conv branches that merge in an Add —
    the smallest multi-consumer residual graph."""
    b = cnn.GraphBuilder("diamond", (2, 3, 12, 12), seed)
    b.conv(8, 3, pad=1)
    split = b.tap()
    b.conv(8, 3, pad=1, relu=False)
    left = b.tap()
    b.from_tap(split).conv(8, 3, pad=1, relu=False)
    right = b.tap()
    b.from_tap(left).add_from(right, relu=True)
    b.global_avgpool()
    b.fc(5, relu=False, softmax=True)
    return b.build()


def test_diamond_fanout_executes_and_tracks_float():
    g = _diamond_graph()
    gate = CNN2Gate.from_graph(g)
    x = (RNG.standard_normal((2, 3, 12, 12)) * 0.5).astype(np.float32)
    gate.calibrate_quantization(x)
    y_q = np.asarray(gate.build("emulation")(jnp.asarray(x)))
    y_f = np.asarray(cnn.run_float(g, jnp.asarray(x)))
    assert y_q.shape == y_f.shape
    rel = np.linalg.norm(y_q - y_f) / max(np.linalg.norm(y_f), 1e-9)
    assert rel < 0.75  # the tolerance the linear tiny_cnn itself meets


def test_residual_add_mismatched_branch_scales_bit_exact():
    """Force the two branch producers onto different fixed-point
    positions and check the executor against a reference chain built
    from the ref.py oracles: the merge must align operands with
    per-operand round-half-up shifts, bit-for-bit."""
    g = _diamond_graph()
    pm = P.parse(g, fuse_skip=False)
    conv_names = [li.name for li in pm.layers if li.kind == P.CONV]
    add_name = next(li.name for li in pm.layers if li.kind == P.ADD)
    fc_name = next(li.name for li in pm.layers if li.kind == P.FC)
    # stem at m_y=6; left branch emits at m=6, right branch at m=4
    specs = {
        conv_names[0]: QuantSpec(m_w=7, m_x=6, m_y=6),
        conv_names[1]: QuantSpec(m_w=7, m_x=6, m_y=6),
        conv_names[2]: QuantSpec(m_w=7, m_x=6, m_y=4),
        add_name: QuantSpec(m_w=0, m_x=4, m_y=3),
        fc_name: QuantSpec(m_w=7, m_x=3, m_y=7),
    }
    gate = CNN2Gate.from_graph(g, fuse_skip=False)
    gate.apply_quantization(specs)
    qm = gate.quantized
    add_q = next(ql for ql in qm.layers if ql.info.kind == P.ADD)
    assert add_q.operand_shifts == (2, 0)  # 6-4 and 4-4

    x = (RNG.standard_normal((2, 3, 12, 12)) * 0.5).astype(np.float32)
    y_exec = np.asarray(pipe.run_int8(qm, jnp.asarray(x), interpret=True))

    # reference chain straight from the oracles (NHWC int8)
    convs = {ql.info.name: ql for ql in qm.layers if ql.info.kind == P.CONV}
    xq = jnp.clip(jnp.round(jnp.asarray(x) * 2.0 ** 6), -128, 127
                  ).astype(jnp.int8).transpose(0, 2, 3, 1)

    def run_conv(name, xin, relu):
        ql = convs[name]
        xin = jnp.pad(xin, ((0, 0), (1, 1), (1, 1), (0, 0)))
        return ref.qconv2d_ref(xin, ql.w_q, ql.b_q, (1, 1),
                               ql.spec.requant_shift, relu)

    stem = run_conv(conv_names[0], xq, True)
    left = run_conv(conv_names[1], stem, False)
    right = run_conv(conv_names[2], stem, False)
    merged = ref.qadd_ref([left, right], (2, 0), shift=1, relu=True)
    gap = ref.avgpool2d_ref(merged, merged.shape[1], 1)
    fc_q = next(ql for ql in qm.layers if ql.info.kind == P.FC)
    flat = gap.reshape(gap.shape[0], -1)
    logits_q = ref.qgemm_ref(flat, fc_q.w_q, fc_q.b_q,
                             fc_q.spec.requant_shift, relu=False)
    logits = jnp.asarray(np.asarray(logits_q, np.float32) * 2.0 ** -7)
    want = np.asarray(jax.nn.softmax(logits, axis=-1))
    np.testing.assert_allclose(y_exec, want, rtol=0, atol=0)


def test_merge_below_common_scale_rejected():
    """Shift-only alignment cannot scale an operand *up*: a user spec
    that puts the merge position above an operand must raise (fused and
    unfused programs alike)."""
    g = _diamond_graph()
    pm = P.parse(g, fuse_skip=False)
    conv_names = [li.name for li in pm.layers if li.kind == P.CONV]
    add_name = next(li.name for li in pm.layers if li.kind == P.ADD)
    fc_name = next(li.name for li in pm.layers if li.kind == P.FC)
    specs = {
        conv_names[0]: QuantSpec(m_w=7, m_x=6, m_y=6),
        conv_names[1]: QuantSpec(m_w=7, m_x=6, m_y=6),
        conv_names[2]: QuantSpec(m_w=7, m_x=6, m_y=4),
        add_name: QuantSpec(m_w=0, m_x=6, m_y=6),  # above right branch
        fc_name: QuantSpec(m_w=7, m_x=6, m_y=7),
    }
    with pytest.raises(ValueError, match="alignment"):
        pipe.build_quantized(pm, specs)


# -------------------------------------------------- depthwise conv parity
@pytest.mark.parametrize("cfg", [
    # (h, w, c, k, stride, pool, block_h)
    (14, 14, 8, 3, 1, None, 4),
    (17, 17, 16, 3, 2, None, 3),      # stride-2, ragged bands
    (15, 15, 24, 3, 1, (2, 2), 5),    # fused pool across band boundary
    (10, 10, 130, 3, 1, None, 2),     # channels past one 128 lane tile
])
@pytest.mark.parametrize("shift,relu", [(6, True), (3, False)])
def test_depthwise_band_kernel_matches_ref(cfg, shift, relu):
    h, w, c, k, stride, pool, bh = cfg
    x = i8(2, h, w, c)
    wt = i8(k, k, c)
    b = jnp.asarray(RNG.integers(-500, 500, (c,), np.int32))
    got = qdwconv2d(x, wt, b, strides=(stride, stride), shift=shift,
                    relu=relu, pool=pool, block_c=64, block_h=bh,
                    interpret=True)
    want = ref.qconv2d_ref(x, wt.reshape(k, k, 1, c), b, (stride, stride),
                           shift, relu, pool, groups=c)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_depthwise_int32_accumulator_bit_exact():
    """With shift=0 and operands small enough that the accumulator fits
    int8, the kernel output IS the int32 accumulator — bit-for-bit."""
    x = jnp.asarray(RNG.integers(-3, 4, (1, 9, 9, 12), np.int8))
    wt = jnp.asarray(RNG.integers(-3, 4, (3, 3, 12), np.int8))
    got = qdwconv2d(x, wt, None, strides=(1, 1), shift=0, relu=False,
                    block_h=2, interpret=True)
    acc = np.asarray(ref.qconv2d_ref(
        x, wt.reshape(3, 3, 1, 12), None, (1, 1), 0, False, groups=12))
    # independent int32 oracle: plain lax conv at accumulator precision
    acc32 = jax.lax.conv_general_dilated(
        jnp.asarray(x, jnp.int32),
        jnp.asarray(wt.reshape(3, 3, 1, 12), jnp.int32),
        (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=12)
    assert int(jnp.abs(acc32).max()) <= 127  # nothing clipped
    np.testing.assert_array_equal(np.asarray(got), np.asarray(acc32))
    np.testing.assert_array_equal(np.asarray(got), acc)


def test_depthwise_block_h_and_block_c_invariance():
    x, wt = i8(1, 13, 13, 40), i8(3, 3, 40)
    outs = [np.asarray(qdwconv2d(x, wt, None, strides=(1, 1), shift=5,
                                 relu=True, pool=(2, 2), block_c=bc,
                                 block_h=bh, interpret=True))
            for bh, bc in ((1, 128), (3, 128), (None, 64), (4, 8))]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


# ------------------------------------------------ grouped conv guarantees
def test_grouped_conv_never_runs_dense():
    """A group=2 conv must execute grouped: compare against the float
    oracle (which honours feature_group_count) — a silently-dense
    execution produces garbage here because the dense conv would read
    all 8 input channels per filter instead of 4."""
    b = cnn.GraphBuilder("grouped", (2, 3, 10, 10), 5)
    b.conv(8, 3, pad=1)
    b.conv(8, 3, pad=1, group=2)
    b.global_avgpool()
    b.fc(4, relu=False, softmax=True)
    g = b.build()
    gate = CNN2Gate.from_graph(g)
    x = (RNG.standard_normal((2, 3, 10, 10)) * 0.5).astype(np.float32)
    gate.calibrate_quantization(x)
    y_q = np.asarray(gate.build("emulation")(jnp.asarray(x)))
    y_f = np.asarray(cnn.run_float(g, jnp.asarray(x)))
    rel = np.linalg.norm(y_q - y_f) / max(np.linalg.norm(y_f), 1e-9)
    assert rel < 0.75


def test_invalid_group_raises_not_silent():
    pm = P.parse(cnn.tiny_cnn())
    conv = next(li for li in pm.layers if li.kind == P.CONV)
    conv.group = 3  # does not divide c_out=16
    specs = {li.name: QuantSpec(m_w=7, m_x=6, m_y=6) for li in pm.layers}
    with pytest.raises(NotImplementedError, match="group"):
        pipe.build_quantized(pm, specs)


# --------------------------------------------- end-to-end residual nets
@pytest.fixture(scope="module")
def resnet_gate():
    gate = CNN2Gate.from_graph(cnn.resnet_tiny(batch=4))
    x = (RNG.standard_normal((4, 3, 32, 32)) * 0.5).astype(np.float32)
    gate.calibrate_quantization(x)
    return gate, x


def test_resnet_tiny_emulation_matches_float(resnet_gate):
    gate, x = resnet_gate
    y_q = np.asarray(gate.build("emulation")(jnp.asarray(x)))
    y_f = np.asarray(cnn.run_float(cnn.resnet_tiny(batch=4),
                                   jnp.asarray(x)))
    # top-1 must agree wherever the float top-2 margin exceeds the int8
    # noise floor (untrained nets have near-tied softmax rows where
    # argmax is not a meaningful parity signal)
    top2 = np.sort(y_f, axis=-1)[:, -2:]
    decided = (top2[:, 1] - top2[:, 0]) > 0.02
    assert decided.any()
    assert np.all(y_q.argmax(-1)[decided] == y_f.argmax(-1)[decided])
    rel = np.linalg.norm(y_q - y_f) / np.linalg.norm(y_f)
    assert rel < 0.75  # same tolerance the linear nets meet


def test_resnet_tiny_block_h_invariant(resnet_gate):
    gate, x = resnet_gate
    outs = [np.asarray(pipe.run_int8(gate.quantized, jnp.asarray(x),
                                     interpret=True, block_h=bh))
            for bh in (None, 2, 5)]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


def test_mobilenet_tiny_emulation_tracks_float():
    gate = CNN2Gate.from_graph(cnn.mobilenet_tiny(batch=4))
    x = (RNG.standard_normal((4, 3, 32, 32)) * 0.5).astype(np.float32)
    gate.calibrate_quantization(x)
    y_q = np.asarray(gate.build("emulation")(jnp.asarray(x)))
    y_f = np.asarray(cnn.run_float(cnn.mobilenet_tiny(batch=4),
                                   jnp.asarray(x)))
    rel = np.linalg.norm(y_q - y_f) / np.linalg.norm(y_f)
    assert rel < 0.75


def test_branch_scales_aligned_by_calibration(resnet_gate):
    """Branch-aware calibration drives the merge operand shifts to zero
    whenever the producers' m_y caps allow it."""
    gate, _x = resnet_gate
    for ql in gate.quantized.layers:
        if ql.info.kind == P.ADD:
            assert all(s >= 0 for s in ql.operand_shifts)
            ms = [s for s in ql.operand_shifts]
            assert min(ms) == 0  # at least one operand sits at the merge m


def test_concat_stage_executes():
    b = cnn.GraphBuilder("cat", (2, 3, 8, 8), 9)
    b.conv(8, 3, pad=1)
    split = b.tap()
    b.conv(8, 1, relu=False)
    left = b.tap()
    b.from_tap(split).conv(4, 1, relu=False)
    right = b.tap()
    b.from_tap(left).concat_from(right)
    b.relu()
    b.global_avgpool()
    b.fc(3, relu=False, softmax=True)
    g = b.build()
    assert g.shape(g.nodes[-1].inputs[0])  # graph built & shaped
    gate = CNN2Gate.from_graph(g)
    x = (RNG.standard_normal((2, 3, 8, 8)) * 0.5).astype(np.float32)
    gate.calibrate_quantization(x)
    y_q = np.asarray(gate.build("emulation")(jnp.asarray(x)))
    y_f = np.asarray(cnn.run_float(g, jnp.asarray(x)))
    rel = np.linalg.norm(y_q - y_f) / max(np.linalg.norm(y_f), 1e-9)
    assert y_q.shape == y_f.shape and rel < 0.75


def test_padded_maxpool_runs_standalone_and_matches_float():
    """A padded MaxPool must NOT fuse into the conv band kernel (which
    has no pool-pad path) — it runs standalone, where the int8-native
    reduce_window handles pads exactly.  This is the resnet18 stem
    shape (conv pad + 3x3/2 pool pad 1)."""
    b = cnn.GraphBuilder("padpool", (2, 3, 14, 14), 4)
    b.conv(8, 3, pad=1).maxpool(3, 2, pad=1)
    b.fc(5, relu=False, softmax=True)
    g = b.build()
    pm = P.parse(g)
    conv = next(li for li in pm.layers if li.kind == P.CONV)
    assert conv.pool is None  # padded pool did not fuse
    assert any(li.kind == P.POOL for li in pm.layers)
    gate = CNN2Gate.from_graph(g)
    x = (RNG.standard_normal((2, 3, 14, 14)) * 0.5).astype(np.float32)
    gate.calibrate_quantization(x)
    y_q = np.asarray(gate.build("emulation")(jnp.asarray(x)))
    y_f = np.asarray(cnn.run_float(g, jnp.asarray(x)))
    assert y_q.shape == y_f.shape  # shape drift was the crash signature
    rel = np.linalg.norm(y_q - y_f) / max(np.linalg.norm(y_f), 1e-9)
    assert rel < 0.75


def test_concat_fused_relu_applied():
    """A ReLU fused into a Concat stage must clamp negatives (it used
    to be parsed, marked fused, and silently dropped)."""
    xs = [i8(1, 4, 4, 3), i8(1, 4, 4, 5)]
    y = np.asarray(ops.qconcat_nhwc(xs, (0, 1), relu=True))
    assert y.shape == (1, 4, 4, 8) and y.min() >= 0
    want = np.concatenate(
        [np.maximum(np.asarray(ref.align_shift(x.astype(jnp.int32), s)), 0)
         for x, s in zip(xs, (0, 1))], axis=-1)
    np.testing.assert_array_equal(y, want.astype(np.int8))


def test_band_working_set_handles_vector_merge():
    """MLP-style (2-D) residuals must not crash the DSE feasibility
    pass."""
    nodes = [
        Node("Gemm", "g1", ["x", "w1", "b1"], ["t1"]),
        Node("Gemm", "g2", ["t1", "w2", "b2"], ["t2"]),
        Node("Add", "a", ["t1", "t2"], ["y"]),
    ]
    inits = {"w1": RNG.standard_normal((8, 8)).astype(np.float32),
             "b1": np.zeros(8, np.float32),
             "w2": RNG.standard_normal((8, 8)).astype(np.float32),
             "b2": np.zeros(8, np.float32)}
    g = Graph("mlp_skip", nodes, [TensorInfo("x", (1, 8))], ["y"], inits)
    pm = P.parse(g)
    assert conv_band_working_set(pm.layers, 8, 4) > 0


# -------------------------------------------------- toposort property
@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_toposort_property_random_dags(data):
    """Random DAGs of Relu/Add nodes, presented shuffled: Graph must
    recover a valid topological order (or raise GraphError on cycles,
    which this generator never builds)."""
    n_nodes = data.draw(st.integers(2, 12))
    tensors = ["x"]
    nodes = []
    for i in range(n_nodes):
        k = data.draw(st.integers(1, min(2, len(tensors))))
        ins = [data.draw(st.sampled_from(tensors)) for _ in range(k)]
        out = f"t{i}"
        if len(set(ins)) == 2:
            nodes.append(Node("Add", f"n{i}", ins, [out]))
        else:
            nodes.append(Node("Relu", f"n{i}", [ins[0]], [out]))
        tensors.append(out)
    perm = data.draw(st.permutations(nodes))
    g = Graph("rand", perm, [TensorInfo("x", (1, 4))], [nodes[-1].outputs[0]])
    seen = {"x"}
    for n in g.nodes:
        assert all(t in seen for t in n.inputs)
        seen.update(n.outputs)


def test_cycle_still_rejected():
    nodes = [Node("Relu", "a", ["t2"], ["t1"]),
             Node("Relu", "b", ["t1"], ["t2"])]
    with pytest.raises(GraphError):
        Graph("cyc", nodes, [TensorInfo("x", (1, 4))], ["t2"])
