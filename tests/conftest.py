"""Shared test config.

``hypothesis`` is an optional test dependency (see pyproject.toml
``[project.optional-dependencies] test``).  Six test modules import it at
module scope, which would abort *collection* of the whole suite when it
is absent.  When the real package is unavailable we register a stub that
satisfies the imports and turns every ``@given`` property test into a
clean skip, so the deterministic tests in those modules still run.
"""
import functools
import sys

import pytest

try:  # pragma: no cover - trivial when hypothesis is installed
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if not HAVE_HYPOTHESIS:
    import types

    def _given(*_args, **_kwargs):
        def deco(fn):
            @functools.wraps(fn)
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed (pip install "
                            "'.[test]' to run property tests)")
            # pytest must not try to fill the strategy parameters as
            # fixtures: present a zero-argument signature.
            skipper.__wrapped__ = None
            del skipper.__wrapped__
            return skipper
        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Placeholder: only ever passed to the stub ``given``."""

        def __init__(self, name):
            self.name = name

        def __repr__(self):
            return f"<hypothesis-stub strategy {self.name}>"

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):
            return lambda *a, **k: _Strategy(name)

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _Strategies("hypothesis.strategies")
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _hyp.strategies
