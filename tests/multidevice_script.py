"""Executed by test_multidevice.py in a subprocess with 8 fake devices.
Validates the distribution layer end-to-end where the in-process suite
(1 CPU device) cannot: shard_map flash-decoding, sharded train step
numerics vs single-device, compressed psum with distinct shards.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.launch.mesh import (jit_shardings,  # noqa: E402
                               make_compat_mesh, set_mesh,
                               shard_map as compat_shard_map)

from repro import configs  # noqa: E402
from repro.models import layers as L  # noqa: E402
from repro.models.model import Model  # noqa: E402
from repro.optim import (OptimizerConfig, init_train_state,  # noqa: E402
                         make_train_step)
from repro.sharding import PolicyOptions, ShardingPolicy  # noqa: E402


def check_flash_decoding():
    mesh = make_compat_mesh((2, 4), ("data", "model"))
    cfg = configs.get_smoke("qwen2-1.5b")
    policy = ShardingPolicy(mesh, cfg, PolicyOptions())
    policy._decode_seq_axes = ("model",)
    rng = np.random.default_rng(0)
    b, h, hkv, s, d = 4, 4, 2, 32, 16
    q = jnp.asarray(rng.standard_normal((b, h, 1, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    lengths = jnp.asarray([s, s // 2, 7, s - 1], jnp.int32)
    with set_mesh(mesh):
        got = policy.sharded_decode_attention(q, kc, vc, lengths, None)
        got_w = policy.sharded_decode_attention(q, kc, vc, lengths, 6)
    want = L.decode_attention(q, kc, vc, lengths, None)
    want_w = L.decode_attention(q, kc, vc, lengths, 6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                               rtol=1e-5, atol=1e-5)
    print("flash-decoding OK")


def check_sharded_train_matches_single():
    """One jitted train step under a (2,4) mesh must match the
    single-device result bit-for-bit-ish."""
    cfg = configs.get_smoke("qwen3-4b")
    opt = OptimizerConfig(lr=1e-3, warmup_steps=1)
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                              jnp.int32),
    }
    # single device
    model0 = Model(cfg)
    state0 = init_train_state(model0, jax.random.key(0), opt)
    s0, m0 = jax.jit(make_train_step(model0, opt))(state0, batch)

    # sharded
    mesh = make_compat_mesh((2, 4), ("data", "model"))
    policy = ShardingPolicy(mesh, cfg)
    model1 = Model(cfg, policy=policy)
    with set_mesh(mesh):
        state1 = init_train_state(model1, jax.random.key(0), opt)
        pspec = policy.param_specs(state1["params"])
        state1 = {
            "params": jax.tree.map(
                lambda x, sp: jax.device_put(
                    x, jax.sharding.NamedSharding(mesh, sp)),
                state1["params"], pspec,
                is_leaf=lambda x: hasattr(x, "shape")),
            "opt": state1["opt"], "step": state1["step"]}
        s1, m1 = jax.jit(make_train_step(model1, opt))(state1, batch)
    l0, l1 = float(m0["loss"]), float(m1["loss"])
    assert abs(l0 - l1) / max(abs(l0), 1e-9) < 2e-2, (l0, l1)
    # a couple of updated leaves agree
    w0 = np.asarray(s0["params"]["lm_head"], np.float32)
    w1 = np.asarray(s1["params"]["lm_head"], np.float32)
    np.testing.assert_allclose(w0, w1, rtol=5e-2, atol=5e-3)
    print(f"sharded train OK (loss {l0:.4f} vs {l1:.4f})")


def check_compressed_psum_distinct_shards():
    mesh = make_compat_mesh((8,), ("data",))
    rng = np.random.default_rng(2)
    # shard along axis 0: each shard sees a distinct slice
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    xs = jax.device_put(x, jax.sharding.NamedSharding(mesh, P("data", None)))

    def local_mean(v):
        return jax.lax.psum(v, "data") / 8.0

    spec_in = P("data", None)
    want = np.broadcast_to(np.asarray(x).mean(0, keepdims=True), (1, 64))

    def body(v):
        from repro.distributed import quantize_int8
        q, s = quantize_int8(v)
        vsum = jax.lax.psum(q.astype(jnp.float32) * s, "data")
        return vsum / 8.0

    got = compat_shard_map(body, mesh=mesh, in_specs=spec_in,
                           out_specs=P(None, None))(xs)
    np.testing.assert_allclose(np.asarray(got)[0], want[0], atol=0.05)
    print("compressed psum OK")


def check_dryrun_single_cell_small_mesh():
    """End-to-end: lower+compile a reduced arch on an 8-dev mesh with
    the production-policy code path (train + decode kinds)."""
    from repro.configs.base import ShapeConfig
    mesh = make_compat_mesh((2, 4), ("data", "model"))
    for arch in ("qwen2-1.5b", "granite-moe-1b-a400m", "mamba2-2.7b",
                 "zamba2-2.7b", "whisper-large-v3", "qwen2-vl-2b"):
        cfg = configs.get_smoke(arch)
        policy = ShardingPolicy(mesh, cfg)
        model = Model(cfg, policy=policy)
        shape = ShapeConfig("t", "train", 32, 8)
        specs = model.input_specs(shape)
        with set_mesh(mesh):
            params_shape = jax.eval_shape(
                lambda: model.init(jax.random.key(0)))
            pspec = policy.param_specs(params_shape)
            bspec = policy.batch_specs(specs, shape)
            compiled = jax.jit(
                model.loss,
                in_shardings=jit_shardings(mesh, (pspec, bspec))
            ).lower(params_shape, specs).compile()
            assert compiled.cost_analysis() is not None
        # decode kind
        dshape = ShapeConfig("d", "decode", 64, 8)
        dspecs = model.input_specs(dshape)
        cache_shape = dspecs.pop("cache")
        with set_mesh(mesh):
            bspec = policy.batch_specs(dict(dspecs, cache=cache_shape),
                                       dshape)
            cspec = bspec.pop("cache")
            compiled = jax.jit(
                model.decode_step,
                in_shardings=jit_shardings(mesh, (pspec, bspec, cspec)),
            ).lower(params_shape, dspecs, cache_shape).compile()
        print(f"  {arch}: small-mesh train+decode compile OK")
    print("small-mesh dryrun OK")


if __name__ == "__main__":
    assert len(jax.devices()) == 8
    check_flash_decoding()
    check_compressed_psum_distinct_shards()
    check_sharded_train_matches_single()
    check_dryrun_single_cell_small_mesh()
    print("ALL MULTIDEVICE CHECKS PASSED")
