"""Guarded execution: passthrough jaxpr identity, detection of injected
upsets, and bit-exact recovery through the degradation ladder."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import faults as F
from repro.core import pipeline as pipe
from repro.core.guard import GuardPolicy
from repro.core.synthesis import CNN2Gate
from repro.models import cnn

RNG = np.random.default_rng(29)

#: Zero-slack policy: the audit flags ANY deviation from the calibration
#: run — deterministic when the guarded input is the calibration input.
STRICT = GuardPolicy(margin=0.0, sat_tol=0.0)


@pytest.fixture(scope="module")
def gate():
    g = CNN2Gate.from_graph(cnn.resnet_tiny(batch=1))
    x = (RNG.standard_normal((1, 3, 32, 32)) * 0.5).astype(np.float32)
    g.calibrate_quantization(x)
    return g, x


def test_guards_off_is_jaxpr_identical_passthrough(gate):
    g, x = gate
    xj = jnp.asarray(x)
    plain = g.build("emulation")
    guarded_off = g.build_guarded(policy=None)
    a = str(jax.make_jaxpr(lambda v: plain(v))(xj))
    b = str(jax.make_jaxpr(lambda v: guarded_off(v))(xj))
    assert a == b
    np.testing.assert_array_equal(np.asarray(plain(xj)),
                                  np.asarray(guarded_off(xj)))


def test_clean_run_passes_audit(gate):
    g, x = gate
    gx = g.build_guarded(x_cal=x, policy=STRICT)
    y, report = gx(jnp.asarray(x))
    assert report.ok and not report.detected and not report.degraded
    assert report.actions == [] and report.recovered_by is None
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(g.build("emulation")(jnp.asarray(x))))


def test_weight_flip_detected_and_recovered_bit_exact(gate):
    """Acceptance: flip one high bit of a staged conv weight; the guard
    must flag the run, escalate past reexecute (the corruption is
    persistent), and serve the unfused fallback — bit-exact against the
    clean program."""
    g, x = gate
    xj = jnp.asarray(x)
    clean = np.asarray(g.build("emulation")(xj))
    first_conv = next(ql.info.name for ql in g.quantized.layers
                      if ql.w_q is not None)
    plan = F.FaultPlan((F.Fault(F.WEIGHT_BIT, first_conv,
                                index=0, bit=6),))
    qm_f = F.inject(g.quantized, plan)
    gx = g.build_guarded(x_cal=x, policy=STRICT, qm=qm_f)
    y, report = gx(xj)
    assert report.detected and first_conv in report.flagged
    assert report.actions[0].action == "reexecute"
    assert report.actions[0].flagged  # persistent: reexecute re-flags
    assert report.recovered_by == "unfused" and report.degraded
    assert report.ok
    np.testing.assert_array_equal(np.asarray(y), clean)


def test_activation_fault_detected(gate):
    g, x = gate
    plan = F.FaultPlan.sample(g.quantized, 4, kinds=(F.ACTIVATION_BIT,),
                              seed=9, bits=(6, 7))
    gx = g.build_guarded(x_cal=x, policy=STRICT,
                         faults=plan.activation_faults())
    y, report = gx(jnp.asarray(x))
    assert report.detected
    assert report.ok  # ladder found a clean program
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(g.build("emulation")(jnp.asarray(x))))


def test_per_tensor_rung_serves_degraded_output():
    """With the unfused rung disabled, a per-channel program must fall
    through to the per-tensor rung and report degraded service."""
    g = CNN2Gate.from_graph(cnn.resnet_tiny(batch=1))
    x = (RNG.standard_normal((1, 3, 32, 32)) * 0.5).astype(np.float32)
    g.calibrate_quantization(x, per_channel=True)
    first_conv = next(ql.info.name for ql in g.quantized.layers
                      if ql.w_q is not None)
    plan = F.FaultPlan((F.Fault(F.WEIGHT_BIT, first_conv,
                                index=0, bit=6),))
    qm_f = F.inject(g.quantized, plan)
    policy = GuardPolicy(margin=0.0, sat_tol=0.0, fallback_unfused=False)
    gx = g.build_guarded(x_cal=x, policy=policy, qm=qm_f)
    y, report = gx(jnp.asarray(x))
    assert report.detected
    assert report.recovered_by == "per_tensor" and report.degraded
    assert report.ok


@pytest.fixture(scope="module")
def goog():
    g = CNN2Gate.from_graph(cnn.googlenet_tiny(batch=1))
    x = (RNG.standard_normal(g.parsed.input_shape) * 0.5).astype(np.float32)
    g.calibrate_quantization(x)
    return g, x


def test_concat_producer_fault_recovers_through_unfused_rung(goog):
    """ISSUE satellite: corrupt a weight of a stage whose output is
    written straight into a fused-concat merge buffer.  With no
    checkpoints the persistent fault must ride the ladder to the
    unfused fallback — and that fallback program must genuinely have
    concat fusion disabled, not just be a rebuilt copy."""
    g, x = goog
    xj = jnp.asarray(x)
    clean = np.asarray(g.build("emulation")(xj))
    producers = [ql.info.name for ql in g.quantized.layers
                 if ql.info.concat is not None and ql.w_q is not None]
    assert producers, "googlenet_tiny must fuse at least one concat"
    # a single flip can be masked in the datapath: probe until one
    # provably reaches the output
    for name in producers:
        for index, bit in ((0, 7), (1, 7), (0, 6), (2, 7)):
            plan = F.FaultPlan((F.Fault(F.WEIGHT_BIT, name,
                                        index=index, bit=bit),))
            qm_f = F.inject(g.quantized, plan)
            y_f = np.asarray(pipe.make_executor(qm_f, interpret=True)(xj))
            if not np.array_equal(y_f, clean):
                break
        else:
            continue
        break
    else:
        pytest.fail("no probed producer flip reached the output")
    gx = g.build_guarded(x_cal=x, policy=STRICT, qm=qm_f)
    y, report = gx(xj)
    assert report.detected
    assert report.actions[0].action == "reexecute"
    assert report.recovered_by == "unfused" and report.degraded
    assert report.ok
    np.testing.assert_array_equal(np.asarray(y), clean)
    lvl = gx._fallbacks["unfused"]
    assert lvl is not None
    assert not any(li.concat is not None or li.concat_fused
                   for li in lvl.qm.parsed.layers), \
        "rung 2 must disable concat fusion in the fallback program"


def test_with_program_shares_calibration(gate):
    """The bench's re-deployment hook: a new program under the same
    envelope, no recalibration."""
    g, x = gate
    gx = g.build_guarded(x_cal=x, policy=STRICT)
    plan = F.FaultPlan.sample(g.quantized, 2, kinds=(F.WEIGHT_BIT,),
                              seed=1, bits=(5, 6, 7))
    gx2 = gx.with_program(F.inject(g.quantized, plan))
    assert gx2._gold is gx._gold
    _, report = gx2(jnp.asarray(x))
    assert report.detected
