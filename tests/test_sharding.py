"""Sharding policy: divisibility guards, rule coverage, flash-decoding."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_compat_mesh, set_mesh
from repro.models.model import Model
from repro.models import layers as L
from repro.sharding import PolicyOptions, ShardingPolicy
from repro.configs.base import DECODE_32K


def small_mesh(data=2, model=2):
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return make_compat_mesh((data, model), ("data", "model"))


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_param_specs_valid_for_all_archs(arch):
    """Every leaf gets a spec whose sharded dims divide exactly."""
    cfg = configs.get(arch)
    mesh = small_mesh()
    policy = ShardingPolicy(mesh, cfg)
    model = Model(cfg, policy=policy)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = policy.param_specs(shapes)

    def check(leaf, spec):
        assert isinstance(spec, P)
        for dim, axis in zip(leaf.shape, tuple(spec)):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (arch, leaf.shape, spec)

    jax.tree.map(check, shapes, specs,
                 is_leaf=lambda x: hasattr(x, "shape"))


def test_matrix_params_are_model_sharded():
    cfg = configs.get("qwen3-4b")
    mesh = small_mesh()
    policy = ShardingPolicy(mesh, cfg)
    model = Model(cfg, policy=policy)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = policy.param_specs(shapes)
    # attention and mlp weights must use the model axis
    stack = specs["stack"]
    assert tuple(stack["attn"]["wq"]) == (None, None, "model")
    assert tuple(stack["attn"]["wo"]) == (None, "model", None)
    assert tuple(stack["mlp"]["w_down"]) == (None, "model", None)
    assert tuple(specs["lm_head"]) == (None, "model")


def test_moe_experts_sharded_on_model_axis():
    cfg = configs.get("granite-moe-1b-a400m")
    policy = ShardingPolicy(small_mesh(), cfg)
    model = Model(cfg, policy=policy)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = policy.param_specs(shapes)
    assert tuple(specs["stack"]["moe"]["w_up"]) == (None, "model", None, None)
    assert tuple(specs["stack"]["moe"]["router"])[-1] is None


def test_indivisible_dims_stay_replicated():
    """h2o head_dim=120-derived dims that don't divide stay unsharded."""
    cfg = configs.get("whisper-large-v3")   # 20 heads, hd 64
    mesh = small_mesh(2, 2)
    policy = ShardingPolicy(mesh, cfg)
    # a fake (20,)-dim leaf must not shard on a 2-way axis -> 20%2==0 ok;
    # use a 5-dim leaf for the negative case
    spec = policy._validated(P("model"), (5,))
    if mesh.shape["model"] == 2:
        assert tuple(spec) == (None,)


def test_decode_cache_specs_seq_sharded():
    cfg = configs.get("qwen2.5-32b")
    mesh = small_mesh()
    policy = ShardingPolicy(mesh, cfg)
    model = Model(cfg, policy=policy)
    specs = model.input_specs(DECODE_32K)
    bspecs = policy.batch_specs(specs, DECODE_32K)
    kspec = tuple(bspecs["cache"]["k"])
    # (L, B, KV, S, hd): batch on data, seq on model
    assert kspec[1] == "data" and kspec[3] == "model"


def test_long500k_batch1_seq_uses_both_axes():
    cfg = configs.get("zamba2-2.7b")
    from repro.configs.base import LONG_500K
    mesh = small_mesh()
    policy = ShardingPolicy(mesh, cfg)
    model = Model(cfg, policy=policy)
    specs = model.input_specs(LONG_500K)
    bspecs = policy.batch_specs(specs, LONG_500K)
    kspec = tuple(bspecs["cache"]["attn"]["k"])
    assert kspec[3] == ("data", "model")


def test_sharded_decode_attention_matches_reference():
    """shard_map flash-decoding == plain masked decode attention."""
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >=2 devices")
    mesh = make_compat_mesh((1, n), ("data", "model"))
    cfg = configs.get_smoke("qwen2-1.5b")
    policy = ShardingPolicy(mesh, cfg, PolicyOptions())
    policy._decode_seq_axes = ("model",)
    rng = np.random.default_rng(0)
    b, h, hkv, s, d = 2, 4, 2, 8 * n, 16
    q = jnp.asarray(rng.standard_normal((b, h, 1, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    lengths = jnp.asarray([s // 2, s - 3], jnp.int32)
    with set_mesh(mesh):
        got = policy.sharded_decode_attention(q, kc, vc, lengths, None)
    want = L.decode_attention(q, kc, vc, lengths, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_sharded_decode_attention_with_window():
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >=2 devices")
    mesh = make_compat_mesh((1, n), ("data", "model"))
    cfg = configs.get_smoke("h2o-danube-3-4b")
    policy = ShardingPolicy(mesh, cfg, PolicyOptions())
    policy._decode_seq_axes = ("model",)
    rng = np.random.default_rng(1)
    b, h, hkv, s, d = 2, 4, 2, 8 * n, 16
    q = jnp.asarray(rng.standard_normal((b, h, 1, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    lengths = jnp.asarray([s - 1, s // 2], jnp.int32)
    with set_mesh(mesh):
        got = policy.sharded_decode_attention(q, kc, vc, lengths, 6)
    want = L.decode_attention(q, kc, vc, lengths, 6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_zero1_optimizer_spec():
    cfg = configs.get("qwen2-1.5b")
    mesh = small_mesh()
    policy = ShardingPolicy(mesh, cfg)
    spec = policy.optimizer_spec(P(None, "model"), (8960, 1536))
    # first replicated divisible dim picks up the data axis
    assert tuple(spec) == ("data", "model")


def test_policy_act_constraint_applies():
    cfg = configs.get_smoke("qwen2-1.5b")
    mesh = small_mesh()
    policy = ShardingPolicy(mesh, cfg)
    dp = mesh.shape["data"]
    with set_mesh(mesh):
        x = jnp.zeros((2 * dp, 4, 8))
        y = jax.jit(policy.act)(x)
    assert y.shape == x.shape
