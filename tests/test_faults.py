"""Fault-injection layer: plan determinism, golden-image non-mutation,
and the jaxpr-identity guarantee of the executor's fault hooks."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import faults as F
from repro.core import pipeline as pipe
from repro.core import verify as V
from repro.core.synthesis import CNN2Gate
from repro.models import cnn

RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def gate():
    g = CNN2Gate.from_graph(cnn.resnet_tiny(batch=1))
    x = (RNG.standard_normal((1, 3, 32, 32)) * 0.5).astype(np.float32)
    g.calibrate_quantization(x)
    return g, x


def test_sample_deterministic_in_seed(gate):
    g, _ = gate
    kinds = (F.WEIGHT_BIT, F.BIAS_BIT, F.SCALE, F.DROPPED_TILE,
             F.ACTIVATION_BIT, F.ACTIVATION_TILE)
    a = F.FaultPlan.sample(g.quantized, 16, kinds=kinds, seed=3)
    b = F.FaultPlan.sample(g.quantized, 16, kinds=kinds, seed=3)
    assert a == b
    c = F.FaultPlan.sample(g.quantized, 16, kinds=kinds, seed=4)
    assert a != c


def test_inject_returns_new_model_golden_untouched(gate):
    g, _ = gate
    qm = g.quantized
    golden = [np.array(ql.w_q) for ql in qm.layers if ql.w_q is not None]
    plan = F.FaultPlan.sample(qm, 4, kinds=(F.WEIGHT_BIT,), seed=0)
    qm_f = F.inject(qm, plan)
    assert qm_f is not qm
    after = [np.array(ql.w_q) for ql in qm.layers if ql.w_q is not None]
    for w0, w1 in zip(golden, after):
        np.testing.assert_array_equal(w0, w1)
    # the corrupted program differs from the golden one
    diff = sum(int((np.array(a.w_q) != np.array(b.w_q)).sum())
               for a, b in zip(qm.layers, qm_f.layers)
               if a.w_q is not None)
    assert 1 <= diff <= 4  # one byte per weight_bit fault (collisions ok)


def test_single_weight_bit_flip_is_one_byte(gate):
    g, _ = gate
    qm = g.quantized
    target = next(ql for ql in qm.layers if ql.w_q is not None)
    plan = F.FaultPlan((F.Fault(F.WEIGHT_BIT, target.info.name,
                                index=7, bit=6),))
    qm_f = F.inject(qm, plan)
    w0 = np.array(target.w_q).reshape(-1)
    w1 = np.array(next(ql for ql in qm_f.layers
                       if ql.info.name == target.info.name).w_q).reshape(-1)
    changed = np.nonzero(w0 != w1)[0]
    assert list(changed) == [7]
    assert (int(w0[7]) ^ int(w1[7])) & 0xFF == 1 << 6


def test_unknown_stage_rejected(gate):
    g, _ = gate
    plan = F.FaultPlan((F.Fault(F.WEIGHT_BIT, "no_such_stage"),))
    with pytest.raises(KeyError, match="no_such_stage"):
        F.inject(g.quantized, plan)


def test_activation_fault_changes_output(gate):
    g, x = gate
    qm = g.quantized
    xj = jnp.asarray(x)
    clean = np.asarray(pipe.make_executor(qm, interpret=True)(xj))
    plan = F.FaultPlan.sample(qm, 3, kinds=(F.ACTIVATION_BIT,), seed=5)
    payload = plan.activation_faults()
    assert payload  # at least one tensor targeted
    ex_f = pipe.make_executor(qm, interpret=True, faults=payload)
    faulty = np.asarray(ex_f(xj))
    assert not np.array_equal(clean, faulty)


def test_fault_hooks_off_keep_jaxpr_identical(gate):
    """``faults=None`` / ``faults={}`` / ``audit=False`` must trace the
    exact same program as the pre-existing executor — the hooks are
    trace-time-only."""
    g, x = gate
    qm = g.quantized
    batch = x.shape[0]
    # the verifier's executor_jaxpr traces the same interpret-mode
    # program the probes analyze — one tracer for every identity test
    base = V.executor_jaxpr(qm, batch=batch, as_text=True)
    off = V.executor_jaxpr(qm, batch=batch, as_text=True,
                           audit=False, faults=None)
    empty = V.executor_jaxpr(qm, batch=batch, as_text=True, faults={})
    assert base == off == empty
