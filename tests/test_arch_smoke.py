"""Per-arch smoke tests: reduced same-family config, one forward/train
step on CPU, asserting output shapes + no NaNs (deliverable f)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models.model import Model
from repro.optim import OptimizerConfig, init_train_state, make_train_step

RNG = np.random.default_rng(7)
B, S = 2, 16


def make_batch(cfg, with_labels=True):
    batch = {}
    if cfg.input_embeds:
        batch["embeds"] = jnp.asarray(
            RNG.standard_normal((B, S, cfg.d_model)) * 0.1, jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if with_labels:
        batch["labels"] = jnp.asarray(
            RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(
            RNG.standard_normal((B, cfg.encoder_seq, cfg.d_model)) * 0.1,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    logits = model.forward(params, make_batch(cfg))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_one_train_step(arch):
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=1)
    state = init_train_state(model, jax.random.key(0), opt)
    step = jax.jit(make_train_step(model, opt))
    state2, metrics = step(state, make_batch(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(state2["step"]) == 1
    # params actually changed
    diff = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state["params"], state2["params"])
    assert max(jax.tree.leaves(diff)) > 0


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "qwen3-4b", "mamba2-2.7b",
                                  "zamba2-2.7b", "whisper-large-v3",
                                  "granite-moe-1b-a400m", "qwen2-vl-2b",
                                  "h2o-danube-3-4b"])
def test_prefill_decode_consistency(arch):
    """prefill + N decode steps must equal the full forward."""
    cfg = configs.get_smoke(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    batch = make_batch(cfg, with_labels=False)
    ref = model.forward(params, dict(batch, labels=None))
    prefix = 10
    pre = {k: (v[:, :prefix] if k == "tokens"
               else (v[:, :, :prefix] if k == "positions" else
                     (v[:, :prefix] if k == "embeds" else v)))
           for k, v in batch.items()}
    lg, cache = model.prefill(params, pre, cache_len=S + 2)
    errs = [float(np.abs(np.asarray(lg[:, -1], np.float32)
                         - np.asarray(ref[:, prefix - 1], np.float32)).max())]
    for t in range(prefix, S):
        db = {"lengths": jnp.asarray(t, jnp.int32)}
        if cfg.input_embeds:
            db["embeds"] = batch["embeds"][:, t:t + 1]
        else:
            db["tokens"] = batch["tokens"][:, t:t + 1]
        lg, cache = model.decode_step(params, db, cache)
        errs.append(float(np.abs(np.asarray(lg[:, 0], np.float32)
                                 - np.asarray(ref[:, t], np.float32)).max()))
    assert max(errs) < 2e-4, errs


def test_param_counts_match_assignment():
    """Full configs must land near the published sizes."""
    expect = {
        "qwen2-1.5b": 1.5e9, "qwen3-4b": 4.4e9, "qwen2.5-32b": 32.8e9,
        "h2o-danube-3-4b": 4.0e9, "granite-moe-1b-a400m": 1.3e9,
        "mamba2-2.7b": 2.7e9, "whisper-large-v3": 1.6e9,
        "zamba2-2.7b": 2.4e9, "qwen2-vl-2b": 1.8e9,
        "llama4-scout-17b-a16e": 102e9,
    }
    for arch, want in expect.items():
        got = configs.get(arch).param_count()
        assert abs(got - want) / want < 0.12, (arch, got, want)


def test_moe_active_params():
    g = configs.get("granite-moe-1b-a400m")
    assert 0.35e9 < g.active_param_count() < 0.5e9
    l4 = configs.get("llama4-scout-17b-a16e")
    assert l4.active_param_count() < 0.2 * l4.param_count()


def test_sliding_window_ring_decode():
    """SWA ring-buffer cache (size == window) must equal the full-cache
    windowed decode — the long_500k memory-bounding mechanism."""
    cfg = configs.get_smoke("h2o-danube-3-4b")
    assert cfg.sliding_window == 32
    model = Model(cfg)
    params = model.init(jax.random.key(2))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 48)), jnp.int32)
    ref = model.forward(params, {"tokens": toks})
    # decode token-by-token with a ring cache of exactly window size
    cache = model.init_cache(1, cfg.sliding_window)
    assert cache["k"].shape[3] == cfg.sliding_window
    errs = []
    for t in range(48):
        lg, cache = model.decode_step(
            params, {"tokens": toks[:, t:t + 1],
                     "lengths": jnp.asarray(t, jnp.int32)}, cache)
        if t >= cfg.sliding_window:  # fully in-window regime
            errs.append(float(np.abs(
                np.asarray(lg[:, 0], np.float32)
                - np.asarray(ref[:, t], np.float32)).max()))
    assert max(errs) < 2e-4, max(errs)
