"""Checkpointed stage-boundary recovery (DESIGN.md §11): executor
snapshot/replay bit-exactness, the guard's checkpoint-replay rung on
linear and branchy models, placement math, and the DSE's checkpoint
memory accounting."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import faults as F
from repro.core import pipeline as pipe
from repro.core import resources as R
from repro.core.guard import GuardPolicy
from repro.core.spaces import CNNDesignSpace
from repro.core.synthesis import CNN2Gate
from repro.models import cnn

RNG = np.random.default_rng(43)

STRICT = GuardPolicy(margin=0.0, sat_tol=0.0)


def _gate(builder):
    g = CNN2Gate.from_graph(builder(batch=1))
    x = (RNG.standard_normal(g.parsed.input_shape) * 0.5).astype(np.float32)
    g.calibrate_quantization(x)
    return g, x


@pytest.fixture(scope="module")
def resnet():
    return _gate(cnn.resnet_tiny)


@pytest.fixture(scope="module")
def goog():
    return _gate(cnn.googlenet_tiny)


# ------------------------------------------------------- executor hooks

def test_checkpoint_build_output_identical(resnet):
    g, x = resnet
    xj = jnp.asarray(x)
    y0 = np.asarray(g.build("emulation")(xj))
    ex = pipe.make_executor(g.quantized, interpret=True,
                            checkpoints=R.plan_checkpoints(g.parsed, 2))
    y, ckpts = ex(xj)
    np.testing.assert_array_equal(np.asarray(y), y0)
    assert len(ckpts) == 2


def test_snapshot_matches_liveness_model(resnet):
    """The snapshot the executor takes is exactly the liveness set the
    resource model charges the DSE for — same tensors, same bytes."""
    g, x = resnet
    boundaries = R.plan_checkpoints(g.parsed, 2)
    ex = pipe.make_executor(g.quantized, interpret=True,
                            checkpoints=boundaries)
    _, ckpts = ex(jnp.asarray(x))
    names = [ql.info.name for ql in g.quantized.layers]
    for b in boundaries:
        snap = ckpts[names[b]]
        model = R.checkpoint_live_bytes(g.parsed, b)
        assert set(snap) == set(model)
        for t, arr in snap.items():
            assert np.asarray(arr).nbytes == model[t]
    assert R.checkpoint_bytes(g.parsed, boundaries) == sum(
        np.asarray(a).nbytes
        for b in boundaries for a in ckpts[names[b]].values())


def test_replay_bit_exact_from_every_eligible_boundary(resnet):
    g, x = resnet
    xj = jnp.asarray(x)
    y0 = np.asarray(g.build("emulation")(xj))
    elig = R.eligible_checkpoints(g.parsed)
    ex = pipe.make_executor(g.quantized, interpret=True, checkpoints=elig)
    _, ckpts = ex(xj)
    names = [ql.info.name for ql in g.quantized.layers]
    for b in elig:
        rex = pipe.make_executor(g.quantized, interpret=True,
                                 replay_from=b)
        yr = rex(ckpts[names[b]])
        np.testing.assert_array_equal(np.asarray(yr), y0)


def test_checkpoint_inside_fused_concat_group_rejected(goog):
    g, _ = goog
    layers = g.parsed.layers
    name_idx = {li.name: i for i, li in enumerate(layers)}
    producer = next(i for i, li in enumerate(layers)
                    if li.concat is not None)
    c_end = name_idx[layers[producer].concat.name]
    assert producer < c_end
    for bad in range(producer, c_end):
        assert bad not in R.eligible_checkpoints(g.parsed)
    with pytest.raises(ValueError, match="fused-concat"):
        pipe.make_executor(g.quantized, interpret=True,
                           checkpoints=[producer])


def test_plan_checkpoints_properties(resnet):
    g, _ = resnet
    elig = set(R.eligible_checkpoints(g.parsed))
    assert R.plan_checkpoints(g.parsed, 0) == ()
    seen = []
    for k in (1, 2, 3, len(g.parsed.layers) + 5):
        plan = R.plan_checkpoints(g.parsed, k)
        assert plan == R.plan_checkpoints(g.parsed, k)  # deterministic
        assert len(plan) == min(k, len(elig))
        assert set(plan) <= elig
        assert list(plan) == sorted(set(plan))
        seen.append(plan)
    assert R.checkpoint_bytes(g.parsed, seen[0]) <= \
        R.checkpoint_bytes(g.parsed, seen[-1])


# --------------------------------------------------- the recovery rung

@pytest.mark.parametrize("fixture", ["resnet", "goog"])
def test_guard_checkpoint_recovery_bit_exact(fixture, request):
    """Acceptance: a persistent single-stage weight fault recovers
    through the checkpoint-replay rung bit-exact against the clean
    program, replaying strictly fewer stages than the network depth —
    on the linear model AND the branchy fused-concat one."""
    g, x = request.getfixturevalue(fixture)
    xj = jnp.asarray(x)
    clean = np.asarray(g.build("emulation")(xj))
    depth = len(g.quantized.layers)
    # a single flip can be architecturally masked (die inside the
    # datapath): probe candidates until one provably reaches the output
    last_w = [ql.info.name for ql in g.quantized.layers
              if ql.w_q is not None][-1]
    for index, bit in ((0, 7), (1, 7), (2, 7), (0, 6), (3, 7)):
        plan = F.FaultPlan((F.Fault(F.WEIGHT_BIT, last_w,
                                    index=index, bit=bit),))
        qm_f = F.inject(g.quantized, plan)
        y_f = np.asarray(pipe.make_executor(qm_f, interpret=True)(xj))
        if not np.array_equal(y_f, clean):
            break
    else:
        pytest.fail("no probed flip reached the output")
    gx = g.build_guarded(x_cal=x, policy=STRICT, qm=qm_f, checkpoints=2)
    y, report = gx(xj)
    assert report.detected
    assert report.recovered_by == "checkpoint_replay"
    assert report.outcome == "checkpoint_replayed"
    assert report.ok and not report.degraded
    act = report.actions[0]
    assert act.action == "checkpoint_replay" and not act.flagged
    assert 0 < act.replayed < depth
    np.testing.assert_array_equal(np.asarray(y), clean)


def test_no_upstream_snapshot_falls_through_to_reexecute(resnet):
    """A fault flagged before the first boundary has no snapshot to
    replay from: the rung is skipped and the ladder proceeds as
    before (reexecute, then fallback for a persistent fault)."""
    g, x = resnet
    first_w = next(ql.info.name for ql in g.quantized.layers
                   if ql.w_q is not None)
    plan = F.FaultPlan((F.Fault(F.WEIGHT_BIT, first_w, index=0, bit=6),))
    gx = g.build_guarded(x_cal=x, policy=STRICT,
                         qm=F.inject(g.quantized, plan), checkpoints=2)
    y, report = gx(jnp.asarray(x))
    assert report.detected
    assert report.actions[0].action == "reexecute"
    assert report.recovered_by == "unfused" and report.ok
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(g.build("emulation")(jnp.asarray(x))))


def test_checkpoints_and_replay_from_are_exclusive(resnet):
    g, _ = resnet
    with pytest.raises(ValueError, match="exclusive"):
        pipe.make_executor(g.quantized, interpret=True,
                           checkpoints=[1], replay_from=1)


# ------------------------------------------------- DSE memory property

def test_dse_checkpoint_charge_never_exceeds_budget(resnet):
    """Property (ISSUE satellite): for every option the DSE accepts,
    the row-band working set PLUS the retained checkpoint bytes fit the
    board's declared on-chip memory — resilience cannot silently
    overcommit block RAM."""
    g, _ = resnet
    board = R.FPGA_BOARDS["5CSEMA5"]
    space = CNNDesignSpace(g.parsed, board, block_h_options=[8, 16],
                           checkpoint_options=[0, 1, 2, 4])
    assert space.axis_names() == ["n_i", "n_l", "block_h", "ckpt_k"]
    accepted_k = set()
    for opt in space.options():
        rep = space.evaluate(opt)
        band = rep.raw["band_ws_bytes"]
        ck = rep.raw["ckpt_bytes"]
        assert len(rep.raw["ckpt_plan"]) == min(
            opt[3], len(R.eligible_checkpoints(g.parsed)))
        if rep.fits:
            accepted_k.add(opt[3])
            assert 8 * (band + ck) <= board.mem_bits
            assert rep.percents["mem"] <= 100.0
    # the axis must be a real choice on this board, not vacuous
    assert 0 in accepted_k and any(k > 0 for k in accepted_k)
