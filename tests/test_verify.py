"""qverify static analyzer (DESIGN.md §13): the adversarial matrix.

Every seeded violation class must trip exactly its rule, and the
shipped builders must verify clean — the verifier is only trustworthy
if it is both sound on bad programs and quiet on good ones.  The last
tests pin the acceptance property that verification never changes the
emitted program (executor jaxpr byte-identity with verify on/off).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import parser as P
from repro.core import pipeline as pipe
from repro.core import verify as V
from repro.core.quantize import QuantSpec
from repro.core.resources import eligible_checkpoints
from repro.core.synthesis import CNN2Gate
from repro.models import cnn


def _resnet_gate(per_channel=False, seed=0):
    gate = CNN2Gate.from_graph(cnn.resnet_tiny(batch=1))
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(gate.parsed.input_shape) * 0.5
         ).astype(np.float32)
    gate.calibrate_quantization(x, per_channel=per_channel)
    return gate


def _rule_ids(diags):
    return {d.rule_id for d in diags}


# ------------------------------------------------------ clean programs

def test_shipped_builders_verify_clean():
    for builder in (cnn.resnet_tiny, cnn.squeezenet_tiny):
        gate = CNN2Gate.from_graph(builder(batch=1))
        rng = np.random.default_rng(0)
        x = (rng.standard_normal(gate.parsed.input_shape) * 0.5
             ).astype(np.float32)
        gate.calibrate_quantization(x)
        rep = gate.verify()
        assert rep.ok and not rep.diagnostics, rep.render()


def test_report_api():
    d_err = V.Diagnostic("QV101", V.ERROR, stage="c1", tensor="t",
                         detail="boom")
    d_warn = V.Diagnostic("QV206", V.WARNING, stage="x")
    rep = V.VerificationReport([d_err, d_warn])
    assert not rep.ok
    assert rep.errors == [d_err] and rep.warnings == [d_warn]
    assert rep.by_rule("QV101") == [d_err]
    assert rep.rule_ids == ("QV101", "QV206")
    assert "QV101" in str(d_err) and "stage=c1" in str(d_err)
    with pytest.raises(V.VerificationError) as ei:
        rep.raise_if_errors()
    assert ei.value.diagnostics == (d_err,)
    assert isinstance(ei.value, ValueError)  # legacy guards keep working
    # warnings alone never raise
    assert V.VerificationReport([d_warn]).raise_if_errors().ok


# ------------------------------------------- QV101: accumulator overflow

def test_overflow_prone_spec_trips_qv101():
    """A huge-Cin conv whose weights quantize to full-magnitude int8:
    128 * Cin*KH*KW*|w_q| blows int32 — the verifier must prove it."""
    cin = 16384  # 128 * (3*3*16384 taps * 115) ≈ 2.17e9 > 2^31-1
    b = cnn.GraphBuilder("overflow", (1, cin, 4, 4))
    b.conv(8, 3, pad=1, relu=False)
    b.inits["conv_1_w"][:] = 0.9  # every tap quantizes hot
    parsed = P.parse(b.build())
    name = next(li.name for li in parsed.layers if li.kind == P.CONV)
    specs = {name: QuantSpec(m_w=7, m_x=0, m_y=7)}  # w_q = ±115
    rep = V.verify_program(parsed, specs)
    assert _rule_ids(rep.errors) == {"QV101"}
    assert "int32" in rep.errors[0].detail
    with pytest.raises(V.VerificationError, match="QV101"):
        pipe.build_quantized(parsed, specs)
    # a sane spec (small m_w: weights quantize coarsely) is provable
    ok = {name: QuantSpec(m_w=0, m_x=0, m_y=0)}
    assert V.verify_program(parsed, ok).ok


def test_per_channel_overflow_localized_to_lane():
    """Only the hot lane's spec overflows; per-lane analysis must still
    catch it (a per-tensor mean would not)."""
    cin = 16384
    b = cnn.GraphBuilder("pc_overflow", (1, cin, 4, 4))
    b.conv(4, 3, pad=1, relu=False)
    b.inits["conv_1_w"][:] = 0.9
    parsed = P.parse(b.build())
    name = next(li.name for li in parsed.layers if li.kind == P.CONV)
    specs = {name: QuantSpec(m_w=(0, 0, 7, 0), m_x=0, m_y=0)}
    rep = V.verify_program(parsed, specs)
    assert "QV101" in _rule_ids(rep.errors)
    assert "lane 2" in " ".join(d.detail for d in rep.by_rule("QV101"))


# --------------------------------------- QV201/QV102: shift range rules

def test_negative_requant_shift_trips_qv201():
    gate = _resnet_gate()
    specs = dict(gate.specs)
    name = next(li.name for li in gate.parsed.layers
                if li.kind == P.CONV)
    s = specs[name]
    specs[name] = dataclasses.replace(s, m_y=s.m_w + s.m_x + 3)
    rep = V.verify_program(gate.parsed, specs, check_identity=False)
    assert "QV201" in _rule_ids(rep.errors)
    with pytest.raises(V.VerificationError, match="QV201"):
        pipe.build_quantized(gate.parsed, specs)


def test_oversized_shift_trips_qv102():
    gate = _resnet_gate()
    specs = dict(gate.specs)
    name = next(li.name for li in gate.parsed.layers
                if li.kind == P.CONV)
    specs[name] = QuantSpec(m_w=40, m_x=0, m_y=0)  # shift 40 > MAX_SHIFT
    rep = V.verify_program(gate.parsed, specs, check_identity=False)
    assert "QV102" in _rule_ids(rep.errors)


# ------------------------------------------ QV202: negative merge align

def test_negative_merge_alignment_trips_qv202():
    """A merge spec pinned above its operand positions cannot be
    reached by right shifts — QV202, and build_quantized agrees (its
    raise keeps the historical 'alignment' wording)."""
    gate = _resnet_gate()
    pm = gate.parsed
    host = next(li for li in pm.layers if li.merge is not None)
    specs = {li.name: QuantSpec(m_w=7, m_x=6, m_y=6)
             for li in pm.layers if li.kind in (P.CONV, P.FC)}
    specs[host.merge.name] = QuantSpec(m_w=0, m_x=8, m_y=8)
    rep = V.verify_program(pm, specs, check_identity=False)
    assert "QV202" in _rule_ids(rep.errors)
    with pytest.raises(ValueError, match="alignment"):
        pipe.build_quantized(pm, specs)


# ------------------------------------------ QV203: threading conflicts

def test_conflicting_pins_trip_qv203():
    """Two consumers of one tensor demanding different m_x: the runtime
    thread_scales silently keeps the first pin — the verifier calls the
    conflict out."""
    b = cnn.GraphBuilder("fork", (1, 4, 8, 8))
    b.conv(4, 3, pad=1)
    t = b.tap()                      # shared fan-out tensor
    b.conv(4, 3, pad=1)
    a = b.tap()
    b.from_tap(t).conv(4, 3, pad=1)  # second consumer of t
    b.add_from(a, relu=False)
    parsed = P.parse(b.build(), fuse_skip=False)
    c0, ca, cb = (li.name for li in parsed.layers if li.kind == P.CONV)
    m = next(li.name for li in parsed.layers if li.kind == P.ADD)
    specs = {c0: QuantSpec(m_w=4, m_x=4, m_y=4),
             ca: QuantSpec(m_w=4, m_x=4, m_y=4),
             cb: QuantSpec(m_w=4, m_x=5, m_y=4),  # disagrees on t
             m: QuantSpec(m_w=0, m_x=4, m_y=4)}
    _m, diags = V.thread_scales_checked(parsed, specs)
    assert "QV203" in _rule_ids(diags)


def test_missing_weighted_spec_trips_qv205():
    gate = _resnet_gate()
    specs = dict(gate.specs)
    dropped = next(li.name for li in gate.parsed.layers
                   if li.kind == P.CONV)
    del specs[dropped]
    rep = V.verify_program(gate.parsed, specs, check_identity=False)
    assert "QV205" in _rule_ids(rep.errors)
    assert any(d.stage == dropped for d in rep.by_rule("QV205"))


# --------------------------------------------- QV206: malformed specs

def test_wrong_lane_count_trips_qv206():
    gate = _resnet_gate()
    specs = dict(gate.specs)
    name = next(li.name for li in gate.parsed.layers
                if li.kind == P.CONV)
    specs[name] = dataclasses.replace(specs[name], m_w=(4, 4, 4))
    rep = V.verify_program(gate.parsed, specs, check_identity=False)
    assert "QV206" in _rule_ids(rep.errors)


def test_strict_per_tensor_conflict_trips_qv206():
    gate = _resnet_gate(per_channel=True)
    rep = V.verify_program(gate.parsed, gate.specs, per_channel=False,
                           check_identity=False)
    assert "QV206" in _rule_ids(rep.errors)
    with pytest.raises(ValueError,
                       match="per_channel=False was requested"):
        pipe.build_quantized(gate.parsed, gate.specs, per_channel=False)


# ----------------------------------------- QV301: concat partitioning

def _fused_concat_model():
    gate = CNN2Gate.from_graph(cnn.squeezenet_tiny(batch=1))
    rng = np.random.default_rng(3)
    x = (rng.standard_normal(gate.parsed.input_shape) * 0.5
         ).astype(np.float32)
    gate.calibrate_quantization(x)
    return gate


def _with_offset(parsed, delta):
    """Clone the parse with the first fused producer's concat_offset
    shifted by ``delta`` — the seeded overlapping-slices violation."""
    layers = list(parsed.layers)
    i, li = next((i, li) for i, li in enumerate(layers)
                 if li.concat is not None and li.concat_offset > 0)
    layers[i] = dataclasses.replace(li,
                                    concat_offset=li.concat_offset + delta)
    return dataclasses.replace(parsed, layers=layers)


def test_overlapping_concat_offsets_trip_qv301():
    gate = _fused_concat_model()
    bad = _with_offset(gate.parsed, -1)  # slides onto the previous slice
    diags = V.check_concat_partition(bad)
    assert _rule_ids(diags) == {"QV301"}
    assert any("overlap" in d.detail for d in diags)
    # gaps (slide the slice the other way) are equally fatal
    diags = V.check_concat_partition(_with_offset(gate.parsed, +1))
    assert _rule_ids(diags) == {"QV301"}
    # and the clean program really partitions
    assert V.check_concat_partition(gate.parsed) == []


# --------------------------------- QV302/QV303: liveness & slice escape

def test_use_after_release_trips_qv302():
    """A stage spliced into a committed schedule that re-reads a tensor
    the journaled release plan already dropped: static analysis must
    see the dangling read (the executor's environment pops buffers at
    exactly those indices)."""
    gate = _resnet_gate()
    pm = gate.parsed
    plan = V.release_schedule(pm)  # buffer lifetimes the build committed
    layers = list(pm.layers)
    first_conv = next(li for li in layers if li.kind == P.CONV)
    final = layers[-1]
    # a fake consumer of the first conv's long-released output, spliced
    # after the (renamed) final stage
    layers[-1] = dataclasses.replace(final, output=final.output + "_t")
    tail = dataclasses.replace(
        final, name="late",
        inputs=(layers[-1].output, first_conv.output),
        output=pm.output_name)
    bad = dataclasses.replace(pm, layers=layers + [tail])
    diags = V.check_liveness(bad, release_at=plan)
    assert "QV302" in _rule_ids(diags)
    assert any("release" in d.detail for d in diags)
    # a self-consistent schedule re-derives its own plan and is clean
    assert V.check_liveness(bad) == []


def test_use_before_def_trips_qv302():
    gate = _resnet_gate()
    pm = gate.parsed
    layers = list(pm.layers)
    li = next(li for li in layers if li.kind == P.CONV)
    i = layers.index(li)
    layers[i] = dataclasses.replace(li, inputs=("never_made",))
    diags = V.check_liveness(dataclasses.replace(pm, layers=layers))
    assert "QV302" in _rule_ids(diags)
    assert any("before any scheduled stage" in d.detail for d in diags)


def test_fused_slice_escape_trips_qv303():
    """A consumer reading a fused-concat producer's output directly:
    that tensor only exists as a slice of the shared merge buffer."""
    gate = _fused_concat_model()
    pm = gate.parsed
    layers = list(pm.layers)
    prod = next(li for li in layers if li.concat is not None)
    cc_i = next(i for i, li in enumerate(layers)
                if li.name == prod.concat.name)
    after = layers[cc_i + 1]
    layers[cc_i + 1] = dataclasses.replace(
        after, inputs=tuple(after.inputs) + (prod.output,))
    diags = V.check_liveness(dataclasses.replace(pm, layers=layers))
    assert "QV303" in _rule_ids(diags)


# --------------------------------------- QV304: checkpoint boundaries

def test_in_group_checkpoint_boundary_trips_qv304():
    gate = _fused_concat_model()
    pm = gate.parsed
    blocked = sorted(set(range(len(pm.layers) - 1))
                     - set(eligible_checkpoints(pm)))
    assert blocked  # squeezenet has fused-concat groups
    diags = V.check_checkpoint_boundaries(pm, [blocked[0]])
    assert _rule_ids(diags) == {"QV304"}
    assert "fused-concat" in diags[0].detail
    # make_executor delegates to the same rule
    with pytest.raises(ValueError, match="fused-concat"):
        pipe.make_executor(gate.quantized, interpret=True,
                           checkpoints=[blocked[0]])
    # and the guard proves boundaries before building anything
    from repro.core.guard import GuardPolicy, GuardedExecutor
    x = np.zeros(pm.input_shape, np.float32)
    with pytest.raises(V.VerificationError):
        GuardedExecutor(gate, x, policy=GuardPolicy(),
                        checkpoints=[blocked[0]])


def test_out_of_range_boundary_trips_qv304():
    gate = _resnet_gate()
    diags = V.check_checkpoint_boundaries(gate.parsed, [99])
    assert _rule_ids(diags) == {"QV304"}
    assert "outside the schedule" in diags[0].detail
    assert V.check_checkpoint_boundaries(
        gate.parsed, eligible_checkpoints(gate.parsed)) == []


# ------------------------------------------- QV401/QV402: budget rules

def test_vmem_budget_rules():
    gate = _resnet_gate()
    # unarmed: budgets are deployment decisions, not program properties
    assert V.check_resources(gate.parsed, vmem_budget=None) == []
    tight = V.check_resources(gate.parsed, n_i=16, n_l=32,
                              vmem_budget=1024)
    assert "QV401" in _rule_ids(tight)
    ck = eligible_checkpoints(gate.parsed)[:2]
    armed = V.check_resources(gate.parsed, n_i=16, n_l=32,
                              vmem_budget=10 ** 5, checkpoints=ck)
    assert "QV402" in _rule_ids(armed)
    roomy = V.check_resources(gate.parsed, n_i=16, n_l=32,
                              vmem_budget=10 ** 9, checkpoints=ck)
    assert roomy == []


# ------------------------------------------------ DSE & CLI integration

def test_design_space_charges_verifier_rejects_like_infeasible():
    from repro.core.dse import FAILED_PCT
    from repro.core.resources import FPGA_BOARDS
    from repro.core.spaces import CNNDesignSpace

    gate = _resnet_gate()
    bad_specs = dict(gate.specs)
    name = next(li.name for li in gate.parsed.layers
                if li.kind == P.CONV)
    s = bad_specs[name]
    bad_specs[name] = dataclasses.replace(s, m_y=s.m_w + s.m_x + 3)
    space = CNNDesignSpace(gate.parsed, FPGA_BOARDS["ARRIA10"],
                           specs=bad_specs)
    assert "QV201" in space.verifier_errors
    rep = space.evaluate(space.options()[0])
    assert not rep.fits and rep.percents["mem"] == FAILED_PCT
    assert rep.raw["verifier"] == list(space.verifier_errors)
    # clean specs evaluate normally
    good = CNNDesignSpace(gate.parsed, FPGA_BOARDS["ARRIA10"],
                          specs=gate.specs)
    assert good.verifier_errors == ()
    assert good.evaluate(good.options()[0]).percents["mem"] < 100.0


def test_robust_evaluator_does_not_retry_verifier_rejects():
    from repro.core import dse

    class _Space(dse.DesignSpace):
        def __init__(self):
            self.calls = 0

        def options(self):
            return [(1, 1)]

        def axes(self):
            return [[1], [1]]

        def evaluate(self, option):
            self.calls += 1
            raise V.VerificationError(
                [V.Diagnostic("QV201", V.ERROR, stage="c1")])

    space = _Space()
    ev = dse.RobustEvaluator(space, retries=3, backoff_s=0.0)
    rep = ev.evaluate((1, 1))
    assert not rep.fits
    assert space.calls == 1  # deterministic failure: no retries
    assert "QV201" in next(iter(ev.quarantined.values()))


def test_verify_cli_clean_on_zoo_model():
    from repro.launch import verify as cli

    assert cli.main(["--models", "resnet_tiny", "--per-channel", "off",
                     "--fused", "on"]) == 0
    with pytest.raises(SystemExit):
        cli.main(["--models", "nope"])
    assert cli.main(["--list-rules"]) == 0


# ------------------------------- acceptance: verification is pure

def test_executor_jaxpr_byte_identical_with_verification():
    gate = _resnet_gate()
    qm_v = pipe.build_quantized(gate.parsed, gate.specs, verify=True)
    qm_n = pipe.build_quantized(gate.parsed, gate.specs, verify=False)
    assert V.executor_jaxpr(qm_v, as_text=True) == \
        V.executor_jaxpr(qm_n, as_text=True)
