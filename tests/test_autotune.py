"""Pod-scale DSE autotuner (the paper's fitter on TPU) — subprocess
test with the 512-device production mesh and a tiny option space."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(900)
def test_autotune_bf_small_space(tmp_path):
    out = tmp_path / "autotune.json"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.autotune",
         "--arch", "qwen2-1.5b", "--shape", "train_4k", "--algo", "bf",
         "--axes", "remat=dots", "--axes", "n_micro=1,8",
         "--eval-depth", "1", "--lut-threshold", "2000",
         "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=870,
        cwd=root)
    sys.stdout.write(res.stdout)
    sys.stderr.write(res.stderr[-3000:])
    assert res.returncode == 0
    payload = json.loads(out.read_text())
    assert payload["best"] is not None
    assert payload["evaluations"] == 2
    # every history entry carries Algorithm-1 feasibility info
    assert all("fits" in h or "f_avg" in h for h in payload["history"])
    # the fitter must prefer the option with better utilisation
    by_opt = {json.dumps(h["option"], sort_keys=True): h
              for h in payload["history"]}
    best = json.dumps(payload["best"], sort_keys=True)
    feasible = [h for h in by_opt.values() if h["fits"]]
    if feasible:
        top = max(feasible, key=lambda h: h["f_avg"])
        assert json.dumps(top["option"], sort_keys=True) == best
