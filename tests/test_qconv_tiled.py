"""Row-tiled qconv kernel + whole-network fused NHWC executor.

Parity matrix (bit-exact vs kernels/ref.py oracles): stride-2 convs,
pool windows straddling row-band boundaries (AlexNet's overlapping
3x3/2 pool), Cout not a multiple of 128, block_h not dividing H.  Plus
the executor's no-transpose invariant, the row-band VMEM working-set
drop, and the block_h DSE axis.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dse
from repro.core import pipeline as pipe
from repro.core.parser import parse
from repro.core.resources import (FPGA_BOARDS, VMEM_BUDGET_BYTES,
                                  conv_band_working_set)
from repro.core.spaces import CNNDesignSpace
from repro.core.synthesis import CNN2Gate
from repro.kernels import ops, ref
from repro.kernels.qconv import band_geometry, qconv2d, vmem_bytes
from repro.models import cnn

RNG = np.random.default_rng(7)


def i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, shape, np.int8))


# ------------------------------------------------------ kernel parity
@pytest.mark.parametrize("cfg", [
    # (h, w, cin, cout, k, stride, pool, block_h)
    (16, 16, 4, 8, 3, 1, None, 4),        # plain banding
    (23, 23, 8, 32, 5, 2, None, 3),       # stride-2, block_h !| oh
    (27, 27, 16, 64, 3, 1, (3, 2), 2),    # AlexNet 3x3/2 pool straddles bands
    (27, 27, 16, 64, 3, 1, (3, 2), 5),    # same, ragged band count
    (14, 14, 32, 130, 3, 1, (2, 2), 3),   # cout not a multiple of 128
    (11, 11, 8, 16, 3, 2, (2, 2), 1),     # stride-2 conv + pool, 1-row bands
    (18, 18, 4, 24, 3, 1, (2, 2), 100),   # block_h > oh clamps to one band
])
@pytest.mark.parametrize("shift,relu", [(7, True), (4, False)])
def test_tiled_qconv_matches_ref(cfg, shift, relu):
    h, w, cin, cout, k, stride, pool, bh = cfg
    x = i8(2, h, w, cin)
    wt = i8(k, k, cin, cout)
    b = jnp.asarray(RNG.integers(-1000, 1000, (cout,), np.int32))
    got = qconv2d(x, wt, b, strides=(stride, stride), shift=shift, relu=relu,
                  pool=pool, block_cout=64, block_h=bh, interpret=True)
    want = ref.qconv2d_ref(x, wt, b, (stride, stride), shift, relu, pool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_block_h_invariance():
    """Every band height must give the identical bit pattern."""
    x, wt = i8(1, 21, 21, 8), i8(3, 3, 8, 16)
    outs = [np.asarray(qconv2d(x, wt, None, strides=(1, 1), shift=6,
                               relu=True, pool=(3, 2), block_h=bh,
                               interpret=True))
            for bh in (1, 2, 4, 7, None)]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


def test_band_geometry_halo():
    # no pool: halo is the kh-1 conv overlap
    conv_rows, in_rows, in_step = band_geometry(4, 3, 1, None)
    assert (conv_rows, in_rows, in_step) == (4, 6, 4)
    # AlexNet 3x3/2 pool: last window carries pw-ps=1 row past the stride
    conv_rows, in_rows, in_step = band_geometry(4, 3, 1, (3, 2))
    assert conv_rows == 9 and in_rows == 11 and in_step == 8
    # stride-2 conv scales the input step
    _cr, in_rows2, in_step2 = band_geometry(4, 3, 2, None)
    assert in_step2 == 8 and in_rows2 == 9


# ------------------------------------------- NHWC pool paths (int8-native)
@pytest.mark.parametrize("window,stride,pads", [
    (2, 2, (0, 0, 0, 0)), (3, 2, (0, 0, 0, 0)), (2, 2, (1, 0, 1, 0))])
def test_nhwc_pools_match_ref(window, stride, pads):
    x = i8(2, 12, 12, 5)
    got_max = ops.maxpool2d_nhwc(x, window, stride, pads)
    got_avg = ops.avgpool2d_nhwc(x, window, stride, pads)
    xp_max = jnp.pad(x, ((0, 0), (pads[0], pads[2]), (pads[1], pads[3]),
                         (0, 0)), constant_values=ref.INT8_MIN)
    np.testing.assert_array_equal(
        np.asarray(got_max), np.asarray(ref.maxpool2d_ref(xp_max, window, stride)))
    # independent numpy window-loop oracle for the avg pool (exclude-pad
    # divide): ops.avgpool2d_nhwc shares code with ref.avgpool2d_ref, so
    # comparing those two against each other would prove nothing
    xn = np.asarray(x, np.int64)
    oh = (12 + pads[0] + pads[2] - window) // stride + 1
    ow = (12 + pads[1] + pads[3] - window) // stride + 1
    want = np.zeros((2, oh, ow, 5), np.int64)
    for i in range(oh):
        for j in range(ow):
            h0, h1 = max(0, i * stride - pads[0]), \
                min(12, i * stride - pads[0] + window)
            w0, w1 = max(0, j * stride - pads[1]), \
                min(12, j * stride - pads[1] + window)
            count = (h1 - h0) * (w1 - w0)
            want[:, i, j, :] = np.floor(
                (xn[:, h0:h1, w0:w1, :].sum((1, 2)) + count // 2) / count)
    np.testing.assert_array_equal(np.asarray(got_avg),
                                  np.clip(want, -128, 127))
    assert got_max.dtype == jnp.int8 and got_avg.dtype == jnp.int8


# --------------------------------------------------- fused executor
def _count_transposes(jaxpr) -> int:
    """Transpose eqns reaching XLA, recursing through pjit/closed calls
    but NOT into pallas_call (its internal emulation is opaque)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "transpose":
            n += 1
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            if isinstance(v, jax.core.ClosedJaxpr):
                n += _count_transposes(v.jaxpr)
            elif isinstance(v, jax.core.Jaxpr):
                n += _count_transposes(v)
    return n


@pytest.fixture(scope="module")
def tiny_gate():
    gate = CNN2Gate.from_graph(cnn.tiny_cnn(batch=2))
    x = (RNG.standard_normal((2, 3, 32, 32)) * 0.5).astype(np.float32)
    gate.calibrate_quantization(x)
    return gate, x


def test_executor_single_ingress_conversion(tiny_gate):
    """Whole-network fused dataflow: exactly ONE layout transpose (the
    NCHW->NHWC ingress; tiny_cnn ends in FC so there is no egress one).
    The seed executor emitted two per conv/pool stage."""
    gate, x = tiny_gate
    ex = pipe.make_executor(gate.quantized, interpret=True)
    jaxpr = jax.make_jaxpr(lambda v: ex(v))(jnp.asarray(x))
    assert _count_transposes(jaxpr.jaxpr) == 1


def test_executor_matches_oracle_chain(tiny_gate):
    """Fused NHWC executor == float oracle top-1 and invariant to
    block_h (pure blocking knob)."""
    gate, x = tiny_gate
    g = cnn.tiny_cnn(batch=2)
    y_f = np.asarray(cnn.run_float(g, jnp.asarray(x)))
    outs = [np.asarray(pipe.run_int8(gate.quantized, jnp.asarray(x),
                                     interpret=True, block_h=bh))
            for bh in (None, 2, 3, 8)]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)
    assert np.all(outs[0].argmax(-1) == y_f.argmax(-1))


def test_executor_caches_per_config(tiny_gate):
    gate, x = tiny_gate
    qm = gate.quantized
    qm._executors.clear()
    pipe.run_int8(qm, jnp.asarray(x), interpret=True)
    pipe.run_int8(qm, jnp.asarray(x), interpret=True)
    pipe.run_int8(qm, jnp.asarray(x), interpret=True, block_h=4)
    assert len(qm._executors) == 2


def test_fc_weight_staging_nhwc_flatten_order():
    """The conv->FC boundary needs no runtime transpose: FC rows are
    permuted at build time to NHWC-flatten order."""
    gate = CNN2Gate.from_graph(cnn.tiny_cnn(batch=1))
    x = (RNG.standard_normal((1, 3, 32, 32)) * 0.5).astype(np.float32)
    gate.calibrate_quantization(x)
    fc = next(ql for ql in gate.quantized.layers if ql.info.kind == "fc")
    w_raw = gate.parsed.graph.initializers[fc.info.weight]
    from repro.core.quantize import quantize_weights
    w_q, _ = quantize_weights(w_raw, None, fc.spec)
    prev4d = next(li for li in reversed(gate.parsed.layers[
        :gate.parsed.layers.index(fc.info)]) if len(li.out_shape) == 4)
    _n, c, h, w = prev4d.out_shape
    want = (w_q.reshape(c, h, w, -1).transpose(1, 2, 0, 3)
            .reshape(w_q.shape[0], -1))
    np.testing.assert_array_equal(np.asarray(fc.w_q), want)


# ------------------------------------------------ VMEM working-set model
def test_vgg_layer_working_set_drops_4x():
    """Acceptance: VGG-16 224x224x64 layer (3x3/1, pad 1) per-step VMEM
    drops >= 4x with row-band tiling."""
    whole = vmem_bytes(226, 226, 64, 3, 3, 128, 224, 224)
    band = vmem_bytes(226, 226, 64, 3, 3, 128, 224, 224, block_h=8)
    assert whole / band >= 4.0
    assert band <= VMEM_BUDGET_BYTES  # the tiled band actually fits VMEM
    assert whole > VMEM_BUDGET_BYTES  # ...which the whole plane did not


def test_band_working_set_monotone_in_block_h():
    pm = parse(cnn.alexnet())
    ws = [conv_band_working_set(pm.layers, 32, bh) for bh in (1, 4, 16, 64)]
    assert ws == sorted(ws)
    assert conv_band_working_set(pm.layers, 32, None) >= ws[-1]


# ----------------------------------------------------- block_h in the DSE
def test_dse_explores_block_h_axis():
    pm = parse(cnn.alexnet())
    space = CNNDesignSpace(pm, FPGA_BOARDS["ARRIA10"],
                           block_h_options=[4, 8, 16])
    assert len(space.axes()) == 3
    assert all(len(o) == 3 for o in space.options())
    res = dse.rl_dse(space, seed=0)
    assert res.found and len(res.best) == 3
    assert res.best[2] in (4, 8, 16)


def test_dse_rejects_oversized_row_band():
    """A band whose working set exceeds the board's on-chip memory must
    be infeasible (mem quota > 100), and the fitter must avoid it."""
    pm = parse(cnn.alexnet())
    board = FPGA_BOARDS["5CSEMA5"]  # 4 Mbit on-chip
    space = CNNDesignSpace(pm, board, block_h_options=[1, 55])
    rep_big = space.evaluate((8, 8, 55))   # whole-plane-scale band
    assert rep_big.percents["mem"] > 100.0 and not rep_big.fits
    rep_small = space.evaluate((8, 8, 1))  # line-buffer-scale band
    assert rep_small.fits
    res = dse.brute_force(space)
    assert res.found and res.best[2] == 1


def test_explore_with_block_h_through_synthesis():
    gate = CNN2Gate.from_graph(cnn.alexnet())
    res = gate.explore("ARRIA10", algo="bf", block_h_options=[4, 8])
    assert res.found and len(res.best) == 3
    assert res.best[:2] == (16, 32)  # paper's decision is preserved
