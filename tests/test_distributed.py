"""Distributed utilities: compression + error feedback, straggler
monitor, microbatch accumulation, data pipeline determinism."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import distributed as D
from repro.data.pipeline import DataConfig, Prefetcher, make_source


# ------------------------------------------------------- compression
@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 1000))
def test_int8_quantize_error_bounded(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(128) * rng.uniform(0.01, 10))
    q, s = D.quantize_int8(x)
    err = np.abs(np.asarray(D.dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_accumulation():
    """Sum of EF-compressed grads converges to the sum of true grads:
    total quantization error stays bounded by one step's error."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(64, np.float32)
    ef_sum = np.zeros(64, np.float32)
    e = {"g": jnp.zeros(64, jnp.float32)}
    for step in range(50):
        g = rng.standard_normal(64).astype(np.float32)
        true_sum += g
        deq, e_new = D.ef_compress({"g": jnp.asarray(g)}, e)
        e = e_new
        ef_sum += np.asarray(deq["g"])
    resid = np.abs(true_sum - ef_sum)
    # residual equals the current feedback buffer — one step's error
    np.testing.assert_allclose(resid, np.abs(np.asarray(e["g"])), atol=1e-4)
    assert resid.max() < 0.2  # int8 on unit-scale grads


def test_ef_training_converges_like_uncompressed():
    """Toy quadratic: EF-compressed SGD reaches the optimum."""
    w_true = jnp.asarray(np.random.default_rng(1).standard_normal(16))

    def loss(w, x):
        return jnp.mean((x @ (w - w_true)) ** 2)

    rng = np.random.default_rng(2)
    w = jnp.zeros(16)
    e = {"w": jnp.zeros(16)}
    for _ in range(300):
        x = jnp.asarray(rng.standard_normal((8, 16)))
        g = jax.grad(loss)(w, x)
        deq, e = D.ef_compress({"w": g}, e)
        w = w - 0.1 * deq["w"]
    assert float(jnp.linalg.norm(w - w_true)) < 0.05


def test_compressed_psum_matches_mean():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    got = D.compressed_psum(x, "data", mesh)
    # all shards hold identical x (replicated spec) -> mean == x
    np.testing.assert_allclose(np.asarray(got), np.asarray(x),
                               atol=0.05, rtol=0.05)


# ---------------------------------------------------------- straggler
def test_straggler_monitor_detects_outliers():
    mon = D.StragglerMonitor(threshold=2.0, sustained=2)
    for s in range(10):
        assert mon.observe(s, 1.0) is None
    ev = mon.observe(10, 5.0)
    assert ev is not None and ev.ratio == pytest.approx(5.0)
    assert not mon.should_checkpoint
    mon.observe(11, 5.0)
    assert mon.should_checkpoint
    mon.observe(12, 1.0)  # recovery resets
    assert not mon.should_checkpoint


def test_straggler_median_robust_to_drift():
    mon = D.StragglerMonitor(threshold=2.0)
    for s in range(20):
        mon.observe(s, 1.0 + 0.01 * s)  # slow drift is not an outlier
    assert mon.events == []


# ----------------------------------------------------- microbatching
def test_accumulating_step_matches_full_batch():
    rng = np.random.default_rng(4)
    w = {"w": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)}
    batch = {"x": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
             "y": jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)}

    def loss(params, b):
        return jnp.mean((b["x"] @ params["w"] - b["y"]) ** 2)

    l1, g1 = D.make_accumulating_step(loss, 1)(w, batch)
    l4, g4 = D.make_accumulating_step(loss, 4)(w, batch)
    assert l1 == pytest.approx(float(l4), rel=1e-5)
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g4["w"]),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------------ data pipeline
def test_data_deterministic_and_host_disjoint():
    base = dict(vocab_size=101, seq_len=32, global_batch=8, seed=5)
    s_a = make_source(DataConfig(**base, host_id=0, num_hosts=2))
    s_b = make_source(DataConfig(**base, host_id=1, num_hosts=2))
    b0 = s_a.batch_at(3)
    b0_again = s_a.batch_at(3)
    np.testing.assert_array_equal(b0["tokens"], b0_again["tokens"])
    assert b0["tokens"].shape == (4, 32)
    assert not np.array_equal(b0["tokens"], s_b.batch_at(3)["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b0["labels"][:, :-1], b0["tokens"][:, 1:])


def test_prefetcher_resumes_at_step():
    src = make_source(DataConfig(vocab_size=50, seq_len=8, global_batch=2,
                                 seed=1))
    pf = Prefetcher(src, start_step=7)
    step, batch = next(pf)
    assert step == 7
    np.testing.assert_array_equal(batch["tokens"],
                                  src.batch_at(7)["tokens"])
    step2, _ = next(pf)
    assert step2 == 8
    pf.close()


def test_prefetcher_propagates_producer_error():
    """A failing source must surface on the consumer thread — after the
    already-buffered good batches — instead of hanging ``__next__``."""
    class Corrupt:
        def batch_at(self, step):
            if step >= 2:
                raise ValueError("corrupt shard")
            return {"tokens": np.zeros((1, 2), np.int32)}

    pf = Prefetcher(Corrupt(), start_step=0, depth=2)
    got = []
    with pytest.raises(RuntimeError, match="producer failed") as ei:
        for _ in range(5):
            got.append(next(pf)[0])
    assert got == [0, 1]            # buffered batches drain first
    assert isinstance(ei.value.__cause__, ValueError)
    pf.close()


def test_synthetic_data_is_learnable():
    """The synthetic LM has structure: a bigram table beats uniform."""
    src = make_source(DataConfig(vocab_size=32, seq_len=64, global_batch=16,
                                 seed=0))
    counts = np.ones((32, 32))
    for s in range(20):
        b = src.batch_at(s)
        np.add.at(counts, (b["tokens"].ravel(), b["labels"].ravel()), 1)
    probs = counts / counts.sum(1, keepdims=True)
    b = src.batch_at(99)
    nll = -np.mean(np.log(probs[b["tokens"].ravel(), b["labels"].ravel()]))
    assert nll < 0.7 * np.log(32)  # far better than uniform
