"""Multi-device distribution tests, run in a subprocess so the fake
device count (XLA_FLAGS) can be set before jax initialises — the
in-process suite keeps the normal 1-device view."""
import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(900)
def test_multidevice_suite():
    script = os.path.join(os.path.dirname(__file__),
                          "multidevice_script.py")
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    res = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=880)
    sys.stdout.write(res.stdout)
    sys.stderr.write(res.stderr[-4000:])
    assert res.returncode == 0
    assert "ALL MULTIDEVICE CHECKS PASSED" in res.stdout
