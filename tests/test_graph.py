"""Graph IR + Eq. (3)/(4) shape inference — property-tested vs lax."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graph import Graph, GraphError, Node, TensorInfo, conv_output_hw
from repro.core import onnx_lite
from repro.core import parser
from repro.models import cnn


@settings(max_examples=200, deadline=None)
@given(
    h=st.integers(4, 64), w=st.integers(4, 64),
    k=st.integers(1, 5), s=st.integers(1, 3),
    p=st.integers(0, 3), d=st.integers(1, 2),
)
def test_eq3_matches_lax_conv_shape(h, w, k, s, p, d):
    """Eq. (3) must agree with XLA's own convolution shape rule."""
    if h + 2 * p < d * (k - 1) + 1 or w + 2 * p < d * (k - 1) + 1:
        return  # degenerate: no valid output
    ho, wo = conv_output_hw((h, w), (k, k), (s, s), (p, p, p, p), (d, d))
    out = jax.eval_shape(
        lambda x, wt: jax.lax.conv_general_dilated(
            x, wt, (s, s), ((p, p), (p, p)), rhs_dilation=(d, d),
            dimension_numbers=("NCHW", "OIHW", "NCHW")),
        jax.ShapeDtypeStruct((1, 3, h, w), jnp.float32),
        jax.ShapeDtypeStruct((8, 3, k, k), jnp.float32),
    )
    assert out.shape == (1, 8, ho, wo)


def test_graph_toposort_and_cycle_detection():
    nodes = [
        Node("Relu", "r2", ["t1"], ["t2"]),
        Node("Relu", "r1", ["x"], ["t1"]),  # out of order on purpose
    ]
    g = Graph("g", nodes, [TensorInfo("x", (1, 4))], ["t2"])
    assert [n.name for n in g.nodes] == ["r1", "r2"]
    with pytest.raises(GraphError):
        Graph("bad", [Node("Relu", "r", ["t"], ["t"])],
              [TensorInfo("x", (1, 4))], ["t"])


def test_undefined_tensor_rejected():
    with pytest.raises(GraphError):
        Graph("g", [Node("Relu", "r", ["nope"], ["y"])],
              [TensorInfo("x", (1, 4))], ["y"])


def test_shape_inference_full_network():
    g = cnn.alexnet(batch=2)
    assert g.shape(g.outputs[0]) == (2, 1000)
    g = cnn.vgg16(batch=1)
    assert g.shape(g.outputs[0]) == (1, 1000)


def test_onnx_lite_roundtrip_file(tmp_path):
    g = cnn.tiny_cnn()
    onnx_lite.save(g, str(tmp_path / "m"))
    g2 = onnx_lite.load(str(tmp_path / "m"))
    assert [n.op_type for n in g2.nodes] == [n.op_type for n in g.nodes]
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 3, 32, 32)),
                    jnp.float32)
    np.testing.assert_allclose(np.asarray(cnn.run_float(g, x)),
                               np.asarray(cnn.run_float(g2, x)), rtol=1e-6)


def test_parser_fuses_conv_relu_pool():
    pm = parser.parse(cnn.alexnet())
    kinds = [(l.kind, l.relu, l.pool is not None) for l in pm.layers]
    # Fig. 6: 5 conv stages (1, 2, 5 pooled) + 3 FC stages
    assert kinds == [
        ("conv", True, True), ("conv", True, True), ("conv", True, False),
        ("conv", True, False), ("conv", True, True),
        ("fc", True, False), ("fc", True, False), ("fc", False, False),
    ]
    assert pm.layers[-1].softmax
    # linked structure preserves order
    assert pm.layers[0].next is pm.layers[1]
    assert pm.layers[1].prev is pm.layers[0]


def test_parser_op_counts_match_paper_tables():
    # Table 3: 80.04 GOp/s * 18.24 ms  => ~1.46 GOp AlexNet
    # Table 4: 151.7 GOp/s * 205 ms    => ~31.1 GOp VGG-16
    assert abs(parser.parse(cnn.alexnet()).total_ops / 1e9 - 1.43) < 0.1
    assert abs(parser.parse(cnn.vgg16()).total_ops / 1e9 - 30.94) < 0.5


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_divisibility_constraints_hold(data):
    """Property (§4.2): every feasible N_i divides all c_in (beyond the
    first layer); every feasible N_l divides all non-final c_out."""
    pm = parser.parse(cnn.alexnet())
    ni = data.draw(st.sampled_from(pm.feasible_ni()))
    nl = data.draw(st.sampled_from(pm.feasible_nl()))
    for li in pm.layers[1:]:
        assert li.c_in % ni == 0
    for li in pm.layers[:-1]:
        assert li.c_out % nl == 0
