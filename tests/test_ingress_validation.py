"""Ingress validation: imported models with poisoned weights or broken
structure are rejected with a structured GraphValidationError."""
import numpy as np
import pytest

from repro.core import onnx_lite
from repro.core.graph import (Graph, GraphValidationError, Node,
                              TensorInfo)
from repro.core.parser import parse, validate_ingress
from repro.models import cnn


def _poisoned_graph():
    g = cnn.tiny_cnn()
    w_name = next(n.inputs[1] for n in g.nodes if n.op_type == "Conv")
    g.initializers[w_name] = g.initializers[w_name].copy()
    g.initializers[w_name].reshape(-1)[3] = np.nan
    return g, w_name


def test_parse_rejects_nan_weight():
    g, w_name = _poisoned_graph()
    with pytest.raises(GraphValidationError) as ei:
        parse(g)
    assert ei.value.reason == "non-finite initializer"
    assert ei.value.tensor == w_name
    assert "1 NaN/Inf" in str(ei.value)


def test_validation_error_is_a_value_error():
    g, _ = _poisoned_graph()
    with pytest.raises(ValueError):
        parse(g)


def test_from_model_dict_rejects_nan_initializer():
    g = cnn.tiny_cnn()
    model = onnx_lite.to_model_dict(g)
    inits = dict(g.initializers)
    name = next(iter(inits))
    inits[name] = np.full_like(inits[name], np.inf)
    with pytest.raises(GraphValidationError) as ei:
        onnx_lite.from_model_dict(model, inits)
    assert ei.value.tensor == name


def test_from_model_dict_rejects_malformed_container():
    with pytest.raises(GraphValidationError, match="malformed"):
        onnx_lite.from_model_dict({"nodes": "nope", "inputs": [],
                                   "outputs": []})
    with pytest.raises(GraphValidationError, match="malformed"):
        onnx_lite.from_model_dict({"inputs": [], "outputs": []})


def test_from_model_dict_rejects_dangling_edge():
    model = {
        "nodes": [{"op_type": "Relu", "name": "r",
                   "inputs": ["ghost"], "outputs": ["y"]}],
        "inputs": [{"name": "x", "shape": [1, 3, 4, 4]}],
        "outputs": ["y"],
    }
    with pytest.raises(GraphValidationError) as ei:
        onnx_lite.from_model_dict(model, {})
    assert ei.value.reason == "invalid graph structure"
    assert "ghost" in str(ei.value)


def test_parse_rejects_dynamic_weight_operand():
    """A Conv whose weight arrives as a graph input (not an
    initializer) cannot be staged into on-chip memory."""
    nodes = [Node("Conv", "c", ["x", "w_dyn"], ["y"],
                  {"kernel_shape": (3, 3), "pads": (1, 1, 1, 1)})]
    g = Graph("dynw", nodes,
              inputs=[TensorInfo("x", (1, 3, 8, 8)),
                      TensorInfo("w_dyn", (4, 3, 3, 3))],
              outputs=["y"])
    with pytest.raises(GraphValidationError) as ei:
        validate_ingress(g)
    assert ei.value.reason == "weight operand is not an initializer"
    assert ei.value.node == "c" and ei.value.tensor == "w_dyn"


def test_clean_model_round_trips(tmp_path):
    g = cnn.tiny_cnn()
    path = str(tmp_path / "model")
    onnx_lite.save(g, path)
    g2 = onnx_lite.load(path)
    parse(g2)  # no exception: validation passes on healthy ingress
