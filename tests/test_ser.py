"""Vectorized SER campaigns + selective hardening (core/ser.py):
batched trial classification against the golden run, Wilson intervals,
the vectorized recovery path, and the derived audit policy."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import faults as F
from repro.core import pipeline as pipe
from repro.core import ser
from repro.core.synthesis import CNN2Gate
from repro.models import cnn

RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def gate():
    g = CNN2Gate.from_graph(cnn.resnet_tiny(batch=1))
    x = (RNG.standard_normal((1, 3, 32, 32)) * 0.5).astype(np.float32)
    g.calibrate_quantization(x)
    return g, x


@pytest.fixture(scope="module")
def campaign(gate):
    g, x = gate
    return ser.run_campaign(
        g, x, trials=16, flips=1,
        kinds=(F.WEIGHT_BIT, F.ACTIVATION_BIT, F.DROPPED_TILE),
        seed=3, checkpoints=2, chunk=8)


def test_wilson_interval():
    lo, hi = ser.wilson(0, 0)
    assert (lo, hi) == (0.0, 1.0)
    lo, hi = ser.wilson(5, 10)
    assert lo < 0.5 < hi
    lo, hi = ser.wilson(10, 10)
    assert lo > 0.69 and hi == 1.0
    lo, hi = ser.wilson(0, 100)
    assert lo == 0.0 and hi < 0.05
    w10 = np.diff(ser.wilson(5, 10))
    w100 = np.diff(ser.wilson(50, 100))
    assert w100 < w10  # more trials, tighter interval


def test_weight_and_fault_args_noop_is_golden(gate):
    """The campaign's argument-passing executor with golden weights and
    an all-zero XOR payload is bit-identical to the plain build — the
    no-op padding slots really are no-ops, also under vmap."""
    g, x = gate
    xj = jnp.asarray(x)
    y0 = np.asarray(g.build("emulation")(xj))
    wnames = tuple(ql.info.name for ql in g.quantized.layers
                   if ql.w_q is not None)[:2]
    t0 = g.quantized.layers[0].info.output
    ex = pipe.make_executor(g.quantized, interpret=True,
                            weight_args=wnames, fault_args=(t0,))
    W = {n: next(ql.w_q for ql in g.quantized.layers
                 if ql.info.name == n) for n in wnames}
    nop = {t0: (np.zeros(2, np.int32), np.zeros(2, np.int8))}
    np.testing.assert_array_equal(np.asarray(ex(xj, W, nop)), y0)
    vex = jax.vmap(ex, in_axes=(None, None, 0))
    batch = {t0: (np.zeros((3, 2), np.int32), np.zeros((3, 2), np.int8))}
    ys = np.asarray(vex(xj, W, batch))
    for i in range(3):
        np.testing.assert_array_equal(ys[i], y0)


def test_campaign_outcomes_partition_trials(campaign):
    c = campaign
    counts = c.counts()
    assert counts["detected"] + counts["masked"] + counts["silent"] \
        == c.trials == 16
    assert counts["silent"] == 0
    for r in c.records:
        assert r.outcome in ("detected", "masked", "silent")
        if r.outcome == "detected":
            assert r.recovered
            assert 0 < r.replayed <= c.n_stages
            if not r.escalated:
                assert r.replayed < c.n_stages
        else:
            assert not r.recovered and r.replayed == 0


def test_campaign_summary_is_json_with_cis(campaign):
    s = campaign.summary()
    doc = json.loads(json.dumps(s))  # JSON-serializable end to end
    assert doc["version"] == ser.SCHEMA_VERSION
    assert doc["trials"] == 16
    for key in ("detected", "masked", "silent", "recovered"):
        r = doc["rates"][key]
        assert 0.0 <= r["lo"] <= r["p"] <= r["hi"] <= 1.0
    for st in doc["per_stage"].values():
        assert st["trials"] >= 1
        assert st["avf"]["hi"] <= 1.0


def test_campaign_rejects_unvectorizable_kinds(gate):
    g, x = gate
    with pytest.raises(ValueError, match="vectorized"):
        ser.run_campaign(g, x, trials=2, kinds=(F.SCALE,))


def test_derived_policy_covers_every_reached_trial(gate, campaign):
    g, _ = gate
    pol = ser.derive_guard_policy([campaign], g.parsed)
    sel = set(pol.audit_stages)
    assert g.parsed.layers[-1].name in sel  # output always certified
    assert len(sel) < len(g.parsed.layers)  # actually selective
    for r in campaign.records:
        if r.output_differs:
            assert set(r.flagged) & sel, \
                f"trial {r.plan.seed} uncovered by {sorted(sel)}"


def test_selective_policy_still_detects_and_recovers(gate, campaign):
    """End to end: deploy the derived (subset-audit) policy with
    checkpoints against a campaign fault — still detected, still
    recovered bit-exact."""
    g, x = gate
    pol = ser.derive_guard_policy([campaign], g.parsed)
    rec = next(r for r in campaign.records
               if r.outcome == "detected" and r.plan.program_faults
               and set(r.flagged) & set(pol.audit_stages))
    gx = g.build_guarded(x_cal=x, policy=pol,
                         qm=F.inject(g.quantized, rec.plan),
                         checkpoints=2)
    y, report = gx(jnp.asarray(x))
    assert report.detected and report.ok
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(g.build("emulation")(jnp.asarray(x))))


def test_derive_policy_refuses_silent_evidence(gate, campaign):
    g, _ = gate
    import dataclasses
    bad = dataclasses.replace(campaign) if False else ser.Campaign(
        model=campaign.model, flips=1, kinds=campaign.kinds, seed=0,
        boundaries=campaign.boundaries,
        boundary_names=campaign.boundary_names,
        n_stages=campaign.n_stages,
        records=[ser.TrialRecord(plan=F.FaultPlan(()), stages=("conv_1",),
                                 flagged=(), outcome="silent",
                                 output_differs=True)])
    with pytest.raises(ValueError, match="silent"):
        ser.derive_guard_policy([bad], g.parsed)
