"""Standalone average-pool stages: parser, kernels, end-to-end int8."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import parser
from repro.core.synthesis import CNN2Gate
from repro.kernels import ops, ref
from repro.models import cnn

RNG = np.random.default_rng(11)


def test_parser_does_not_fuse_avgpool():
    pm = parser.parse(cnn.tiny_cnn_gap())
    kinds = [(l.kind, l.pool_type if l.kind == "pool" else None,
              l.pool is not None) for l in pm.layers]
    # conv (no fused pool), avg pool, conv, global avg pool, fc
    assert kinds == [("conv", None, False), ("pool", "avg", False),
                     ("conv", None, False), ("pool", "avg", False),
                     ("fc", None, False)]


@settings(max_examples=50, deadline=None)
@given(h=st.integers(4, 16), c=st.integers(1, 8),
       k=st.sampled_from([2, 3]), s=st.sampled_from([1, 2]))
def test_avgpool_ref_matches_float_rounding(h, c, k, s):
    if h < k:
        return
    x = RNG.integers(-128, 128, (1, h, h, c), np.int8)
    got = np.asarray(ref.avgpool2d_ref(jnp.asarray(x), k, s))
    # round-half-up fixed-point mean
    from numpy.lib.stride_tricks import sliding_window_view
    win = sliding_window_view(x.astype(np.int64), (k, k), axis=(1, 2))
    win = win[:, ::s, ::s]
    want = np.floor((win.sum((-1, -2)) + k * k // 2) / (k * k))
    np.testing.assert_array_equal(got, np.clip(want, -128, 127))


def test_padded_avgpool_excludes_pad_pixels():
    """ONNX default (count_include_pad=0): a padded window averages
    only its real taps.  Regression for the old divide-by-k*k behaviour
    that dragged border means toward zero — pinned against an explicit
    numpy window loop."""
    rng = np.random.default_rng(3)
    x = rng.integers(-128, 128, (2, 5, 7, 3), np.int8)
    k, s, p = 3, 2, 1
    got = np.asarray(ops.avgpool2d_nhwc(jnp.asarray(x), k, s, (p, p, p, p)))
    xp = np.pad(x.astype(np.int64), ((0, 0), (p, p), (p, p), (0, 0)))
    oh = (x.shape[1] + 2 * p - k) // s + 1
    ow = (x.shape[2] + 2 * p - k) // s + 1
    want = np.zeros((2, oh, ow, 3), np.int64)
    for i in range(oh):
        for j in range(ow):
            win = xp[:, i * s:i * s + k, j * s:j * s + k, :]
            # real (non-pad) taps of this window in original coords
            hi0, hi1 = max(0, i * s - p), min(x.shape[1], i * s - p + k)
            wj0, wj1 = max(0, j * s - p), min(x.shape[2], j * s - p + k)
            count = (hi1 - hi0) * (wj1 - wj0)
            want[:, i, j, :] = np.floor(
                (win.sum((1, 2)) + count // 2) / count)
    np.testing.assert_array_equal(got, np.clip(want, -128, 127))
    # corner window covers 4 of 9 taps: include-pad semantics would
    # have divided by 9 — make sure at least one corner differs
    inc = np.floor((xp[:, 0:k, 0:k, :].sum((1, 2)) + k * k // 2)
                   / (k * k))
    assert not np.array_equal(want[:, 0, 0, :], inc)


def test_padded_avgpool_int8_network_matches_float():
    """End-to-end: a network with a *padded* AveragePool stage — the
    int8 exclude-pad divide must track the float oracle's exclude-pad
    mean (both sides changed together; include-pad float would drift)."""
    b = cnn.GraphBuilder("padavg", (4, 3, 14, 14), 6)
    b.conv(8, 3, pad=1).avgpool(3, 2, pad=1)
    b.conv(16, 3, pad=1).global_avgpool()
    b.fc(5, relu=False, softmax=True)
    g = b.build()
    pm = parser.parse(g)
    assert any(li.kind == "pool" and any(li.pads) for li in pm.layers)
    gate = CNN2Gate.from_graph(g)
    x = RNG.standard_normal((4, 3, 14, 14)).astype(np.float32) * 0.5
    gate.calibrate_quantization(x)
    y_q = np.asarray(gate.build("emulation")(jnp.asarray(x)))
    y_f = np.asarray(cnn.run_float(g, jnp.asarray(x)))
    assert y_q.shape == y_f.shape
    rel = np.linalg.norm(y_q - y_f) / max(np.linalg.norm(y_f), 1e-9)
    assert rel < 0.75


def test_int8_gap_network_matches_float_top1():
    g = cnn.tiny_cnn_gap(batch=4)
    gate = CNN2Gate.from_graph(g)
    x = RNG.standard_normal((4, 3, 32, 32)).astype(np.float32) * 0.5
    gate.calibrate_quantization(x)
    y_q = np.asarray(gate.build("emulation")(jnp.asarray(x)))
    y_f = np.asarray(cnn.run_float(g, jnp.asarray(x)))
    assert y_q.shape == (4, 10)
    assert np.all(y_q.argmax(-1) == y_f.argmax(-1))
