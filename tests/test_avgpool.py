"""Standalone average-pool stages: parser, kernels, end-to-end int8."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import parser
from repro.core.synthesis import CNN2Gate
from repro.kernels import ref
from repro.models import cnn

RNG = np.random.default_rng(11)


def test_parser_does_not_fuse_avgpool():
    pm = parser.parse(cnn.tiny_cnn_gap())
    kinds = [(l.kind, l.pool_type if l.kind == "pool" else None,
              l.pool is not None) for l in pm.layers]
    # conv (no fused pool), avg pool, conv, global avg pool, fc
    assert kinds == [("conv", None, False), ("pool", "avg", False),
                     ("conv", None, False), ("pool", "avg", False),
                     ("fc", None, False)]


@settings(max_examples=50, deadline=None)
@given(h=st.integers(4, 16), c=st.integers(1, 8),
       k=st.sampled_from([2, 3]), s=st.sampled_from([1, 2]))
def test_avgpool_ref_matches_float_rounding(h, c, k, s):
    if h < k:
        return
    x = RNG.integers(-128, 128, (1, h, h, c), np.int8)
    got = np.asarray(ref.avgpool2d_ref(jnp.asarray(x), k, s))
    # round-half-up fixed-point mean
    from numpy.lib.stride_tricks import sliding_window_view
    win = sliding_window_view(x.astype(np.int64), (k, k), axis=(1, 2))
    win = win[:, ::s, ::s]
    want = np.floor((win.sum((-1, -2)) + k * k // 2) / (k * k))
    np.testing.assert_array_equal(got, np.clip(want, -128, 127))


def test_int8_gap_network_matches_float_top1():
    g = cnn.tiny_cnn_gap(batch=4)
    gate = CNN2Gate.from_graph(g)
    x = RNG.standard_normal((4, 3, 32, 32)).astype(np.float32) * 0.5
    gate.calibrate_quantization(x)
    y_q = np.asarray(gate.build("emulation")(jnp.asarray(x)))
    y_f = np.asarray(cnn.run_float(g, jnp.asarray(x)))
    assert y_q.shape == (4, 10)
    assert np.all(y_q.argmax(-1) == y_f.argmax(-1))
