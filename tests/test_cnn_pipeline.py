"""End-to-end CNN2Gate pipeline: parse -> quantize -> build -> run.

Validates the paper's emulation-mode loop: the int8 pipelined executor
must agree with the float oracle (top-1) and the fullflow AOT build must
be bit-identical to emulation.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.synthesis import CNN2Gate
from repro.core import parser
from repro.models import cnn

RNG = np.random.default_rng(42)


@pytest.fixture(scope="module")
def tiny_gate():
    g = cnn.tiny_cnn(batch=4)
    gate = CNN2Gate.from_graph(g)
    x = RNG.standard_normal((4, 3, 32, 32)).astype(np.float32) * 0.5
    gate.calibrate_quantization(x)
    return gate, g, x


def test_int8_emulation_top1_matches_float(tiny_gate):
    gate, g, x = tiny_gate
    y_q = np.asarray(gate.build("emulation")(jnp.asarray(x)))
    y_f = np.asarray(cnn.run_float(g, jnp.asarray(x)))
    assert y_q.shape == y_f.shape == (4, 10)
    assert np.all(y_q.argmax(-1) == y_f.argmax(-1))
    assert not np.any(np.isnan(y_q))


def test_int8_output_invariant_to_hardware_options(tiny_gate):
    """(N_i, N_l) trade resources for speed — results must be identical
    (the paper's options only change kernel blocking)."""
    gate, _g, x = tiny_gate
    y1 = np.asarray(gate.build("emulation", n_i=4, n_l=4)(jnp.asarray(x)))
    y2 = np.asarray(gate.build("emulation", n_i=16, n_l=32)(jnp.asarray(x)))
    np.testing.assert_array_equal(y1, y2)


def test_fullflow_bit_identical_to_emulation(tiny_gate):
    gate, _g, x = tiny_gate
    y_e = np.asarray(gate.build("emulation")(jnp.asarray(x)))
    y_f = np.asarray(gate.build("fullflow")(jnp.asarray(x)))
    np.testing.assert_array_equal(y_e, y_f)
    assert gate.synthesis_time_s > 0
    assert gate.compiled.memory_analysis() is not None


def test_latency_model_reproduces_table1():
    gate_a = CNN2Gate.from_graph(cnn.alexnet())
    gate_v = CNN2Gate.from_graph(cnn.vgg16())
    # Arria 10 @ (16,32): paper 18.24 ms / 205 ms
    a = gate_a.latency_report("ARRIA10", 16, 32).total_s * 1e3
    v = gate_v.latency_report("ARRIA10", 16, 32).total_s * 1e3
    assert abs(a - 18.24) / 18.24 < 0.05
    assert abs(v - 205.0) / 205.0 < 0.20
    # Cyclone V @ (8,8): paper 153 ms AlexNet
    c = gate_a.latency_report("5CSEMA5", 8, 8).total_s * 1e3
    assert abs(c - 153.0) / 153.0 < 0.05


def test_fig6_breakdown_structure():
    """Fig. 6: per-stage times; later conv stages cheaper than conv2."""
    gate = CNN2Gate.from_graph(cnn.alexnet())
    rep = gate.latency_report("ARRIA10", 16, 32)
    convs = [l for l in rep.layers if l.kind == "conv"]
    fcs = [l for l in rep.layers if l.kind == "fc"]
    assert len(convs) == 5 and len(fcs) == 3
    assert max(c.time_s for c in convs[2:]) < convs[1].time_s * 2
    # FC stages are memory-bound (weights dominate)
    assert all(f.t_memory > f.t_compute for f in fcs)


def test_gops_performance_density():
    """Table 3: performance density GOp/s/DSP = 0.266 for this work."""
    gate = CNN2Gate.from_graph(cnn.alexnet())
    rep = gate.latency_report("ARRIA10", 16, 32)
    dse_res = gate.explore("ARRIA10", algo="bf")
    dsp = dse_res.best_report.raw["dsp"]
    density = rep.gops / dsp
    assert abs(density - 0.266) / 0.266 < 0.10


def test_memory_schedule_covers_all_layers():
    pm = parser.parse(cnn.alexnet())
    sched = parser.memory_schedule(pm, 16, 32)
    assert len(sched) == len(pm.layers)
    assert all(s["read_vectors"] > 0 and s["lanes"] > 0 for s in sched)
