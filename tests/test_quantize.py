"""(N, m) fixed-point quantization properties (§4.2 Physical domain)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quantize as Q


@settings(max_examples=200, deadline=None)
@given(m=st.integers(0, 12),
       vals=st.lists(st.floats(-4, 4, allow_nan=False), min_size=1,
                     max_size=64))
def test_roundtrip_error_bounded_by_half_lsb(m, vals):
    """|dequant(quant(x)) - x| <= 2^-(m+1) for in-range values."""
    x = np.asarray(vals, np.float32)
    in_range = np.abs(x) <= (127.0 / 2 ** m)
    q = Q.quantize_array(x, m)
    xd = Q.dequantize_array(q, m)
    err = np.abs(xd - x)
    assert np.all(err[in_range] <= 2.0 ** -(m + 1) + 1e-6)


@settings(max_examples=100, deadline=None)
@given(m=st.integers(0, 12))
def test_out_of_range_saturates(m):
    big = np.asarray([1e9, -1e9], np.float32)
    q = Q.quantize_array(big, m)
    assert q[0] == 127 and q[1] == -128


@settings(max_examples=100, deadline=None)
@given(vals=st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                     max_size=64))
def test_best_pow2_exponent_never_clips(vals):
    x = np.asarray(vals, np.float32)
    m = Q.best_pow2_exponent(x)
    scaled = np.abs(x) * 2.0 ** m
    assert np.all(scaled <= 127.0 + 1e-4)


def test_requant_shift_definition():
    spec = Q.QuantSpec(m_w=7, m_x=6, m_y=5)
    assert spec.requant_shift == 8
    with pytest.raises(ValueError):
        _ = Q.QuantSpec(m_w=1, m_x=1, m_y=5).requant_shift


@settings(max_examples=200, deadline=None)
@given(acc=st.integers(-(2 ** 30), 2 ** 30), s=st.integers(1, 16))
def test_requantize_round_half_up(acc, s):
    """Shift-requantization == round-half-up division by 2^s, clipped."""
    spec = Q.QuantSpec(m_w=s, m_x=0, m_y=0)
    got = Q.requantize(np.asarray([acc]), spec)[0]
    want = int(np.clip(np.floor((acc + 2 ** (s - 1)) / 2 ** s), -128, 127))
    assert got == want


def test_bias_scale_matches_accumulator():
    """Biases quantize at 2^-(m_w+m_x) so they add into int32 acc raw."""
    spec = Q.QuantSpec(m_w=6, m_x=4, m_y=4)
    w = np.asarray([[0.5]], np.float32)
    b = np.asarray([0.25], np.float32)
    wq, bq = Q.quantize_weights(w, b, spec)
    assert wq.dtype == np.int8 and bq.dtype == np.int32
    assert wq[0, 0] == round(0.5 * 2 ** 6)
    assert bq[0] == round(0.25 * 2 ** 10)


def test_quantization_error_decreases_with_m():
    x = np.random.default_rng(0).uniform(-0.9, 0.9, 1000).astype(np.float32)
    errs = [Q.quantization_error(x, m) for m in range(1, 8)]
    assert all(a >= b for a, b in zip(errs, errs[1:]))


@settings(max_examples=100, deadline=None)
@given(m=st.integers(0, 6),
       vals=st.lists(st.floats(-2, 2, allow_nan=False), min_size=1,
                     max_size=32))
def test_int4_roundtrip_error_bounded(m, vals):
    """The paper notes CNNs work at '8-bit or less': the (N, m) scheme
    is bit-width generic — 4-bit error bound is half an LSB too."""
    x = np.asarray(vals, np.float32)
    in_range = np.abs(x) <= (7.0 / 2 ** m)
    q = Q.quantize_array(x, m, bits=4)
    xd = Q.dequantize_array(q, m)
    err = np.abs(xd - x)
    assert np.all(err[in_range] <= 2.0 ** -(m + 1) + 1e-6)
    assert q.max() <= 7 and q.min() >= -8
