"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.qgemm import qgemm
from repro.kernels.qconv import qconv2d
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(0)


def i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, shape, np.int8))


# --------------------------------------------------------------- qgemm
@pytest.mark.parametrize("m,k,n", [(1, 16, 8), (7, 33, 65), (128, 256, 128),
                                   (200, 100, 300), (1, 9216, 64)])
@pytest.mark.parametrize("shift,relu", [(0, False), (7, True), (12, False)])
def test_qgemm_matches_ref(m, k, n, shift, relu):
    x, w = i8(m, k), i8(k, n)
    b = jnp.asarray(RNG.integers(-(1 << 20), 1 << 20, (n,), np.int32))
    got = qgemm(x, w, b, shift=shift, relu=relu, interpret=True,
                block_m=32, block_n=128, block_k=128)
    want = ref.qgemm_ref(x, w, b, shift, relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qgemm_no_bias():
    x, w = i8(17, 40), i8(40, 10)
    got = qgemm(x, w, None, shift=6, interpret=True)
    want = ref.qgemm_ref(x, w, None, 6, False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------- qconv
@pytest.mark.parametrize("cfg", [
    # (h, w, cin, cout, k, stride, pool)
    (12, 12, 4, 8, 3, 1, None),
    (16, 16, 3, 16, 3, 1, (2, 2)),
    (23, 23, 8, 32, 5, 2, None),
    (27, 27, 16, 24, 3, 1, (3, 2)),     # AlexNet-style overlapping pool
    (14, 14, 32, 130, 3, 1, (2, 2)),    # cout not a multiple of block
])
@pytest.mark.parametrize("shift,relu", [(8, True), (5, False)])
def test_qconv_matches_ref(cfg, shift, relu):
    h, w, cin, cout, k, stride, pool = cfg
    x = i8(2, h, w, cin)
    wt = i8(k, k, cin, cout)
    b = jnp.asarray(RNG.integers(-1000, 1000, (cout,), np.int32))
    got = qconv2d(x, wt, b, strides=(stride, stride), shift=shift, relu=relu,
                  pool=pool, block_cout=64, interpret=True)
    want = ref.qconv2d_ref(x, wt, b, (stride, stride), shift, relu, pool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qconv_nchw_wrapper_pads():
    # ONNX-layout wrapper with explicit pads vs lax conv on padded input
    x = i8(1, 3, 10, 10)
    w = i8(8, 3, 3, 3)  # OIHW
    b = jnp.zeros((8,), jnp.int32)
    got = ops.qconv2d_nchw(x, w, b, strides=(1, 1), pads=(1, 1, 1, 1),
                           shift=7, relu=True, interpret=True)
    xh = jnp.pad(jnp.transpose(x, (0, 2, 3, 1)), ((0, 0), (1, 1), (1, 1), (0, 0)))
    want = ref.qconv2d_ref(xh, jnp.transpose(w, (2, 3, 1, 0)), b, (1, 1), 7, True)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.transpose(want, (0, 3, 1, 2))))


# ----------------------------------------------------------- attention
@pytest.mark.parametrize("b,h,hkv,sq,skv,d", [
    (1, 4, 4, 64, 64, 32),     # MHA
    (2, 8, 2, 128, 128, 64),   # GQA 4:1
    (1, 2, 1, 100, 100, 64),   # ragged seq (padding path)
    (1, 4, 2, 32, 160, 64),    # cross/continuation: skv > sq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, h, hkv, sq, skv, d, dtype):
    q = jnp.asarray(RNG.standard_normal((b, h, sq, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, hkv, skv, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, hkv, skv, d)), dtype)
    off = skv - sq
    got = flash_attention(q, k, v, causal=True, q_offset=off,
                          block_q=32, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=off)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol, rtol=1e-2)


def test_flash_attention_sliding_window():
    q = jnp.asarray(RNG.standard_normal((1, 4, 96, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 96, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 96, 32)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=24,
                          block_q=32, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=24)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-3)


def test_flash_attention_noncausal():
    q = jnp.asarray(RNG.standard_normal((1, 2, 40, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 72, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 72, 64)), jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=16, block_k=32,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-3)


# ----------------------------------------------------------------- ssd
@pytest.mark.parametrize("b,l,h,p,g,n,chunk", [
    (1, 64, 2, 16, 1, 16, 16),
    (2, 100, 4, 32, 2, 32, 32),   # ragged chunks, grouped B/C
    (1, 128, 8, 64, 1, 64, 64),
])
def test_ssd_matches_ref(b, l, h, p, g, n, chunk):
    x = jnp.asarray(RNG.standard_normal((b, l, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, l, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((b, l, g, n)) * 0.3, jnp.float32)
    cc = jnp.asarray(RNG.standard_normal((b, l, g, n)) * 0.3, jnp.float32)
    d = jnp.asarray(RNG.standard_normal((h,)), jnp.float32)
    got = ssd_scan(x, dt, a, bb, cc, d, chunk=chunk, interpret=True)
    want, _ = ref.ssd_ref(x, dt, a, bb, cc, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-3)


def test_ssd_chunk_invariance():
    """Different chunk sizes must agree — the scan decomposition is exact."""
    b, l, h, p, g, n = 1, 96, 2, 16, 1, 16
    x = jnp.asarray(RNG.standard_normal((b, l, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, l, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((b, l, g, n)) * 0.3, jnp.float32)
    cc = jnp.asarray(RNG.standard_normal((b, l, g, n)) * 0.3, jnp.float32)
    y16 = ssd_scan(x, dt, a, bb, cc, chunk=16, interpret=True)
    y48 = ssd_scan(x, dt, a, bb, cc, chunk=48, interpret=True)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y48),
                               atol=1e-4, rtol=1e-3)


# ------------------------------------------------ property sweeps
from hypothesis import given, settings, strategies as st


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 64), k=st.integers(1, 96), n=st.integers(1, 96),
       shift=st.integers(0, 14), relu=st.booleans())
def test_qgemm_property_random_shapes(m, k, n, shift, relu):
    rng = np.random.default_rng(m * 10007 + k * 101 + n)
    x = jnp.asarray(rng.integers(-128, 128, (m, k), np.int8))
    w = jnp.asarray(rng.integers(-128, 128, (k, n), np.int8))
    b = jnp.asarray(rng.integers(-(1 << 16), 1 << 16, (n,), np.int32))
    got = qgemm(x, w, b, shift=shift, relu=relu, interpret=True,
                block_m=16, block_n=32, block_k=32)
    want = ref.qgemm_ref(x, w, b, shift, relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(sq=st.integers(1, 48), skv=st.integers(1, 80),
       h=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2]))
def test_flash_attention_property(sq, skv, h, g):
    if skv < sq:
        skv = sq  # causal continuation requires cache >= query span
    hkv = max(1, h // g)
    hq = hkv * g
    rng = np.random.default_rng(sq * 131 + skv)
    q = jnp.asarray(rng.standard_normal((1, hq, sq, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, hkv, skv, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, hkv, skv, 16)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, q_offset=skv - sq,
                          block_q=16, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=skv - sq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-5, rtol=1e-3)
