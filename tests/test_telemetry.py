"""Telemetry layer: registry semantics under threads, histogram edge
conventions, Chrome-trace schema validity, span nesting, the stage-timed
executor's parity/coverage, and the jaxpr-identity guarantee that
telemetry never perturbs the default executor."""
import json
import threading

import jax
import numpy as np
import pytest

from repro.core import pipeline as pipe
from repro.core import telemetry as tele
from repro.core.synthesis import CNN2Gate
from repro.models import cnn

RNG = np.random.default_rng(23)


# ------------------------------------------------------------ registry

def test_counter_thread_safety_smoke():
    reg = tele.MetricsRegistry()
    c = reg.counter("hits")
    n_threads, n_incs = 8, 2000

    def worker():
        for _ in range(n_incs):
            reg.counter("hits").inc()

    ts = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n_threads * n_incs


def test_counter_monotonic_and_kind_mismatch():
    reg = tele.MetricsRegistry()
    reg.counter("a").inc(2.5)
    with pytest.raises(ValueError):
        reg.counter("a").inc(-1)
    with pytest.raises(TypeError):
        reg.gauge("a")           # already a counter
    with pytest.raises(TypeError):
        reg.histogram("a")


def test_gauge_set_add():
    g = tele.MetricsRegistry().gauge("depth")
    g.set(3)
    g.add(-1)
    assert g.value == 2.0


def test_histogram_bucket_edges_inclusive():
    # Prometheus `le` convention: a value on the edge lands IN that
    # bucket, the first value past it in the next.
    reg = tele.MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0))
    for v in (1.0, 1.0000001, 2.0, 4.0, 4.0000001, 100.0):
        h.record(v)
    assert h.counts == [1, 2, 1, 2]    # last is the +Inf overflow
    assert h.count == 6
    assert h.min == 1.0 and h.max == 100.0


def test_histogram_percentiles():
    h = tele.MetricsRegistry().histogram("lat", buckets=(1.0, 2.0, 4.0))
    assert h.percentile(50) is None    # empty
    for v in (0.5, 1.5, 1.6, 3.0):
        h.record(v)
    p50 = h.percentile(50)
    assert 1.0 <= p50 <= 2.0           # falls in the (1, 2] bucket
    # percentiles are clamped to the observed range, never a raw edge
    assert h.percentile(0) >= 0.5
    assert h.percentile(100) <= 3.0
    h.record(50.0)                     # overflow bucket
    assert h.percentile(99) == 50.0    # +Inf bucket reports observed max
    with pytest.raises(ValueError):
        h.percentile(101)


def test_histogram_rejects_bad_buckets():
    reg = tele.MetricsRegistry()
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=())
    with pytest.raises(ValueError):
        reg.histogram("bad2", buckets=(1.0, 1.0))


def test_snapshot_shape_and_json_round_trip():
    reg = tele.MetricsRegistry()
    reg.counter("c").inc(3)
    reg.gauge("g").set(7)
    reg.histogram("h", buckets=(1.0, 2.0)).record(1.5)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3.0
    assert snap["gauges"]["g"] == 7.0
    hist = snap["histograms"]["h"]
    assert hist["count"] == 1 and hist["bucket_counts"] == [0, 1, 0]
    for k in ("sum", "min", "max", "mean", "p50", "p95", "p99",
              "buckets"):
        assert k in hist
    json.dumps(snap)                   # must be JSON-serializable
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}


# -------------------------------------------------------------- tracer

def test_chrome_trace_schema():
    tr = tele.Tracer()
    with tr.span("outer", cat="test", args={"k": 1}):
        pass
    tr.add_span("injected", ts_us=1.0, dur_us=2.0, cat="stage")
    doc = tr.to_chrome_trace()
    blob = json.dumps(doc)             # Perfetto needs valid JSON
    doc = json.loads(blob)
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert len(evs) == 2
    for ev in evs:                     # complete-event required keys
        for key in ("name", "ph", "ts", "dur", "pid", "tid", "cat"):
            assert key in ev, f"missing {key!r} in {ev}"
        assert ev["ph"] == "X"
        assert isinstance(ev["ts"], (int, float))
        assert isinstance(ev["dur"], (int, float))
        assert ev["dur"] >= 0


def test_span_nesting_containment():
    # Perfetto infers nesting from containment per tid: the child span
    # interval must lie inside the parent's.
    tr = tele.Tracer()
    with tr.span("parent"):
        with tr.span("child"):
            pass
    by_name = {e["name"]: e for e in tr.events()}
    p, c = by_name["parent"], by_name["child"]
    assert p["tid"] == c["tid"]
    assert p["ts"] <= c["ts"]
    assert c["ts"] + c["dur"] <= p["ts"] + p["dur"]


def test_span_records_error():
    tr = tele.Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("kaput")
    (ev,) = tr.events()
    assert "RuntimeError" in ev["args"]["error"]


def test_tracer_drops_past_max_events():
    tr = tele.Tracer(max_events=2)
    for i in range(5):
        tr.add_span(f"s{i}", 0.0, 1.0)
    assert len(tr.events()) == 2
    assert tr.dropped == 3


def test_tracer_export(tmp_path):
    tr = tele.Tracer()
    with tr.span("s"):
        pass
    path = tr.export(str(tmp_path / "sub" / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert doc["traceEvents"][0]["name"] == "s"


# ----------------------------------------------- stage-timed executor

@pytest.fixture(scope="module")
def gate():
    g = CNN2Gate.from_graph(cnn.resnet_tiny(batch=1))
    x = (RNG.standard_normal((1, 3, 32, 32)) * 0.5).astype(np.float32)
    g.calibrate_quantization(x)
    return g, x


def test_telemetry_off_keeps_jaxpr_identical(gate):
    """Default executor jaxpr must be byte-identical whether or not
    telemetry has been exercised in the process — the observability
    layer must never perturb the compiled program."""
    g, x = gate
    base = str(jax.make_jaxpr(
        pipe.make_executor(g.quantized, 16, 32, interpret=True))(x))
    tele.get_tracer().add_span("noise", 0.0, 1.0)
    tele.get_registry().counter("noise").inc()
    try:
        probe = str(jax.make_jaxpr(
            pipe.make_executor(g.quantized, 16, 32, interpret=True,
                               stage_timed=False, tracer=None))(x))
    finally:
        tele.reset()
    assert probe == base


def test_stage_timed_parity_and_coverage(gate):
    g, x = gate
    plain = pipe.make_executor(g.quantized, 16, 32, interpret=True)
    tr = tele.Tracer()
    timed = pipe.make_executor(g.quantized, 16, 32, interpret=True,
                               stage_timed=True, tracer=tr)
    y0 = np.array(plain(x))
    y1, timings = timed(x)
    np.testing.assert_array_equal(y0, np.array(y1))   # bit-exact

    names = [t["stage"] for t in timings]
    assert names[0] == "ingress" and names[-1] == "egress"
    scheduled = [ql.info.name for ql in g.quantized.layers]
    assert names[1:-1] == scheduled                   # full coverage
    assert all(t["wall_us"] >= 0 for t in timings)
    # every stage produced a span on the tracer
    span_names = {e["name"] for e in tr.events()
                  if e.get("cat") == "stage"}
    assert set(scheduled) <= span_names


def test_stage_timed_exclusive_with_hooks(gate):
    g, _ = gate
    with pytest.raises(ValueError, match="stage_timed"):
        pipe.make_executor(g.quantized, 16, 32, interpret=True,
                           stage_timed=True, audit=True)
    with pytest.raises(ValueError, match="stage_timed"):
        pipe.make_executor(g.quantized, 16, 32, interpret=True,
                           stage_timed=True,
                           checkpoints=[g.quantized.layers[0].info.name])


# ------------------------------------------------ attribution profile

def test_spearman_rank_correlation():
    from repro.launch.profile import spearman
    assert spearman([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
    assert spearman([1, 2, 3], [30, 20, 10]) == pytest.approx(-1.0)
    assert spearman([1.0, 1.0, 1.0], [1, 2, 3]) is None  # constant side
    assert spearman([1], [2]) is None                    # too few
    # monotone nonlinear map preserves ranks exactly
    a = [1.0, 4.0, 2.0, 8.0, 5.0]
    assert spearman(a, [v ** 3 for v in a]) == pytest.approx(1.0)


def test_profile_model_report_shape():
    from repro.launch import profile as prof
    tr = tele.Tracer()
    doc = prof.profile_model("tiny_cnn", iters=1, warmup=1, tracer=tr)
    s = doc["summary"]
    assert s["n_stages"] == len(doc["stages"]) > 0
    for row in doc["stages"]:
        for key in ("stage", "kind", "wall_us", "model_us", "ddr_bytes",
                    "vmem_bytes", "macs", "model_wall_ratio"):
            assert key in row
        assert row["wall_us"] >= 0 and row["model_us"] > 0
    assert "ingress" in doc["overhead_us"]
    assert "egress" in doc["overhead_us"]
    json.dumps(doc)                    # BENCH-ready


# ----------------------------------------------- instrumented consumers

def test_robust_evaluator_mirrors_stats_to_registry():
    from repro.core import dse
    from repro.core.resources import ResourceReport

    class TinySpace(dse.DesignSpace):
        def options(self):
            return [(0,), (1,)]

        def axes(self):
            return [[0, 1]]

        def evaluate(self, option):
            pct = 40.0 + 10.0 * option[0]
            return ResourceReport(
                percents={k: pct for k in ("lut", "dsp", "mem", "reg")},
                raw={"pct": pct}, fits=True)

    reg, tr = tele.MetricsRegistry(), tele.Tracer()
    ev = dse.RobustEvaluator(TinySpace(), registry=reg, tracer=tr)
    for opt in ev.options():
        ev.evaluate(opt)
    snap = reg.snapshot()["counters"]
    assert snap.get("dse.evaluated") == ev.stats["evaluated"] == 2
    assert any(e["name"] == "dse.evaluate" for e in tr.events())


def test_bench_json_schema(tmp_path, monkeypatch):
    from benchmarks import common
    monkeypatch.setattr(common, "REPO_ROOT", str(tmp_path))
    with pytest.raises(TypeError):
        common.write_bench_json("x", [1, 2, 3])
    path = common.write_bench_json("x", {"ok": 1})
    with open(path) as f:
        doc = json.load(f)
    assert doc["bench"] == "x" and doc["results"] == {"ok": 1}
    for key in common.ENV_REQUIRED_KEYS:
        assert key in doc["env"]
