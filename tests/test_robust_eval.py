"""RobustEvaluator: timeout, retry, quarantine, and journal resume over
a deliberately hostile design space."""
import os
import time

import pytest

from repro.core import dse
from repro.core.resources import ResourceReport


def _report(pct: float) -> ResourceReport:
    return ResourceReport(
        percents={k: pct for k in ("lut", "dsp", "mem", "reg")},
        raw={"pct": pct}, fits=pct <= 100.0)


class FlakySpace(dse.DesignSpace):
    """Four candidates: one healthy, one that always raises, one that
    hangs past any reasonable timeout, one that fails twice then
    succeeds (and is the best option, so retry matters)."""

    HANG_S = 30.0

    def __init__(self):
        self.calls = {"good": 0, "raises": 0, "hangs": 0, "flaky": 0}

    def options(self):
        return [("good",), ("raises",), ("hangs",), ("flaky",)]

    def axes(self):
        return [["good", "raises", "hangs", "flaky"]]

    def evaluate(self, option):
        (name,) = option
        self.calls[name] += 1
        if name == "raises":
            raise RuntimeError("compiler segfault")
        if name == "hangs":
            time.sleep(self.HANG_S)
            return _report(10.0)
        if name == "flaky":
            if self.calls[name] <= 2:
                raise OSError("license server flake")
            return _report(80.0)   # best fitting candidate
        return _report(50.0)


def _evaluator(space, journal):
    return dse.RobustEvaluator(space, timeout_s=0.3, retries=2,
                               backoff_s=0.01, journal_path=journal)


def test_sweep_completes_quarantines_and_retries(tmp_path):
    journal = str(tmp_path / "sweep.json")
    space = FlakySpace()
    t0 = time.perf_counter()
    res = dse.brute_force(_evaluator(space, journal))
    wall = time.perf_counter() - t0
    # the hang cost one timeout budget, not HANG_S
    assert wall < FlakySpace.HANG_S / 2
    assert res.found and res.best == ("flaky",)   # retry won
    assert res.f_max == pytest.approx(80.0)
    assert space.calls == {"good": 1, "raises": 3, "hangs": 1, "flaky": 3}


def test_quarantine_reasons_and_stats(tmp_path):
    journal = str(tmp_path / "sweep.json")
    space = FlakySpace()
    robust = _evaluator(space, journal)
    dse.brute_force(robust)
    quarantined = dict((tuple(o), why)
                       for o, why in robust.quarantined_options())
    assert set(quarantined) == {("raises",), ("hangs",)}
    assert "RuntimeError" in quarantined[("raises",)]
    assert "EvalTimeout" in quarantined[("hangs",)]
    assert robust.stats["quarantined"] == 2
    assert robust.stats["timeouts"] == 1
    assert robust.stats["retries"] >= 2
    assert robust.stats["evaluated"] == 2      # good + flaky
    # quarantined candidates score as unfittable, never as exceptions
    rep = robust.evaluate(("raises",))
    assert not rep.fits and rep.percents["lut"] == dse.FAILED_PCT


def test_journal_resume_skips_all_work(tmp_path):
    journal = str(tmp_path / "sweep.json")
    dse.brute_force(_evaluator(FlakySpace(), journal))
    assert os.path.exists(journal)
    # fresh evaluator over a fresh space: everything replays from disk
    space2 = FlakySpace()
    robust2 = _evaluator(space2, journal)
    res2 = dse.brute_force(robust2)
    assert space2.calls == {"good": 0, "raises": 0, "hangs": 0, "flaky": 0}
    assert res2.found and res2.best == ("flaky",)
    assert res2.f_max == pytest.approx(80.0)
    assert robust2.stats["journal_hits"] == 4
    assert robust2.stats["evaluated"] == 0


def test_rl_dse_survives_hostile_space(tmp_path):
    space = FlakySpace()
    robust = _evaluator(space, str(tmp_path / "rl.json"))
    res = dse.rl_dse(robust, episodes=3, steps_per_episode=6, seed=0)
    # quarantined candidates read as over-quota (-1 reward), the agent
    # keeps exploring, and each option compiled at most once + retries
    assert space.calls["hangs"] <= 1
    assert res.steps == 18
