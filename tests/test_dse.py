"""DSE invariants + reproduction of the paper's Table-2 decisions."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dse
from repro.core.parser import parse
from repro.core.resources import FPGA_BOARDS, estimate_fpga
from repro.core.spaces import CNNDesignSpace
from repro.core.synthesis import CNN2Gate
from repro.models import cnn


@pytest.fixture(scope="module")
def alexnet_gate():
    return CNN2Gate.from_graph(cnn.alexnet())


# ------------------------------------------------ paper Table 2 decisions
def test_5csema4_does_not_fit(alexnet_gate):
    res = alexnet_gate.explore("5CSEMA4", algo="bf")
    assert not res.found  # paper: "Does not fit"


def test_5csema5_finds_8_8(alexnet_gate):
    res = alexnet_gate.explore("5CSEMA5", algo="bf")
    assert res.best == (8, 8)
    # paper Table 1: Logic 83 %, DSP 83 %, RAM 100 %
    p = res.best_report.percents
    assert abs(p["lut"] - 83) < 5 and abs(p["dsp"] - 83) < 5
    assert p["mem"] > 95


def test_arria10_finds_16_32(alexnet_gate):
    res = alexnet_gate.explore("ARRIA10", algo="bf")
    assert res.best == (16, 32)
    p = res.best_report.percents
    # paper Table 3: Logic 30 %, DSP 20 %
    assert abs(p["lut"] - 30) < 3 and abs(p["dsp"] - 20) < 3


@pytest.mark.parametrize("board,expected", [
    ("5CSEMA4", None), ("5CSEMA5", (8, 8)), ("ARRIA10", (16, 32))])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_rl_dse_agrees_with_bf(alexnet_gate, board, expected, seed):
    res = alexnet_gate.explore(board, algo="rl", seed=seed)
    assert res.best == expected


def test_rl_dse_fewer_compiler_calls_than_bf(alexnet_gate):
    """Table 2: RL-DSE ~25 % faster (fewer unique vendor-compiler calls)."""
    bf = alexnet_gate.explore("ARRIA10", algo="bf", eval_cost_s=7.0)
    rl = alexnet_gate.explore("ARRIA10", algo="rl", eval_cost_s=7.0, seed=0)
    assert rl.evaluations <= bf.evaluations
    assert rl.wall_time_s < bf.wall_time_s


def test_vgg_dse_matches_alexnet_decision():
    """Paper §5: core is nearly identical across CNNs; VGG also gets
    (16,32) on Arria 10 and uses ~8 % more RAM blocks."""
    gate_v = CNN2Gate.from_graph(cnn.vgg16())
    res_v = gate_v.explore("ARRIA10", algo="bf")
    assert res_v.best == (16, 32)
    a = estimate_fpga(FPGA_BOARDS["ARRIA10"], 16, 32,
                      parse(cnn.alexnet()).total_weights)
    v = res_v.best_report
    extra = (v.percents["mem"] - a.percents["mem"])
    assert 4 < extra < 12  # ~8 % more block RAM


# ----------------------------------------------------------- invariants
def test_bf_never_exceeds_thresholds(alexnet_gate):
    th = {"lut": 50.0, "dsp": 100.0, "mem": 100.0, "reg": 100.0}
    res = alexnet_gate.explore("ARRIA10", algo="bf", thresholds=th)
    assert res.found
    assert res.best_report.percents["lut"] <= 50.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_rl_best_always_feasible_and_leq_bf(seed):
    gate = CNN2Gate.from_graph(cnn.alexnet())
    space = CNNDesignSpace(gate.parsed, FPGA_BOARDS["ARRIA10"])
    bf = dse.brute_force(space)
    rl = dse.rl_dse(space, seed=seed)
    if rl.found:
        rep = space.evaluate(rl.best)
        assert all(v <= 100.0 for v in rep.percents.values())
        assert rl.f_max <= bf.f_max + 1e-9  # BF is exhaustive: global opt


def test_reward_shaping_algorithm1():
    """Direct unit test of the Algorithm-1 semantics via history."""
    gate = CNN2Gate.from_graph(cnn.alexnet())
    space = CNNDesignSpace(gate.parsed, FPGA_BOARDS["5CSEMA5"])
    res = dse.rl_dse(space, seed=3)
    # every infeasible option in history must have at least one quota > 100
    for opt, _f, ok in res.history:
        rep = space.evaluate(opt)
        assert ok == all(v <= 100.0 for v in rep.percents.values())


def test_options_respect_caps_and_divisibility(alexnet_gate):
    space = CNNDesignSpace(alexnet_gate.parsed, FPGA_BOARDS["ARRIA10"])
    for ni, nl in space.options():
        assert ni <= 16 and nl <= 32
        for li in alexnet_gate.parsed.layers[1:]:
            assert li.c_in % ni == 0
