"""End-to-end LM training driver example.

Smoke preset (CPU, seconds):
    PYTHONPATH=src python examples/train_lm.py --preset smoke

~100M-parameter run (the deliverable-scale config; needs a beefier
machine or pod — the same command with --mesh production runs on TPU):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

This is a thin veneer over repro.launch.train: resume, async
checkpoints, straggler monitor and preemption handling all included.
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.preset == "smoke":
        argv = ["--arch", "qwen2-1.5b", "--preset", "smoke",
                "--steps", str(args.steps or 60),
                "--seq-len", "64", "--global-batch", "8",
                "--lr", "3e-3", "--warmup", "10"]
    else:
        # ~100M dense transformer (configs/lm100m.py): the
        # train-for-a-few-hundred-steps deliverable scale
        argv = ["--arch", "lm100m", "--preset", "full",
                "--steps", str(args.steps or 300),
                "--seq-len", "512", "--global-batch", "8",
                "--lr", "6e-4", "--warmup", "50"]
    if args.ckpt_dir:
        argv += ["--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50"]
    return train_mod.main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
