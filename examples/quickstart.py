"""CNN2Gate quickstart: the paper's full pipeline on a small CNN.

    PYTHONPATH=src python examples/quickstart.py [--model alexnet]

Steps (Fig. 4a of the paper):
  1. build/export a CNN in the ONNX-lite transport format,
  2. front-end parse -> linked pipeline of fused stages,
  3. apply post-training (N, m) quantization,
  4. hardware-aware DSE against an FPGA profile,
  5. emulation-mode build (CPU verify) + fullflow AOT build,
  6. latency report from the calibrated board model.
"""
import argparse
import time

import numpy as np
import jax.numpy as jnp

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.synthesis import CNN2Gate
from repro.core import onnx_lite
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="tiny",
                    choices=["tiny", "alexnet", "vgg16"])
    ap.add_argument("--board", default="ARRIA10")
    args = ap.parse_args()

    builder = {"tiny": cnn.tiny_cnn, "alexnet": cnn.alexnet,
               "vgg16": cnn.vgg16}[args.model]
    graph = builder(batch=1)
    print(f"[1] built {graph.name}: {len(graph.nodes)} ONNX-style nodes")

    # round-trip through the transport layer, as a real exporter would
    model_dict = onnx_lite.to_model_dict(graph)
    graph = onnx_lite.from_model_dict(model_dict, graph.initializers)

    gate = CNN2Gate.from_graph(graph)
    print("[2] parsed pipeline:")
    print(gate.summary())

    rng = np.random.default_rng(0)
    shape = (1,) + gate.parsed.input_shape[1:]
    sample = (rng.standard_normal(shape) * 0.5).astype(np.float32)
    specs = gate.calibrate_quantization(sample)
    first = next(iter(specs.items()))
    print(f"[3] quantized: e.g. layer {first[0]} -> (N, m) with "
          f"m_w={first[1].m_w}, m_x={first[1].m_x}, m_y={first[1].m_y}")

    res = gate.explore(args.board, algo="rl")
    print(f"[4] RL-DSE on {args.board}: best (N_i, N_l) = {res.best}, "
          f"{res.evaluations} compiler calls, F_avg={res.f_max:.1f}%")

    run = gate.build("emulation", *(res.best or (16, 32)))
    x = jnp.asarray(sample)
    t0 = time.perf_counter()
    y_int8 = np.asarray(run(x))
    emu_t = time.perf_counter() - t0
    y_float = np.asarray(cnn.run_float(graph, x))
    agree = (y_int8.argmax(-1) == y_float.argmax(-1)).mean()
    print(f"[5] emulation: {emu_t:.2f}s; int8 vs float top-1 agreement "
          f"{agree * 100:.0f}%")

    if res.best:
        rep = gate.latency_report(args.board, *res.best)
        print(f"[6] modeled FPGA latency on {args.board}: "
              f"{rep.total_s * 1e3:.2f} ms ({rep.gops:.1f} GOp/s)")
        for lt in rep.layers:
            print(f"      {lt.name:<12} {lt.kind:<5} {lt.time_s * 1e3:8.3f} ms")


if __name__ == "__main__":
    main()
