"""Batched serving example: continuous batching with slot reuse.

    PYTHONPATH=src python examples/serve_lm.py --requests 8 --slots 4

Uses repro.launch.serve's engine: a fixed slot pool, per-slot lengths,
masked decode attention, requests admitted as slots free up.
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    return serve_mod.main([
        "--arch", args.arch, "--preset", "smoke",
        "--slots", str(args.slots), "--requests", str(args.requests),
        "--max-new", str(args.max_new)])


if __name__ == "__main__":
    raise SystemExit(main())
