"""Reproduce the paper's Table 2: BF-DSE vs RL-DSE across three boards.

    PYTHONPATH=src python examples/dse_alexnet.py [--model alexnet]

Simulates the vendor-compiler call cost (7 s, calibrated so BF-DSE's
30-call sweep costs the paper's ~3.5 min) to show RL-DSE's wall-time
saving with the same answers: does-not-fit / (8,8) / (16,32).
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.synthesis import CNN2Gate
from repro.models import cnn

EVAL_COST_S = 7.0  # one Intel-OpenCL first-stage estimate (calibrated)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="alexnet",
                    choices=["alexnet", "vgg16"])
    args = ap.parse_args()
    graph = cnn.alexnet() if args.model == "alexnet" else cnn.vgg16()
    gate = CNN2Gate.from_graph(graph)

    print(f"{'Platform':<22}{'algo':<6}{'best':<10}{'evals':<7}"
          f"{'sim. time':<11}{'F_avg %':<8}")
    for board in ("5CSEMA4", "5CSEMA5", "ARRIA10"):
        for algo in ("bf", "rl"):
            res = gate.explore(board, algo=algo, eval_cost_s=EVAL_COST_S)
            best = str(res.best) if res.found else "no fit"
            print(f"{board:<22}{algo.upper():<6}{best:<10}"
                  f"{res.evaluations:<7}{res.wall_time_s / 60:5.2f} min"
                  f"  {res.f_max:6.1f}")
        if gate.explore(board, algo="bf").found:
            rep = gate.explore(board, algo="bf").best_report
            print(f"{'':<22}utilization: " + ", ".join(
                f"{k}={v:.0f}%" for k, v in rep.percents.items()))
    print("\npaper Table 2: 5CSEMA4 does not fit; 5CSEMA5 -> (8,8); "
          "Arria10 -> (16,32); RL ~25-30% faster than BF")


if __name__ == "__main__":
    main()
